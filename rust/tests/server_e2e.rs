//! End-to-end tests for the network serving subsystem, over real
//! loopback TCP sockets: remote ingest bit-exactness against in-process
//! ingest, concurrent clients, snapshot → restart → restore, and the
//! corruption paths (bad frames, seed mismatches, damaged snapshot
//! files) — all of which must fail with typed errors, never a panic.

use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hll_fpga::hll::{HllConfig, HllSketch};
use hll_fpga::net::KeyedFlowGen;
use hll_fpga::registry::{RegistryConfig, SketchRegistry, WallClock};
use hll_fpga::server::{
    protocol, read_snapshot, restore_registry, ClientError, ErrorCode, EvictPolicy,
    Response, ServerConfig, SketchClient, SketchServer, SnapshotError, SweeperConfig,
};

fn start_server(cfg: ServerConfig) -> (SketchServer, Arc<SketchRegistry<u64>>) {
    let registry = SketchRegistry::shared(RegistryConfig {
        shards: 16,
        ..RegistryConfig::default()
    })
    .unwrap();
    let server = SketchServer::start("127.0.0.1:0", registry.clone(), cfg).unwrap();
    (server, registry)
}

fn temp_path(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("hll_server_e2e_{}_{name}.snap", std::process::id()));
    p
}

/// Keyed batches: every key's words from a zipf-keyed stream, grouped.
fn keyed_batches(keys: u64, words: usize, seed: u64) -> Vec<(u64, Vec<u32>)> {
    KeyedFlowGen::new(keys, 1.07, seed).batched(words, usize::MAX)
}

#[test]
fn remote_ingest_is_bit_exact_with_in_process() {
    let (server, _registry) = start_server(ServerConfig::default());
    let batches = keyed_batches(200, 30_000, 0xFEED);

    // In-process reference: same batches, same order.
    let reference = SketchRegistry::shared(RegistryConfig {
        shards: 16,
        ..RegistryConfig::default()
    })
    .unwrap();
    for (key, words) in &batches {
        reference.ingest(*key, words);
    }

    // Remote ingest over loopback TCP.
    let mut client = SketchClient::connect(server.local_addr()).unwrap();
    client.ping().unwrap();
    let mut sent = 0u64;
    for (key, words) in &batches {
        sent += client.insert_batch(*key, words).unwrap();
    }
    assert_eq!(sent, 30_000);

    // Every per-key estimate matches the in-process registry exactly
    // (both run the same register files — not approximately, bit-exact).
    for (key, want) in reference.estimates() {
        assert_eq!(client.estimate(key).unwrap(), Some(want), "key {key}");
    }
    assert_eq!(client.estimate(u64::MAX).unwrap(), None);
    assert_eq!(
        client.global_estimate().unwrap(),
        reference.global_estimate(),
        "global unions must match"
    );

    // And the server's registry register files equal the reference's.
    assert_eq!(server.registry().merge_all(), reference.merge_all());

    let stats = client.stats().unwrap();
    assert_eq!(stats.keys as usize, reference.len());
    assert_eq!(stats.words, 30_000);

    let srv = server.stats();
    assert_eq!(srv.words_ingested, 30_000);
    assert!(srv.frames >= batches.len() as u64);
    assert_eq!(srv.error_frames, 0);
    server.shutdown();
}

#[test]
fn stats_rpc_reports_per_tier_counts_and_estimator() {
    let (server, _registry) = start_server(ServerConfig::default());
    let mut client = SketchClient::connect(server.local_addr()).unwrap();

    // One heavy tenant (60k distinct words promotes it out of sparse,
    // into the packed tier) plus a handful of tiny sparse tenants.
    let heavy: Vec<u32> = (0..60_000).collect();
    for chunk in heavy.chunks(8_192) {
        client.insert_batch(1, chunk).unwrap();
    }
    for key in 2u64..=5 {
        client.insert_batch(key, &[key as u32]).unwrap();
    }

    let stats = client.stats().unwrap();
    assert_eq!(stats.keys, 5);
    assert_eq!(stats.packed_keys, 1, "heavy tenant must be packed");
    assert_eq!(stats.sparse_keys, 4);
    assert_eq!(stats.dense_keys, 0);
    assert_eq!(
        stats.sparse_keys + stats.packed_keys + stats.dense_keys,
        stats.keys,
        "tiers must partition the key population"
    );
    // Default registry answers with the Ertl estimator (wire byte 0).
    assert_eq!(stats.estimator, 0);
    // Packed keeps the heavy tenant well under a dense register file.
    assert!(
        (stats.memory_bytes as usize) < HllConfig::PAPER.m(),
        "memory {} must undercut one dense file ({})",
        stats.memory_bytes,
        HllConfig::PAPER.m()
    );
    server.shutdown();
}

#[test]
fn pipelined_and_concurrent_clients_match_serial() {
    let (server, registry) = start_server(ServerConfig::default());
    let batches = keyed_batches(500, 40_000, 0xC0DE);

    // Four clients, each pipelining a quarter of the batches.
    let addr = server.local_addr();
    let chunk = batches.len().div_ceil(4);
    std::thread::scope(|scope| {
        for slice in batches.chunks(chunk) {
            scope.spawn(move || {
                let mut client = SketchClient::connect(addr).unwrap();
                let n: usize = slice.iter().map(|(_, w)| w.len()).sum();
                assert_eq!(client.pipeline_insert(slice).unwrap(), n as u64);
            });
        }
    });

    // The union over all keys is order-independent: bit-identical to a
    // serial sketch over every word.
    let mut serial = HllSketch::new(HllConfig::PAPER);
    for (_, words) in &batches {
        serial.insert_batch(words);
    }
    assert_eq!(registry.merge_all(), serial);
    assert_eq!(registry.stats().words(), 40_000);
    assert!(server.stats().connections >= 4);
    server.shutdown();
}

#[test]
fn snapshot_restart_restore_serves_identical_estimates() {
    let path = temp_path("restart");
    let cfg = ServerConfig { snapshot_path: Some(path.clone()), ..ServerConfig::default() };
    let (server, registry) = start_server(cfg);
    let batches = keyed_batches(150, 25_000, 0xA11CE);

    let mut client = SketchClient::connect(server.local_addr()).unwrap();
    client.pipeline_insert(&batches).unwrap();

    // Capture what the live server answers, then snapshot via RPC.
    let mut before: Vec<(u64, Option<f64>)> = Vec::new();
    for (key, _) in &batches {
        before.push((*key, client.estimate(*key).unwrap()));
    }
    let global_before = client.global_estimate().unwrap();
    let (snap_keys, snap_bytes) = client.snapshot().unwrap();
    assert_eq!(snap_keys as usize, registry.len());
    assert_eq!(snap_bytes, std::fs::metadata(&path).unwrap().len());

    // "Restart": tear the server down, restore the snapshot into a
    // fresh registry, serve it from a new server.
    drop(client);
    server.shutdown();
    let restored = SketchRegistry::shared(RegistryConfig {
        shards: 16,
        ..RegistryConfig::default()
    })
    .unwrap();
    assert_eq!(restore_registry(&restored, &path).unwrap() as u64, snap_keys);
    let server2 = SketchServer::start("127.0.0.1:0", restored, ServerConfig::default()).unwrap();
    let mut client2 = SketchClient::connect(server2.local_addr()).unwrap();

    for (key, want) in before {
        assert_eq!(client2.estimate(key).unwrap(), want, "key {key} after restore");
    }
    assert_eq!(client2.global_estimate().unwrap(), global_before);
    server2.shutdown();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn merge_sketch_rpc_and_seed_mismatch_over_network() {
    let (server, _registry) = start_server(ServerConfig::default());
    let mut client = SketchClient::connect(server.local_addr()).unwrap();

    // A locally built sketch merges into a fresh key and answers the
    // same estimate remotely.
    let mut local = HllSketch::paper();
    for v in 0..5_000u32 {
        local.insert_u32(v.wrapping_mul(2_654_435_761));
    }
    client.merge_sketch(77, &local).unwrap();
    assert_eq!(client.estimate(77).unwrap(), Some(local.estimate()));

    // Merging on top is idempotent (bucket-wise max).
    client.merge_sketch(77, &local).unwrap();
    assert_eq!(client.estimate(77).unwrap(), Some(local.estimate()));

    // A seed-7 sketch rides the v2 wire format with its seed and is
    // rejected with a typed ConfigMismatch — the cross-network version
    // of the silent seed-0 merge bug the v2 format fixed.
    let seeded = HllSketch::new(HllConfig::PAPER.with_seed(7));
    match client.merge_sketch(78, &seeded) {
        Err(ClientError::Remote { code, .. }) => assert_eq!(code, ErrorCode::ConfigMismatch),
        other => panic!("expected remote ConfigMismatch, got {other:?}"),
    }
    assert_eq!(client.estimate(78).unwrap(), None, "failed merge must not create the key");

    // Truncated sketch bytes are a typed Malformed error.
    match client.merge_sketch_bytes(79, &[1, 2, 3]) {
        Err(ClientError::Remote { code, .. }) => assert_eq!(code, ErrorCode::Malformed),
        other => panic!("expected remote Malformed, got {other:?}"),
    }

    // The connection survives all three error frames.
    client.ping().unwrap();
    server.shutdown();
}

#[test]
fn evict_policies_over_rpc() {
    let (server, registry) = start_server(ServerConfig::default());
    let mut client = SketchClient::connect(server.local_addr()).unwrap();

    for key in 0u64..20 {
        let words: Vec<u32> = (0..500u32).map(|w| w.wrapping_mul(key as u32 + 7)).collect();
        client.insert_batch(key, &words).unwrap();
    }
    assert_eq!(registry.len(), 20);

    // Key eviction.
    assert_eq!(client.evict(EvictPolicy::Key(3)).unwrap(), 1);
    assert_eq!(client.evict(EvictPolicy::Key(3)).unwrap(), 0);
    assert_eq!(client.estimate(3).unwrap(), None);

    // Wall-clock TTL over RPC: with a System-backed clock every key was
    // touched within the last hour, so nothing ages out.
    assert_eq!(client.evict(EvictPolicy::IdleWall { max_age_secs: 3_600 }).unwrap(), 0);

    // Touch one key, then sweep everything older than the current tick:
    // keys 0..20 were touched at ticks 1..=20, key 7 again at tick 21,
    // so a max_age of 0 (cutoff = now) keeps only key 7.
    client.insert_batch(7, &[1]).unwrap();
    assert_eq!(client.evict(EvictPolicy::Idle { max_age: 0 }).unwrap(), 18);
    assert_eq!(registry.len(), 1);
    assert!(client.estimate(7).unwrap().is_some());

    // Budget eviction down to zero bytes clears the rest.
    assert_eq!(client.evict(EvictPolicy::Budget { max_memory_bytes: 0 }).unwrap(), 1);
    assert_eq!(client.stats().unwrap().keys, 0);
    server.shutdown();
}

#[test]
fn configured_budget_is_enforced_during_ingest() {
    // A registry built with max_memory_bytes holds its cap through the
    // server's periodic enforcement — no client ever sends the budget.
    let budget = 16 * 1024;
    let registry = SketchRegistry::shared(RegistryConfig {
        shards: 8,
        max_memory_bytes: Some(budget),
        ..RegistryConfig::default()
    })
    .unwrap();
    let server =
        SketchServer::start("127.0.0.1:0", registry.clone(), ServerConfig::default()).unwrap();
    let mut client = SketchClient::connect(server.local_addr()).unwrap();

    // 600 distinct keys x ~1000 distinct words each is far past 16 KiB
    // of sparse sketch heap, and far past the 256-frame enforcement
    // period — at least one server-side sweep must have fired.
    for key in 0u64..600 {
        let words: Vec<u32> =
            (0..1_000u32).map(|w| w.wrapping_add(key as u32 * 100_000)).collect();
        client.insert_batch(key, &words).unwrap();
    }
    assert!(
        registry.len() < 600,
        "server never enforced the configured budget ({} keys live)",
        registry.len()
    );
    server.shutdown();
}

#[test]
fn background_sweeper_evicts_idle_keys_on_a_timer() {
    // A manual wall clock ages keys without sleeping; the sweeper thread
    // notices on its next pass — no ingest traffic, no Evict RPC.
    let (wall, clock) = WallClock::manual(1_000);
    let registry = Arc::new(
        SketchRegistry::with_wall_clock(
            RegistryConfig { shards: 8, ..RegistryConfig::default() },
            wall,
        )
        .unwrap(),
    );
    let server = SketchServer::start(
        "127.0.0.1:0",
        registry.clone(),
        ServerConfig {
            sweeper: Some(SweeperConfig {
                interval: Duration::from_millis(20),
                idle_max_age: Some(Duration::from_secs(60)),
                ..SweeperConfig::default()
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let mut client = SketchClient::connect(server.local_addr()).unwrap();
    for key in 0u64..10 {
        client.insert_batch(key, &[key as u32, key as u32 + 1]).unwrap();
    }
    assert_eq!(registry.len(), 10);

    // Half an hour passes; one key stays hot.
    clock.store(1_000 + 1_800, std::sync::atomic::Ordering::Relaxed);
    client.insert_batch(99, &[7, 8, 9]).unwrap();

    // The sweeper (20 ms interval, 60 s TTL) must age out the idle 10
    // well within the deadline.
    let deadline = Instant::now() + Duration::from_secs(10);
    while registry.len() > 1 {
        assert!(
            Instant::now() < deadline,
            "sweeper never evicted idle keys ({} live)",
            registry.len()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(client.estimate(99).unwrap().is_some(), "hot key must survive");
    for key in 0u64..10 {
        assert_eq!(client.estimate(key).unwrap(), None, "idle key {key} must be gone");
    }
    let stats = server.stats();
    assert!(stats.sweeps > 0);
    assert!(stats.keys_swept >= 10);
    server.shutdown();
}

#[test]
fn per_opcode_rpc_counters_match_traffic() {
    let (server, _registry) = start_server(ServerConfig::default());
    let mut client = SketchClient::connect(server.local_addr()).unwrap();

    client.ping().unwrap();
    client.ping().unwrap();
    client.insert_batch(1, &[1, 2, 3]).unwrap();
    client.estimate(1).unwrap();
    client.global_estimate().unwrap();
    client.stats().unwrap();

    // The dump itself is timed *after* it renders, so scrape twice: the
    // second dump sees the first one counted.
    client.metrics_dump().unwrap();
    let text = client.metrics_dump().unwrap();
    let count = |series: &str| -> u64 {
        text.lines()
            .find_map(|l| {
                let (s, v) = l.rsplit_once(' ')?;
                if s == series { v.parse().ok() } else { None }
            })
            .unwrap_or_else(|| panic!("missing series {series}"))
    };
    assert_eq!(count("rpc_total{op=\"ping\"}"), 2);
    assert_eq!(count("rpc_total{op=\"insert_batch\"}"), 1);
    assert_eq!(count("rpc_total{op=\"estimate\"}"), 1);
    assert_eq!(count("rpc_total{op=\"global_estimate\"}"), 1);
    assert_eq!(count("rpc_total{op=\"stats\"}"), 1);
    assert_eq!(count("rpc_total{op=\"metrics_dump\"}"), 1);
    assert_eq!(count("rpc_total{op=\"evict\"}"), 0);
    // Latency histograms saw the same frames the counters did.
    assert_eq!(count("rpc_latency_ns_count{op=\"ping\"}"), 2);
    assert_eq!(count("rpc_payload_bytes_count{op=\"insert_batch\"}"), 1);
    server.shutdown();
}

#[test]
fn snapshot_rpc_unsupported_without_path() {
    let (server, _registry) = start_server(ServerConfig::default());
    let mut client = SketchClient::connect(server.local_addr()).unwrap();
    client.insert_batch(1, &[1, 2, 3]).unwrap();
    match client.snapshot() {
        Err(ClientError::Remote { code, .. }) => assert_eq!(code, ErrorCode::Unsupported),
        other => panic!("expected Unsupported, got {other:?}"),
    }
    server.shutdown();
}

#[test]
fn hostile_bytes_get_typed_errors_and_server_survives() {
    use std::io::Write;

    let (server, _registry) = start_server(ServerConfig::default());
    let addr = server.local_addr();

    // Bad magic: the server answers one typed error frame, then closes.
    {
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.write_all(b"XX\x01\x01\x00\x00\x00\x00").unwrap();
        let resp = protocol::read_response(&mut raw).unwrap();
        match resp {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::Malformed),
            other => panic!("expected error frame, got {other:?}"),
        }
    }

    // Bad protocol version.
    {
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.write_all(b"HL\x63\x01\x00\x00\x00\x00").unwrap();
        match protocol::read_response(&mut raw).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::Malformed),
            other => panic!("expected error frame, got {other:?}"),
        }
    }

    // A truncated frame followed by a hangup must not wedge or kill the
    // server: write half a header and disconnect.
    {
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.write_all(b"HL\x01").unwrap();
    }

    // Unknown opcode inside a well-formed frame: typed error, and the
    // connection stays usable (framing is still in sync).
    {
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.write_all(b"HL\x01\x7F\x00\x00\x00\x00").unwrap();
        match protocol::read_response(&mut raw).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::Malformed),
            other => panic!("expected error frame, got {other:?}"),
        }
        // Same socket, valid request afterwards.
        raw.write_all(&hll_fpga::server::Request::Ping.encode()).unwrap();
        assert_eq!(protocol::read_response(&mut raw).unwrap(), Response::Pong);
    }

    // After all that abuse a fresh client still works.
    let mut client = SketchClient::connect(addr).unwrap();
    client.insert_batch(5, &[10, 20, 30]).unwrap();
    assert!(client.estimate(5).unwrap().is_some());
    assert!(server.stats().error_frames >= 3);
    server.shutdown();
}

#[test]
fn damaged_snapshot_files_are_typed_errors() {
    let path = temp_path("damaged");
    let cfg = ServerConfig { snapshot_path: Some(path.clone()), ..ServerConfig::default() };
    let (server, _registry) = start_server(cfg);
    let mut client = SketchClient::connect(server.local_addr()).unwrap();
    client.insert_batch(1, &(0..1000u32).collect::<Vec<_>>()).unwrap();
    client.snapshot().unwrap();
    server.shutdown();

    let original = std::fs::read(&path).unwrap();

    // Flipped checksum byte in the header.
    let mut bad = original.clone();
    bad[20] ^= 0xFF;
    std::fs::write(&path, &bad).unwrap();
    assert!(matches!(
        read_snapshot(&path),
        Err(SnapshotError::ChecksumMismatch { .. })
    ));

    // Flipped body byte.
    let mut bad = original.clone();
    let last = bad.len() - 1;
    bad[last] ^= 0x10;
    std::fs::write(&path, &bad).unwrap();
    assert!(matches!(
        read_snapshot(&path),
        Err(SnapshotError::ChecksumMismatch { .. })
    ));

    // Truncated header.
    std::fs::write(&path, &original[..12]).unwrap();
    assert!(matches!(read_snapshot(&path), Err(SnapshotError::Corrupt(_))));

    // Bad magic.
    let mut bad = original.clone();
    bad[0] = b'Z';
    std::fs::write(&path, &bad).unwrap();
    assert!(matches!(read_snapshot(&path), Err(SnapshotError::BadMagic(_))));

    // Restore of a damaged file leaves the registry untouched.
    let fresh: Arc<SketchRegistry<u64>> =
        SketchRegistry::shared(RegistryConfig::default()).unwrap();
    assert!(restore_registry(&fresh, &path).is_err());
    assert!(fresh.is_empty());
    let _ = std::fs::remove_file(&path);
}
