//! Integration across the simulation substrates: FPGA engine × PCIe
//! model × network simulator × CPU baseline, checked against each other
//! and against the paper's cross-cutting claims.

use hll_fpga::cpu_baseline::{aggregate_parallel, ScalingModel};
use hll_fpga::fpga::{theoretical_throughput_bytes_per_s, ParallelHll};
use hll_fpga::hll::{HashKind, HllConfig, HllSketch};
use hll_fpga::net::{run_with_data, NicConfig};
use hll_fpga::pcie::CoProcessorModel;
use hll_fpga::stats::DistinctStream;

#[test]
fn fpga_sim_cpu_baseline_and_software_sketch_agree() {
    // Three independent implementations of the aggregation phase must
    // produce identical sketches: the software core, the cycle-level
    // FPGA engine, and the thread-parallel CPU baseline.
    let cfg = HllConfig::PAPER;
    let words: Vec<u32> = DistinctStream::new(80_000, 5).collect();

    let mut sw = HllSketch::new(cfg);
    sw.insert_batch(&words);

    let mut fpga = ParallelHll::new(cfg, 8);
    fpga.feed(&words);
    let fpga_result = fpga.finish();

    let (cpu, _) = aggregate_parallel(cfg, &words, 4);

    assert_eq!(fpga_result.sketch, sw);
    assert_eq!(cpu, sw);
}

#[test]
fn nic_and_coprocessor_runs_share_functional_result() {
    let words: Vec<u32> = DistinctStream::new(40_000, 9).collect();
    let nic = run_with_data(&NicConfig::paper(8), &words);
    let nic_sketch = &nic.hll.as_ref().unwrap().sketch;

    let mut sw = HllSketch::new(HllConfig::PAPER);
    sw.insert_batch(&words);
    assert_eq!(nic_sketch, &sw);
}

#[test]
fn paper_headline_claims_cross_model() {
    // Claim 2: multi-pipelined FPGA ≈ 1.8× the 16-core/32-thread CPU
    // (64-bit hash), with the FPGA PCIe-bound at 12.48 GB/s.
    let model = ScalingModel::paper_xeon();
    let cpu64 = model.rate(HashKind::H64, 32);
    let fpga = CoProcessorModel::default()
        .run(&HllConfig::PAPER, 10, 1 << 30)
        .throughput_bytes_per_s();
    let ratio = fpga / cpu64;
    assert!((1.6..2.1).contains(&ratio), "FPGA/CPU64 = {ratio}");

    // Claim 1: single pipeline ≈ 2× a single CPU thread (32-bit hash).
    let r1 = theoretical_throughput_bytes_per_s(1) / model.rate(HashKind::H32, 1);
    assert!((1.8..2.2).contains(&r1), "pipeline/thread = {r1}");

    // Section VII: NIC ≈ 35% above the 16-core CPU.
    let nic = hll_fpga::net::run_timing(&NicConfig::paper(16), 32 << 20);
    let nic_ratio = nic.throughput_bytes_per_s() / cpu64;
    assert!((1.15..1.6).contains(&nic_ratio), "NIC/CPU = {nic_ratio}");
}

#[test]
fn fig4a_and_table4_saturation_points_differ_as_in_paper() {
    // PCIe deployment saturates at 10 pipelines; the NIC needs 16 to
    // absorb bursts — the paper calls out this asymmetry explicitly.
    let pcie = CoProcessorModel::default();
    assert_eq!(pcie.saturation_pipelines(), 10);

    let t8 = hll_fpga::net::run_timing(&NicConfig::paper(8), 8 << 20);
    let t16 = hll_fpga::net::run_timing(&NicConfig::paper(16), 8 << 20);
    assert!(
        t16.throughput_bytes_per_s() >= t8.throughput_bytes_per_s(),
        "NIC gains from 8→16 pipelines"
    );
}

#[test]
fn drain_time_invariant_across_deployments() {
    // 203 µs computation phase, regardless of data size or deployment.
    let words_small: Vec<u32> = DistinctStream::new(1_000, 1).collect();
    let words_large: Vec<u32> = DistinctStream::new(100_000, 2).collect();
    let mut a = ParallelHll::new(HllConfig::PAPER, 4);
    a.feed(&words_small);
    let ra = a.finish();
    let mut b = ParallelHll::new(HllConfig::PAPER, 16);
    b.feed(&words_large);
    let rb = b.finish();
    assert_eq!(ra.drain_cycles, rb.drain_cycles);
    let secs = ra.clock.cycles_to_seconds(ra.drain_cycles);
    assert!((secs - 203e-6).abs() < 2e-6, "{secs}");
}
