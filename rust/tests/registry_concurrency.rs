//! Differential tests for the concurrent sketch subsystem: N-thread
//! ingest vs the sequential reference, and registry sparse→dense upgrade
//! behaviour — fuzzed with `proptest_lite`.

use std::sync::Arc;

use hll_fpga::coordinator::{run_keyed_stream, CoordinatorConfig};
use hll_fpga::hll::{AdaptiveSketch, ConcurrentHllSketch, HashKind, HllConfig, HllSketch};
use hll_fpga::proptest_lite::Runner;
use hll_fpga::registry::{RegistryConfig, SketchRegistry};

#[test]
fn concurrent_ingest_is_register_identical_to_sequential() {
    // The core tentpole property: for any stream, any thread count and
    // any slicing, the shared CAS-max register file equals the one
    // sequential insert_batch produces. Register updates are commutative
    // monotone maxes, so this is exact, not statistical.
    Runner::new("concurrent_vs_sequential").cases(12).run(|g| {
        let n = g.usize_in(0..=20_000);
        let words: Vec<u32> = (0..n).map(|_| g.u32()).collect();
        let threads = g.usize_in(1..=8);
        let p = *g.choose(&[12u8, 14, 16]);
        let h = if g.bool() { HashKind::H32 } else { HashKind::H64 };
        let cfg = HllConfig::new(p, h).unwrap();

        let mut sequential = HllSketch::new(cfg);
        sequential.insert_batch(&words);

        let shared = ConcurrentHllSketch::new(cfg);
        let chunk = words.len().div_ceil(threads).max(1);
        std::thread::scope(|scope| {
            for slice in words.chunks(chunk) {
                let shared = &shared;
                scope.spawn(move || shared.insert_batch(slice));
            }
        });
        assert_eq!(
            shared.snapshot(),
            sequential,
            "p={p} h={h:?} threads={threads} n={n}"
        );
    });
}

#[test]
fn registry_upgrade_preserves_estimates() {
    // Tier promotions (sparse→packed, and packed→dense if it ever fires)
    // must not move a key's estimate: the Ertl estimate is a pure
    // function of the register histogram, which every tier preserves
    // exactly, so the handoffs are bit-exact.
    Runner::new("upgrade_preserves_estimate").cases(6).run(|g| {
        let cfg = HllConfig::PAPER;
        let registry: SketchRegistry<u64> = SketchRegistry::new(RegistryConfig {
            hll: cfg,
            shards: 8,
            track_global: false,
            ..RegistryConfig::default()
        })
        .unwrap();
        // Enough distinct words to push the key through the upgrade.
        let n = g.usize_in(40_000..=80_000);
        let words: Vec<u32> = (0..n).map(|_| g.u32()).collect();
        let key = g.u64();
        // Track the estimate trajectory around the upgrade boundary.
        let mut reference = AdaptiveSketch::new(cfg);
        let mut was_sparse = true;
        for chunk in words.chunks(1024) {
            registry.ingest(key, chunk);
            for &w in chunk {
                reference.insert_u32(w);
            }
            let got = registry.estimate(&key).unwrap();
            let want = reference.estimate();
            assert_eq!(got, want, "estimate diverged at {} words", reference.memory_bytes());
            if was_sparse && !reference.is_sparse() {
                was_sparse = false;
            }
        }
        assert!(!reference.is_sparse(), "stream too small to force the upgrade");
        let stats = registry.stats();
        // Random streams in this size range compress into the packed
        // tier (ranks concentrate in a 7-value window) and stay there.
        assert_eq!(stats.packed_keys(), 1);
        assert_eq!(stats.dense_keys(), 0);
        // The upgraded sketch equals a dense sketch built directly.
        let mut dense = HllSketch::new(cfg);
        dense.insert_batch(&words);
        assert_eq!(registry.evict(&key).unwrap(), dense);
    });
}

#[test]
fn keyed_coordinator_any_shape_matches_references() {
    Runner::new("keyed_coordinator_shapes").cases(8).run(|g| {
        let n = g.usize_in(0..=8_000);
        let key_domain = g.u64_in(1..=300);
        let pairs: Vec<(u64, u32)> =
            (0..n).map(|_| (g.u64_in(0..=key_domain - 1), g.u32())).collect();
        let cfg = CoordinatorConfig {
            pipelines: g.usize_in(1..=6),
            batch_size: g.usize_in(1..=2048),
            queue_depth: g.usize_in(1..=4),
            ..CoordinatorConfig::default()
        };
        let registry = SketchRegistry::shared(RegistryConfig {
            shards: 16,
            ..RegistryConfig::default()
        })
        .unwrap();
        let summary = run_keyed_stream(&cfg, registry.clone(), &pairs).unwrap();
        assert_eq!(summary.metrics.words_in, n as u64);

        let mut whole = HllSketch::new(HllConfig::PAPER);
        for &(_, w) in &pairs {
            whole.insert_u32(w);
        }
        assert_eq!(registry.merge_all(), whole);
        let distinct_keys: std::collections::HashSet<u64> =
            pairs.iter().map(|&(k, _)| k).collect();
        assert_eq!(registry.len(), distinct_keys.len());
    });
}

#[test]
fn concurrent_registry_ingest_matches_single_threaded() {
    // Same pair multiset, different thread interleavings → identical
    // registry contents (per-key and union).
    let registry_a = SketchRegistry::shared(RegistryConfig::default()).unwrap();
    let registry_b = SketchRegistry::shared(RegistryConfig::default()).unwrap();
    let mut gen = hll_fpga::net::KeyedFlowGen::new(500, 1.07, 77);
    let pairs = gen.batch(60_000);

    registry_a.ingest_pairs(&pairs);

    let b: Arc<SketchRegistry<u64>> = registry_b.clone();
    std::thread::scope(|scope| {
        for slice in pairs.chunks(pairs.len() / 6) {
            let b = b.clone();
            scope.spawn(move || b.ingest_pairs(slice));
        }
    });

    assert_eq!(registry_a.len(), registry_b.len());
    assert_eq!(registry_a.merge_all(), registry_b.merge_all());
    assert_eq!(registry_a.global_estimate(), registry_b.global_estimate());
    for (key, est) in registry_a.estimates() {
        assert_eq!(registry_b.estimate(&key), Some(est), "key {key}");
    }
}
