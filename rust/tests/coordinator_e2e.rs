//! Integration: the streaming coordinator over both engines, checked
//! against serial ground truth and across engines.

use hll_fpga::coordinator::{run_serial, run_stream, CoordinatorConfig};
use hll_fpga::hll::{HashKind, HllConfig};
use hll_fpga::runtime::{EngineKind, Manifest, XlaService};
use hll_fpga::stats::DistinctStream;
use hll_fpga::util::Xoshiro256StarStar;

fn artifacts_ready() -> bool {
    Manifest::default_dir().join("manifest.tsv").exists()
}

#[test]
fn native_coordinator_full_stack() {
    let cfg = CoordinatorConfig {
        pipelines: 6,
        batch_size: 4096,
        queue_depth: 2,
        ..CoordinatorConfig::default()
    };
    let n = 300_000u64;
    let words: Vec<u32> = DistinctStream::new(n, 17).collect();
    let summary = run_stream(cfg, None, &words).unwrap();
    let (serial, _) = run_serial(&cfg, &words);
    assert_eq!(summary.sketch, serial);
    let err = (summary.estimate.estimate - n as f64).abs() / n as f64;
    assert!(err < 0.02, "err {err}");
}

#[test]
fn xla_coordinator_matches_native() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let service = XlaService::start().unwrap();
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xE2E);
    let words: Vec<u32> = (0..50_000).map(|_| rng.next_u32()).collect();

    let base = CoordinatorConfig {
        pipelines: 3,
        batch_size: 1024,
        ..CoordinatorConfig::default()
    };
    let native = run_stream(
        CoordinatorConfig { engine: EngineKind::Native, ..base },
        None,
        &words,
    )
    .unwrap();
    let xla = run_stream(
        CoordinatorConfig { engine: EngineKind::Xla, ..base },
        Some(service.handle()),
        &words,
    )
    .unwrap();
    assert_eq!(native.sketch.registers(), xla.sketch.registers());
    assert_eq!(native.estimate.zero_registers, xla.estimate.zero_registers);
    let drift = (native.estimate.estimate - xla.estimate.estimate).abs()
        / native.estimate.estimate.max(1.0);
    assert!(drift < 1e-9, "estimate drift {drift}");
}

#[test]
fn xla_coordinator_variant_config() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let service = XlaService::start().unwrap();
    let hll = HllConfig::new(14, HashKind::H64).unwrap();
    let base = CoordinatorConfig {
        hll,
        pipelines: 2,
        batch_size: 8192,
        ..CoordinatorConfig::default()
    };
    let words: Vec<u32> = DistinctStream::new(30_000, 3).collect();
    let native = run_stream(
        CoordinatorConfig { engine: EngineKind::Native, ..base },
        None,
        &words,
    )
    .unwrap();
    let xla = run_stream(
        CoordinatorConfig { engine: EngineKind::Xla, ..base },
        Some(service.handle()),
        &words,
    )
    .unwrap();
    assert_eq!(native.sketch, xla.sketch);
}

#[test]
fn many_small_feeds_with_duplicates() {
    let cfg = CoordinatorConfig {
        pipelines: 4,
        batch_size: 100,
        ..CoordinatorConfig::default()
    };
    // 10k distinct values, each fed 5 times in shuffled chunks.
    let mut words: Vec<u32> = Vec::new();
    for rep in 0..5u64 {
        let mut vs: Vec<u32> = DistinctStream::new(10_000, 77).collect();
        let mut rng = Xoshiro256StarStar::seed_from_u64(rep);
        rng.shuffle(&mut vs);
        words.extend(vs);
    }
    let mut c = hll_fpga::coordinator::Coordinator::start(cfg, None).unwrap();
    for chunk in words.chunks(777) {
        c.feed(chunk);
    }
    let summary = c.finish().unwrap();
    let err = (summary.estimate.estimate - 10_000.0).abs() / 10_000.0;
    assert!(err < 0.05, "estimate {} vs 10k", summary.estimate.estimate);
    assert_eq!(summary.metrics.words_in, 50_000);
}
