//! Cross-language estimator parity: rust `EstimatorKind::Legacy` vs the
//! python oracle (`python/compile/kernels/ref.py::hll_estimate`).
//!
//! Both sides synthesize identical register files from a shared
//! splitmix64 generator and check the same committed golden estimates —
//! `python/tests/test_estimator_parity.py` is the twin. The goldens
//! cover all three legacy branches (LinearCounting, raw, 32-bit
//! large-range correction) plus a small-m alpha-table config, so any
//! drift between the serving-layer legacy path and the compiled Pallas
//! kernel's computation fails on both sides of the language fence.

use hll_fpga::hll::{EstimatorKind, HashKind, HllConfig, HllSketch};

/// One splitmix64 step; mirrors `_splitmix` in the python twin.
fn splitmix(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic register file: per register draw (occupied?, rank).
/// Mirrored line-for-line in the python twin.
fn synth_registers(cfg: HllConfig, seed: u64, occ_per_mille: u64, rank_offset: u32) -> Vec<u8> {
    let max_rank = cfg.max_rank() as u32;
    let mut state = seed;
    (0..cfg.m())
        .map(|_| {
            let x = splitmix(&mut state);
            let y = splitmix(&mut state);
            if x % 1000 < occ_per_mille {
                (rank_offset + 1 + y.trailing_zeros()).min(max_rank) as u8
            } else {
                0
            }
        })
        .collect()
}

/// (p, h_bits, seed, occ_per_mille, rank_offset, expected_estimate) —
/// the `expected` column is the python oracle's output, committed in
/// both test files.
const GOLDEN: &[(u8, u8, u64, u64, u32, f64)] = &[
    (12, 64, 0xA5A5, 1000, 0, 8897.226585133449),   // raw branch
    (12, 64, 0x1234, 120, 0, 566.4193796524122),    // LinearCounting
    (14, 64, 0xBEEF, 500, 0, 11618.608482912226),   // LinearCounting
    (12, 32, 0xCAFE, 1000, 14, 146845837.76433104), // 32-bit large-range
    (16, 64, 0x42, 1000, 0, 141701.6198943316),     // raw, paper config
    (4, 32, 0x7, 1000, 0, 32.622579881656804),      // raw, alpha table m=16
];

#[test]
fn legacy_estimator_matches_python_oracle() {
    for &(p, h_bits, seed, occ, off, expected) in GOLDEN {
        let hash = if h_bits == 32 { HashKind::H32 } else { HashKind::H64 };
        let cfg = HllConfig::new(p, hash).unwrap();
        let regs = synth_registers(cfg, seed, occ, off);
        let sketch = HllSketch::from_registers(cfg, regs).unwrap();
        let est = sketch.estimate_with(EstimatorKind::Legacy);
        let rel = (est - expected).abs() / expected;
        assert!(
            rel < 1e-9,
            "p={p} H{h_bits} seed={seed:#x}: legacy {est} vs oracle {expected} (rel {rel:.2e})"
        );
    }
}

#[test]
fn ertl_estimator_is_sane_on_golden_registers() {
    // Ertl intentionally computes a *different* (better) function — no
    // parity claim, but it must stay finite, positive and in the same
    // regime on every golden register file, including the saturated
    // 32-bit one where the legacy path needs its range correction.
    for &(p, h_bits, seed, occ, off, legacy) in GOLDEN {
        let hash = if h_bits == 32 { HashKind::H32 } else { HashKind::H64 };
        let cfg = HllConfig::new(p, hash).unwrap();
        let sketch =
            HllSketch::from_registers(cfg, synth_registers(cfg, seed, occ, off)).unwrap();
        let est = sketch.estimate_with(EstimatorKind::Ertl);
        assert!(est.is_finite() && est > 0.0, "p={p} H{h_bits}: ertl {est}");
        assert!(
            est > legacy * 0.3 && est < legacy * 3.0,
            "p={p} H{h_bits}: ertl {est} not in the same regime as legacy {legacy}"
        );
    }
}
