//! Failure injection: the runtime and coordinator must surface errors,
//! not panic or silently corrupt state.

use std::path::PathBuf;

use hll_fpga::hll::{HashKind, HllConfig};
use hll_fpga::runtime::{Manifest, ManifestError, XlaService};

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("hll_fail_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

const HEADER: &str = "name\tfile\tkind\tp\th_bits\tbatch\tm\toutputs\n";

#[test]
fn missing_artifacts_dir_is_clean_error() {
    let d = tmpdir("missing").join("definitely_absent");
    match Manifest::load(&d) {
        Err(ManifestError::NotFound(p)) => assert!(p.ends_with("manifest.tsv")),
        other => panic!("expected NotFound, got {other:?}"),
    }
}

#[test]
fn corrupt_hlo_text_fails_at_compile_not_panic() {
    // A manifest that points at garbage HLO: service start succeeds
    // (lazy compile), the first use must return Err.
    let d = tmpdir("corrupt");
    std::fs::write(
        d.join("manifest.tsv"),
        format!("{HEADER}agg\tbad.hlo.txt\taggregate\t16\t64\t1024\t65536\tregs\n"),
    )
    .unwrap();
    std::fs::write(d.join("bad.hlo.txt"), "HloModule utterly { broken }").unwrap();
    let manifest = Manifest::load(&d).unwrap();
    let svc = XlaService::start_with(manifest).expect("service starts lazily");
    let res = svc.handle().aggregate(
        16,
        HashKind::H64,
        vec![vec![0i32; 1024]],
        vec![0i32; 65536],
    );
    assert!(res.is_err(), "garbage HLO must error, got {res:?}");
}

#[test]
fn artifact_for_unknown_config_is_reported() {
    let d = tmpdir("nocfg");
    std::fs::write(
        d.join("manifest.tsv"),
        format!("{HEADER}agg\ta.hlo.txt\taggregate\t16\t64\t1024\t65536\tregs\n"),
    )
    .unwrap();
    std::fs::write(d.join("a.hlo.txt"), "HloModule x\n").unwrap();
    let manifest = Manifest::load(&d).unwrap();
    let svc = XlaService::start_with(manifest).unwrap();
    // p=10 has no artifact: shape lookup must fail cleanly.
    let err = svc.handle().aggregate_batch_shape(10, HashKind::H64, 1024);
    assert!(err.is_err());
}

#[test]
fn wrong_register_count_rejected_by_service() {
    if !Manifest::default_dir().join("manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let svc = XlaService::start().unwrap();
    // 10 registers for a p=16 artifact: shape error, no crash.
    let res = svc
        .handle()
        .aggregate(16, HashKind::H64, vec![vec![0i32; 1024]], vec![0i32; 10]);
    assert!(res.is_err());
}

#[test]
fn sketch_invariants_hold_after_failed_merge() {
    // A rejected merge must leave the destination untouched.
    let mut a = hll_fpga::hll::HllSketch::new(HllConfig::PAPER);
    for v in 0..1000u32 {
        a.insert_u32(v);
    }
    let before = a.clone();
    let b = hll_fpga::hll::HllSketch::new(HllConfig::new(14, HashKind::H64).unwrap());
    assert!(a.merge(&b).is_err());
    assert_eq!(a, before, "failed merge must not mutate");
}

#[test]
fn manifest_with_duplicate_columns_still_parses_first() {
    // Robustness to future manifest evolution: extra columns ignored.
    let d = tmpdir("extra_cols");
    std::fs::write(
        d.join("manifest.tsv"),
        "name\tfile\tkind\tp\th_bits\tbatch\tm\toutputs\tnew_column\n\
         agg\ta.hlo.txt\taggregate\t16\t64\t1024\t65536\tregs\textra\n",
    )
    .unwrap();
    std::fs::write(d.join("a.hlo.txt"), "HloModule x\n").unwrap();
    let m = Manifest::load(&d).unwrap();
    assert_eq!(m.entries().len(), 1);
}
