//! Observability end-to-end: a live primary + follower pair scraped
//! over the wire (`MetricsDump` RPC). Verifies the exposition carries
//! per-opcode latency quantiles, event-loop tick profiles, per-tier
//! registry gauges, and replication-lag gauges on the primary plus
//! `replica_*` series (including seal-to-apply lag) on the follower —
//! and pins the stats-drift fixes (MergeSketch feeds the ingest
//! counters; hostile frames count exactly once).
//!
//! The tracing half exercises the flight recorder over real sockets:
//! a traced `InsertBatch` on a replicating primary must surface — via
//! `TraceDump` on the primary *and* the follower — one trace id whose
//! spans walk client-send → decode → dispatch → shard-ingest → seal →
//! follower-apply with monotonic begin timestamps; old peers that
//! predate `TRACE_DUMP` answer the negotiation probe with a typed
//! error and keep interoperating untraced; v3 subscribers never see
//! trace entries while v4 subscribers get the writer's id; and a
//! slow-request anomaly freezes a black-box snapshot containing the
//! offending span.

use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use hll_fpga::hll::HllSketch;
use hll_fpga::net::KeyedFlowGen;
use hll_fpga::obs::registry::parse_line;
use hll_fpga::obs::{recorder, EventKind, Stage, TraceEvent, EXPOSITION_HEADER};
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::replica::{FollowerConfig, FollowerServer, ReplicationConfig};
use hll_fpga::server::{
    protocol, ErrorCode, Response, ServerConfig, SketchClient, SketchServer,
};

/// Exact-series lookup: the value of the line whose full series key
/// (name + rendered labels) equals `series`.
fn metric(text: &str, series: &str) -> Option<f64> {
    text.lines().find_map(|l| {
        let (s, v) = l.rsplit_once(' ')?;
        if s == series {
            v.parse().ok()
        } else {
            None
        }
    })
}

/// Header + every line machine-parseable.
fn assert_well_formed(text: &str) {
    let mut lines = text.lines();
    assert_eq!(lines.next(), Some(EXPOSITION_HEADER), "exposition must lead with the header");
    for line in lines {
        assert!(parse_line(line).is_some(), "unparseable exposition line {line:?}");
    }
}

#[test]
fn metrics_dump_covers_primary_and_follower() {
    let cfg = RegistryConfig { shards: 16, ..RegistryConfig::default() };
    let primary_reg = SketchRegistry::shared(cfg).unwrap();
    let primary = SketchServer::start(
        "127.0.0.1:0",
        primary_reg.clone(),
        ServerConfig {
            replication: Some(ReplicationConfig {
                capture_interval: Duration::from_millis(5),
                ..ReplicationConfig::default()
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let log = primary.replication_log().unwrap();
    let follower_reg = SketchRegistry::shared(cfg).unwrap();
    let follower = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg,
        FollowerConfig::default(),
    )
    .unwrap();

    // Mixed traffic: a zipf-keyed stream, one heavy tenant that
    // promotes past sparse, a sketch merge, and reads.
    let mut client = SketchClient::connect(primary.local_addr()).unwrap();
    client.ping().unwrap();
    let batches = KeyedFlowGen::new(100, 1.07, 0x0B5).batched(20_000, usize::MAX);
    client.pipeline_insert(&batches).unwrap();
    let heavy: Vec<u32> = (0..60_000).collect();
    for chunk in heavy.chunks(8_192) {
        client.insert_batch(9_999, chunk).unwrap();
    }
    let mut local = HllSketch::paper();
    for v in 0..2_000u32 {
        local.insert_u32(v.wrapping_mul(2_654_435_761));
    }
    client.merge_sketch(77, &local).unwrap();
    client.estimate(9_999).unwrap();
    client.global_estimate().unwrap();
    let stats = client.stats().unwrap();

    // Let replication drain so the follower-side series are live.
    let deadline = Instant::now() + Duration::from_secs(60);
    while primary_reg.dirty_keys() > 0 || follower.cursor() < log.latest_seq() {
        assert!(Instant::now() < deadline, "replication never drained");
        std::thread::sleep(Duration::from_millis(2));
    }

    // --- Primary scrape, over the wire.
    let text = client.metrics_dump().unwrap();
    assert_well_formed(&text);

    // Per-opcode latency quantiles and counters.
    for op in ["ping", "insert_batch", "merge_sketch", "estimate", "stats"] {
        let q99 = metric(&text, &format!("rpc_latency_ns{{op=\"{op}\",quantile=\"0.99\"}}"))
            .unwrap_or_else(|| panic!("missing p99 latency for {op}"));
        assert!(q99 > 0.0, "p99 latency for {op} must be nonzero");
        let total = metric(&text, &format!("rpc_total{{op=\"{op}\"}}")).unwrap();
        assert!(total >= 1.0, "rpc_total for {op} must count the traffic");
    }
    let frames = metric(&text, "rpc_latency_ns_count{op=\"insert_batch\"}").unwrap();
    assert!(frames as u64 >= batches.len() as u64, "every insert frame must be timed");
    assert!(
        metric(&text, "rpc_payload_bytes{op=\"insert_batch\",quantile=\"0.5\"}").unwrap() > 0.0
    );

    // Event-loop tick profile: loop 0 polled and did work.
    assert!(metric(&text, "loop_poll_wait_ns_count{loop=\"0\"}").unwrap() > 0.0);
    assert!(metric(&text, "loop_work_ns{loop=\"0\",quantile=\"0.99\"}").unwrap() > 0.0);
    assert!(metric(&text, "loop_ready_events_count{loop=\"0\"}").unwrap() > 0.0);
    let sat = metric(&text, "loop_saturation_permille{loop=\"0\"}").unwrap();
    assert!((0.0..=1_000.0).contains(&sat), "saturation must be a permille ({sat})");

    // Per-tier registry gauges agree with the Stats RPC.
    assert_eq!(metric(&text, "registry_keys").unwrap() as u64, stats.keys);
    let tiers: f64 = ["sparse", "packed", "dense"]
        .iter()
        .map(|t| metric(&text, &format!("registry_tier_keys{{tier=\"{t}\"}}")).unwrap())
        .sum();
    assert_eq!(tiers as u64, stats.keys, "tier gauges must partition the key population");
    assert!(metric(&text, "registry_memory_bytes").unwrap() > 0.0);
    assert_eq!(metric(&text, "registry_words_total").unwrap() as u64, stats.words);

    // Replication gauges: the log sealed batches and the follower's
    // acks pulled the lag down to (or near) zero.
    assert!(metric(&text, "replication_latest_seq").unwrap() >= 1.0);
    assert!(metric(&text, "replication_lag_entries").is_some());
    assert!(metric(&text, "replication_lag_bytes").is_some());
    assert!(metric(&text, "server_delta_batches_sent_total").unwrap() >= 1.0);

    // --- Follower scrape, also over the wire (it serves reads).
    let mut fclient = SketchClient::connect(follower.local_addr()).unwrap();
    let ftext = fclient.metrics_dump().unwrap();
    assert_well_formed(&ftext);
    assert!(metric(&ftext, "replica_cursor").unwrap() >= 1.0);
    assert!(metric(&ftext, "replica_batches_applied").unwrap() >= 1.0);
    assert!(metric(&ftext, "replica_entries_applied").unwrap() >= 1.0);
    assert_eq!(metric(&ftext, "replica_halted").unwrap(), 0.0);
    let lag_samples = metric(&ftext, "replica_seal_to_apply_ns_count").unwrap();
    assert!(lag_samples >= 1.0, "seal-to-apply lag must have samples");
    assert!(
        metric(&ftext, "replica_seal_to_apply_ns{quantile=\"0.99\"}").unwrap() > 0.0,
        "p99 seal-to-apply lag must be nonzero"
    );

    follower.shutdown();
    primary.shutdown();
}

#[test]
fn merge_sketch_feeds_the_ingest_counters() {
    let registry = SketchRegistry::shared(RegistryConfig {
        shards: 16,
        ..RegistryConfig::default()
    })
    .unwrap();
    let server =
        SketchServer::start("127.0.0.1:0", registry, ServerConfig::default()).unwrap();
    let mut client = SketchClient::connect(server.local_addr()).unwrap();

    let mut local = HllSketch::paper();
    for v in 0..5_000u32 {
        local.insert_u32(v.wrapping_mul(2_654_435_761));
    }
    client.merge_sketch(7, &local).unwrap();
    let s = server.stats();
    assert_eq!(s.sketches_merged, 1);
    // The merge credits the sketch's estimated cardinality as a words
    // floor — before the fix this path left words_ingested at zero.
    assert!(
        s.words_ingested >= 4_000,
        "merge must credit ingested words (got {})",
        s.words_ingested
    );

    // A failed merge (truncated bytes) counts an error, not a merge.
    assert!(client.merge_sketch_bytes(8, &[1, 2, 3]).is_err());
    let s = server.stats();
    assert_eq!(s.sketches_merged, 1);
    assert_eq!(s.error_frames, 1);

    // The same cells back the exposition — no double accounting.
    let text = client.metrics_dump().unwrap();
    assert_eq!(metric(&text, "server_sketches_merged_total").unwrap(), 1.0);
    assert_eq!(metric(&text, "server_error_frames_total").unwrap(), 1.0);
    server.shutdown();
}

#[test]
fn hostile_frames_count_exactly_once() {
    let registry = SketchRegistry::shared(RegistryConfig {
        shards: 16,
        ..RegistryConfig::default()
    })
    .unwrap();
    let server =
        SketchServer::start("127.0.0.1:0", registry, ServerConfig::default()).unwrap();
    let addr = server.local_addr();

    // One bad-magic frame → exactly one typed error frame → exactly one
    // tick of the centralized error counter.
    {
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.write_all(b"XX\x01\x01\x00\x00\x00\x00").unwrap();
        match protocol::read_response(&mut raw).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::Malformed),
            other => panic!("expected error frame, got {other:?}"),
        }
    }
    assert_eq!(server.stats().error_frames, 1, "one hostile frame, one error count");
    let text = server.metrics_text();
    assert_eq!(metric(&text, "server_error_frames_total").unwrap(), 1.0);
    server.shutdown();
}

/// The PR's end-to-end acceptance path: one traced `InsertBatch` on a
/// replicating primary must yield — via `TraceDump` on the primary
/// *and* on the follower (both servers share this process's recorder)
/// — a single trace id whose spans cover client-send → decode →
/// dispatch → shard-ingest → seal on the primary and apply on the
/// follower, with monotonic begin timestamps.
#[test]
fn traced_insert_spans_decode_to_follower_apply() {
    let cfg = RegistryConfig { shards: 16, ..RegistryConfig::default() };
    let primary_reg = SketchRegistry::shared(cfg).unwrap();
    let primary = SketchServer::start(
        "127.0.0.1:0",
        primary_reg.clone(),
        ServerConfig {
            replication: Some(ReplicationConfig {
                capture_interval: Duration::from_millis(5),
                ..ReplicationConfig::default()
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let log = primary.replication_log().unwrap();
    let follower_reg = SketchRegistry::shared(cfg).unwrap();
    let follower = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg,
        FollowerConfig::default(),
    )
    .unwrap();

    let mut client = SketchClient::connect(primary.local_addr()).unwrap();
    assert!(client.negotiate_tracing().unwrap(), "live server must accept tracing");
    assert!(client.tracing_enabled());
    let (words, trace_id) = client.insert_batch_traced(42, &[1, 2, 3, 4]).unwrap();
    assert_eq!(words, 4);
    assert_ne!(trace_id, 0, "negotiated connection must stamp a trace id");

    let deadline = Instant::now() + Duration::from_secs(60);
    while primary_reg.dirty_keys() > 0 || follower.cursor() < log.latest_seq() {
        assert!(Instant::now() < deadline, "replication never drained");
        std::thread::sleep(Duration::from_millis(2));
    }

    // The trace walks these stages in causal order; begins must be
    // monotonic (a later stage never begins before an earlier one).
    let chain = [
        Stage::ClientSend,
        Stage::Decode,
        Stage::Dispatch,
        Stage::ShardIngest,
        Stage::Seal,
        Stage::FollowerApply,
    ];
    let mut fclient = SketchClient::connect(follower.local_addr()).unwrap();
    for (who, events) in
        [("primary", client.trace_dump().unwrap()), ("follower", fclient.trace_dump().unwrap())]
    {
        let mine: Vec<&TraceEvent> =
            events.iter().filter(|e| e.trace_id == trace_id).collect();
        let mut begin_ns = Vec::new();
        for stage in chain {
            let begins: Vec<&&TraceEvent> = mine
                .iter()
                .filter(|e| e.stage == stage as u8 && e.kind == EventKind::Begin as u8)
                .collect();
            assert_eq!(
                begins.len(),
                1,
                "{who} dump: expected exactly one {} begin for trace {trace_id:x}",
                stage.name()
            );
            begin_ns.push(begins[0].ns);
            assert!(
                mine.iter().any(|e| e.stage == stage as u8 && e.kind == EventKind::End as u8),
                "{who} dump: missing {} end",
                stage.name()
            );
        }
        for (w, pair) in begin_ns.windows(2).enumerate() {
            assert!(
                pair[0] <= pair[1],
                "{who} dump: {} began after {} ({begin_ns:?})",
                chain[w].name(),
                chain[w + 1].name()
            );
        }
    }

    // Span timings surfaced as stage_latency_ns series: request stages
    // on the primary, the apply stage on the follower's own registry.
    let text = client.metrics_dump().unwrap();
    assert_well_formed(&text);
    for stage in ["decode", "dispatch", "shard_ingest"] {
        let n = metric(&text, &format!("stage_latency_ns_count{{stage=\"{stage}\"}}"))
            .unwrap_or_else(|| panic!("missing stage_latency_ns for {stage}"));
        assert!(n >= 1.0, "stage {stage} must have timed samples");
    }
    let ftext = fclient.metrics_dump().unwrap();
    assert!(
        metric(&ftext, "stage_latency_ns_count{stage=\"follower_apply\"}").unwrap() >= 1.0,
        "follower must time its apply stage"
    );

    // The client-side renderer names stages and carries the trace id.
    let rendered = client.trace_dump_text().unwrap();
    assert!(rendered.contains("shard_ingest"), "renderer must name stages:\n{rendered}");
    assert!(
        rendered.contains(&format!("{trace_id:016x}")),
        "renderer must show the trace id"
    );

    follower.shutdown();
    primary.shutdown();
}

/// Interop with peers that predate tracing: the negotiation probe gets
/// a typed error back (the old server's unknown-opcode path), the
/// connection keeps serving, and ingest frames stay in the old exact
/// length — no trailing trace context.
#[test]
fn old_peer_answers_trace_probe_with_typed_error_and_stays_untraced() {
    use std::io::Read;

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // A minimal stand-in for a pre-tracing server: answer the unknown
    // TRACE_DUMP opcode with a typed error (connection stays open, as
    // the real old server's payload-decode error path does), then
    // serve one plain insert — asserting its payload carries no
    // 16-byte trailer, which the old strict decoder would reject.
    let fake = std::thread::spawn(move || {
        let (mut sock, _) = listener.accept().unwrap();
        let (opcode, payload) = protocol::read_frame(&mut sock).unwrap();
        assert_eq!(opcode, protocol::opcodes::TRACE_DUMP);
        assert!(payload.is_empty());
        sock.write_all(
            &Response::Error {
                code: ErrorCode::Malformed,
                message: "unknown opcode 0x0c".into(),
            }
            .encode(),
        )
        .unwrap();
        let (opcode, payload) = protocol::read_frame(&mut sock).unwrap();
        assert_eq!(opcode, protocol::opcodes::INSERT_BATCH);
        assert_eq!(
            payload.len(),
            12 + 3 * 4,
            "untraced frame must be the exact legacy length"
        );
        sock.write_all(&Response::Ingested { words: 3 }.encode()).unwrap();
        // Drain until the client hangs up (guards against stray bytes).
        let mut rest = Vec::new();
        sock.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "client wrote unexpected trailing bytes: {rest:?}");
    });

    let mut client = SketchClient::connect(addr).unwrap();
    assert!(!client.negotiate_tracing().unwrap(), "old peer must negotiate to untraced");
    assert!(!client.tracing_enabled());
    assert_eq!(client.insert_batch(7, &[1, 2, 3]).unwrap(), 3);
    drop(client);
    fake.join().unwrap();
}

/// Wire-version gate for the replication trace entry: a v3 subscriber
/// must never see `TRACE_IDS` entries (its decoder predates kind 5),
/// while a v4 subscriber receives the writer's trace id alongside the
/// sealed entries.
#[test]
fn v3_subscriber_sees_no_trace_entries_while_v4_gets_writer_ids() {
    use hll_fpga::server::protocol::Request;

    let cfg = RegistryConfig { shards: 16, ..RegistryConfig::default() };
    let primary_reg = SketchRegistry::shared(cfg).unwrap();
    let primary = SketchServer::start(
        "127.0.0.1:0",
        primary_reg.clone(),
        ServerConfig {
            replication: Some(ReplicationConfig {
                capture_interval: Duration::from_millis(5),
                ..ReplicationConfig::default()
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let log = primary.replication_log().unwrap();

    // Seed one batch so both subscribers can position at the head.
    let mut producer = SketchClient::connect(primary.local_addr()).unwrap();
    producer.insert_batch(1, &[10, 20]).unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    while primary_reg.dirty_keys() > 0 || log.latest_seq() == 0 {
        assert!(Instant::now() < deadline, "first capture never sealed");
        std::thread::sleep(Duration::from_millis(2));
    }

    let subscribe = |wire: u8| {
        let mut raw = TcpStream::connect(primary.local_addr()).unwrap();
        raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        raw.write_all(
            &Request::Subscribe { epoch: log.epoch(), cursor: log.latest_seq(), wire }.encode(),
        )
        .unwrap();
        raw
    };
    let mut v3 = subscribe(protocol::DELTA_WIRE_V3);
    let mut v4 = subscribe(protocol::DELTA_WIRE_V4);

    assert!(producer.negotiate_tracing().unwrap());
    let (_, trace_id) = producer.insert_batch_traced(2, &[30, 40, 50]).unwrap();
    assert_ne!(trace_id, 0);

    let read_until_key2 = |raw: &mut TcpStream| {
        let mut traces = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(15);
        loop {
            assert!(Instant::now() < deadline, "traced batch never arrived");
            match protocol::read_response(raw).unwrap() {
                Response::DeltaBatchV3 { entries, writer_traces, .. } => {
                    traces.extend(writer_traces);
                    if entries.iter().any(|(k, _)| *k == 2) {
                        return traces;
                    }
                }
                other => panic!("expected DeltaBatchV3 frames, got {other:?}"),
            }
        }
    };
    let v3_traces = read_until_key2(&mut v3);
    assert!(
        v3_traces.is_empty(),
        "v3 subscriber must never see trace entries, got {v3_traces:x?}"
    );
    let v4_traces = read_until_key2(&mut v4);
    assert!(
        v4_traces.contains(&trace_id),
        "v4 subscriber must see the writer's trace id {trace_id:x}, got {v4_traces:x?}"
    );
    primary.shutdown();
}

/// Satellite: the slow-request WARN's structured half. A request over
/// the threshold must freeze a black-box snapshot whose events include
/// the offending request's spans under its trace id, plus the instant
/// marker carrying the elapsed time.
#[test]
fn slow_request_anomaly_snapshot_contains_offending_span() {
    let registry = SketchRegistry::shared(RegistryConfig {
        shards: 16,
        ..RegistryConfig::default()
    })
    .unwrap();
    let server = SketchServer::start(
        "127.0.0.1:0",
        registry,
        ServerConfig {
            // Zero threshold: every request is "slow".
            slow_request_threshold: Some(Duration::from_nanos(0)),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let mut client = SketchClient::connect(server.local_addr()).unwrap();
    assert!(client.negotiate_tracing().unwrap());
    // The negotiation probe itself won the first slow-warn CAS slot
    // (untraced). Wait out the rate limiter so the traced insert wins
    // the next slot and snapshots under *its* trace id.
    std::thread::sleep(Duration::from_millis(150));
    let (_, trace_id) = client.insert_batch_traced(5, &[1, 2, 3]).unwrap();
    assert_ne!(trace_id, 0);

    let deadline = Instant::now() + Duration::from_secs(10);
    let snap = loop {
        let hit = recorder::anomalies().into_iter().find(|a| {
            a.label.starts_with("slow request")
                && a.events.iter().any(|e| e.trace_id == trace_id)
        });
        if let Some(snap) = hit {
            break snap;
        }
        assert!(Instant::now() < deadline, "slow-request anomaly never snapshotted");
        std::thread::sleep(Duration::from_millis(5));
    };
    // The snapshot holds the offending span (dispatch + shard ingest
    // begin/end) and the instant marker whose payload is the elapsed ns.
    for stage in [Stage::Dispatch, Stage::ShardIngest] {
        assert!(
            snap.events.iter().any(|e| e.trace_id == trace_id
                && e.stage == stage as u8
                && e.kind == EventKind::Begin as u8),
            "snapshot missing {} span of the slow request",
            stage.name()
        );
    }
    assert!(
        snap.events.iter().any(|e| e.trace_id == trace_id
            && e.kind == EventKind::Instant as u8
            && e.stage == Stage::Dispatch as u8),
        "snapshot missing the slow-request instant marker"
    );
    server.shutdown();
}
