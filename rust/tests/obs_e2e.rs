//! Observability end-to-end: a live primary + follower pair scraped
//! over the wire (`MetricsDump` RPC). Verifies the exposition carries
//! per-opcode latency quantiles, event-loop tick profiles, per-tier
//! registry gauges, and replication-lag gauges on the primary plus
//! `replica_*` series (including seal-to-apply lag) on the follower —
//! and pins the stats-drift fixes (MergeSketch feeds the ingest
//! counters; hostile frames count exactly once).

use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use hll_fpga::hll::HllSketch;
use hll_fpga::net::KeyedFlowGen;
use hll_fpga::obs::registry::parse_line;
use hll_fpga::obs::EXPOSITION_HEADER;
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::replica::{FollowerConfig, FollowerServer, ReplicationConfig};
use hll_fpga::server::{
    protocol, ErrorCode, Response, ServerConfig, SketchClient, SketchServer,
};

/// Exact-series lookup: the value of the line whose full series key
/// (name + rendered labels) equals `series`.
fn metric(text: &str, series: &str) -> Option<f64> {
    text.lines().find_map(|l| {
        let (s, v) = l.rsplit_once(' ')?;
        if s == series {
            v.parse().ok()
        } else {
            None
        }
    })
}

/// Header + every line machine-parseable.
fn assert_well_formed(text: &str) {
    let mut lines = text.lines();
    assert_eq!(lines.next(), Some(EXPOSITION_HEADER), "exposition must lead with the header");
    for line in lines {
        assert!(parse_line(line).is_some(), "unparseable exposition line {line:?}");
    }
}

#[test]
fn metrics_dump_covers_primary_and_follower() {
    let cfg = RegistryConfig { shards: 16, ..RegistryConfig::default() };
    let primary_reg = SketchRegistry::shared(cfg).unwrap();
    let primary = SketchServer::start(
        "127.0.0.1:0",
        primary_reg.clone(),
        ServerConfig {
            replication: Some(ReplicationConfig {
                capture_interval: Duration::from_millis(5),
                ..ReplicationConfig::default()
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let log = primary.replication_log().unwrap();
    let follower_reg = SketchRegistry::shared(cfg).unwrap();
    let follower = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg,
        FollowerConfig::default(),
    )
    .unwrap();

    // Mixed traffic: a zipf-keyed stream, one heavy tenant that
    // promotes past sparse, a sketch merge, and reads.
    let mut client = SketchClient::connect(primary.local_addr()).unwrap();
    client.ping().unwrap();
    let batches = KeyedFlowGen::new(100, 1.07, 0x0B5).batched(20_000, usize::MAX);
    client.pipeline_insert(&batches).unwrap();
    let heavy: Vec<u32> = (0..60_000).collect();
    for chunk in heavy.chunks(8_192) {
        client.insert_batch(9_999, chunk).unwrap();
    }
    let mut local = HllSketch::paper();
    for v in 0..2_000u32 {
        local.insert_u32(v.wrapping_mul(2_654_435_761));
    }
    client.merge_sketch(77, &local).unwrap();
    client.estimate(9_999).unwrap();
    client.global_estimate().unwrap();
    let stats = client.stats().unwrap();

    // Let replication drain so the follower-side series are live.
    let deadline = Instant::now() + Duration::from_secs(60);
    while primary_reg.dirty_keys() > 0 || follower.cursor() < log.latest_seq() {
        assert!(Instant::now() < deadline, "replication never drained");
        std::thread::sleep(Duration::from_millis(2));
    }

    // --- Primary scrape, over the wire.
    let text = client.metrics_dump().unwrap();
    assert_well_formed(&text);

    // Per-opcode latency quantiles and counters.
    for op in ["ping", "insert_batch", "merge_sketch", "estimate", "stats"] {
        let q99 = metric(&text, &format!("rpc_latency_ns{{op=\"{op}\",quantile=\"0.99\"}}"))
            .unwrap_or_else(|| panic!("missing p99 latency for {op}"));
        assert!(q99 > 0.0, "p99 latency for {op} must be nonzero");
        let total = metric(&text, &format!("rpc_total{{op=\"{op}\"}}")).unwrap();
        assert!(total >= 1.0, "rpc_total for {op} must count the traffic");
    }
    let frames = metric(&text, "rpc_latency_ns_count{op=\"insert_batch\"}").unwrap();
    assert!(frames as u64 >= batches.len() as u64, "every insert frame must be timed");
    assert!(
        metric(&text, "rpc_payload_bytes{op=\"insert_batch\",quantile=\"0.5\"}").unwrap() > 0.0
    );

    // Event-loop tick profile: loop 0 polled and did work.
    assert!(metric(&text, "loop_poll_wait_ns_count{loop=\"0\"}").unwrap() > 0.0);
    assert!(metric(&text, "loop_work_ns{loop=\"0\",quantile=\"0.99\"}").unwrap() > 0.0);
    assert!(metric(&text, "loop_ready_events_count{loop=\"0\"}").unwrap() > 0.0);
    let sat = metric(&text, "loop_saturation_permille{loop=\"0\"}").unwrap();
    assert!((0.0..=1_000.0).contains(&sat), "saturation must be a permille ({sat})");

    // Per-tier registry gauges agree with the Stats RPC.
    assert_eq!(metric(&text, "registry_keys").unwrap() as u64, stats.keys);
    let tiers: f64 = ["sparse", "packed", "dense"]
        .iter()
        .map(|t| metric(&text, &format!("registry_tier_keys{{tier=\"{t}\"}}")).unwrap())
        .sum();
    assert_eq!(tiers as u64, stats.keys, "tier gauges must partition the key population");
    assert!(metric(&text, "registry_memory_bytes").unwrap() > 0.0);
    assert_eq!(metric(&text, "registry_words_total").unwrap() as u64, stats.words);

    // Replication gauges: the log sealed batches and the follower's
    // acks pulled the lag down to (or near) zero.
    assert!(metric(&text, "replication_latest_seq").unwrap() >= 1.0);
    assert!(metric(&text, "replication_lag_entries").is_some());
    assert!(metric(&text, "replication_lag_bytes").is_some());
    assert!(metric(&text, "server_delta_batches_sent_total").unwrap() >= 1.0);

    // --- Follower scrape, also over the wire (it serves reads).
    let mut fclient = SketchClient::connect(follower.local_addr()).unwrap();
    let ftext = fclient.metrics_dump().unwrap();
    assert_well_formed(&ftext);
    assert!(metric(&ftext, "replica_cursor").unwrap() >= 1.0);
    assert!(metric(&ftext, "replica_batches_applied").unwrap() >= 1.0);
    assert!(metric(&ftext, "replica_entries_applied").unwrap() >= 1.0);
    assert_eq!(metric(&ftext, "replica_halted").unwrap(), 0.0);
    let lag_samples = metric(&ftext, "replica_seal_to_apply_ns_count").unwrap();
    assert!(lag_samples >= 1.0, "seal-to-apply lag must have samples");
    assert!(
        metric(&ftext, "replica_seal_to_apply_ns{quantile=\"0.99\"}").unwrap() > 0.0,
        "p99 seal-to-apply lag must be nonzero"
    );

    follower.shutdown();
    primary.shutdown();
}

#[test]
fn merge_sketch_feeds_the_ingest_counters() {
    let registry = SketchRegistry::shared(RegistryConfig {
        shards: 16,
        ..RegistryConfig::default()
    })
    .unwrap();
    let server =
        SketchServer::start("127.0.0.1:0", registry, ServerConfig::default()).unwrap();
    let mut client = SketchClient::connect(server.local_addr()).unwrap();

    let mut local = HllSketch::paper();
    for v in 0..5_000u32 {
        local.insert_u32(v.wrapping_mul(2_654_435_761));
    }
    client.merge_sketch(7, &local).unwrap();
    let s = server.stats();
    assert_eq!(s.sketches_merged, 1);
    // The merge credits the sketch's estimated cardinality as a words
    // floor — before the fix this path left words_ingested at zero.
    assert!(
        s.words_ingested >= 4_000,
        "merge must credit ingested words (got {})",
        s.words_ingested
    );

    // A failed merge (truncated bytes) counts an error, not a merge.
    assert!(client.merge_sketch_bytes(8, &[1, 2, 3]).is_err());
    let s = server.stats();
    assert_eq!(s.sketches_merged, 1);
    assert_eq!(s.error_frames, 1);

    // The same cells back the exposition — no double accounting.
    let text = client.metrics_dump().unwrap();
    assert_eq!(metric(&text, "server_sketches_merged_total").unwrap(), 1.0);
    assert_eq!(metric(&text, "server_error_frames_total").unwrap(), 1.0);
    server.shutdown();
}

#[test]
fn hostile_frames_count_exactly_once() {
    let registry = SketchRegistry::shared(RegistryConfig {
        shards: 16,
        ..RegistryConfig::default()
    })
    .unwrap();
    let server =
        SketchServer::start("127.0.0.1:0", registry, ServerConfig::default()).unwrap();
    let addr = server.local_addr();

    // One bad-magic frame → exactly one typed error frame → exactly one
    // tick of the centralized error counter.
    {
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.write_all(b"XX\x01\x01\x00\x00\x00\x00").unwrap();
        match protocol::read_response(&mut raw).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::Malformed),
            other => panic!("expected error frame, got {other:?}"),
        }
    }
    assert_eq!(server.stats().error_frames, 1, "one hostile frame, one error count");
    let text = server.metrics_text();
    assert_eq!(metric(&text, "server_error_frames_total").unwrap(), 1.0);
    server.shutdown();
}
