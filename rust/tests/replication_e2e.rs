//! End-to-end tests for the primary→follower replication subsystem,
//! over real loopback TCP sockets: bit-exact convergence with a
//! follower killed and resumed mid-stream (cursor resume), stale-cursor
//! full-sync fallback, read-only follower behavior, and hostile inputs
//! (config-mismatched delta streams, replication frames aimed at the
//! wrong server) — all typed errors, never a panic.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hll_fpga::hll::{HashKind, HllConfig, HllSketch};
use hll_fpga::net::KeyedFlowGen;
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::replica::{FollowerConfig, FollowerServer, ReplicaCursor, ReplicationConfig};
use hll_fpga::server::{
    protocol, restore_from_bytes, ClientError, ErrorCode, EvictPolicy, Request, Response,
    ServerConfig, SketchClient, SketchServer,
};

/// Registries in these tests use p=12 (4 KiB register files): delta
/// frames carry full dense sketches, and the paper config's 64 KiB per
/// key would make socket-heavy tests needlessly slow on CI.
fn small_cfg() -> RegistryConfig {
    RegistryConfig {
        hll: HllConfig::new(12, HashKind::H64).unwrap(),
        shards: 16,
        ..RegistryConfig::default()
    }
}

fn replicating_server(rcfg: ReplicationConfig) -> (SketchServer, Arc<SketchRegistry<u64>>) {
    let registry = SketchRegistry::shared(small_cfg()).unwrap();
    let server = SketchServer::start(
        "127.0.0.1:0",
        registry.clone(),
        ServerConfig { replication: Some(rcfg), ..ServerConfig::default() },
    )
    .unwrap();
    (server, registry)
}

fn wait_for(mut cond: impl FnMut() -> bool, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(20);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Force-seal everything dirty, then wait until the follower has
/// applied up to the *final* log head — the deterministic drain barrier
/// every convergence assertion sits behind. Loops because the primary's
/// background capture thread may be mid-capture (drained but not yet
/// sealed) while the manual capture runs; the head is final only once
/// no captures are in flight and it stopped moving.
fn drain(primary: &SketchServer, follower: &FollowerServer) {
    let log = primary.replication_log().expect("primary must replicate");
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        log.capture(primary.registry(), usize::MAX);
        let latest = log.latest_seq();
        wait_for(|| follower.cursor() >= latest, "follower to reach the log head");
        if primary.registry().dirty_keys() == 0
            && log.captures_in_flight() == 0
            && log.latest_seq() == latest
        {
            return;
        }
        assert!(Instant::now() < deadline, "replication never fully drained");
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn assert_bit_exact(primary: &Arc<SketchRegistry<u64>>, follower: &Arc<SketchRegistry<u64>>) {
    for (key, want) in primary.estimates() {
        assert_eq!(follower.estimate(&key), Some(want), "key {key}");
    }
    assert_eq!(follower.len(), primary.len());
    assert_eq!(follower.merge_all(), primary.merge_all(), "per-key unions must be register-identical");
    assert_eq!(
        follower.global_estimate(),
        primary.global_estimate(),
        "global unions must match"
    );
}

#[test]
fn follower_converges_bit_exactly_with_kill_and_cursor_resume() {
    let (primary, primary_reg) = replicating_server(ReplicationConfig {
        capture_interval: Duration::from_millis(5),
        ..ReplicationConfig::default()
    });
    let log = primary.replication_log().unwrap();
    let mut client = SketchClient::connect(primary.local_addr()).unwrap();

    let batches = KeyedFlowGen::new(200, 1.07, 0x5EED).batched(30_000, 4096);
    let third = batches.len().div_ceil(3);

    // Phase 1: a follower streams while the primary ingests.
    let follower_reg = SketchRegistry::shared(small_cfg()).unwrap();
    let f1 = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
    )
    .unwrap();
    client.pipeline_insert(&batches[..third]).unwrap();
    // Kill the follower mid-stream, once it has demonstrably applied
    // some of it (cursor > 0 ⇒ the resume below exercises real resume,
    // not a second bootstrap).
    wait_for(|| f1.cursor() > 0, "follower to apply its first batches");
    let f1_stats = f1.stats();
    assert!(f1_stats.full_syncs >= 1, "bootstrap must full-sync");
    let cursor = f1.shutdown();
    assert!(cursor.seq > 0);
    assert_eq!(cursor.epoch, log.epoch(), "cursor must carry the primary's epoch");

    // Phase 2: the primary keeps ingesting while the follower is down.
    client.pipeline_insert(&batches[third..2 * third]).unwrap();

    // Phase 3: resume from the saved cursor against the same registry,
    // with more ingest arriving concurrently with the catch-up stream.
    let f2 = FollowerServer::start_at_cursor(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
        cursor,
    )
    .unwrap();
    client.pipeline_insert(&batches[2 * third..]).unwrap();

    drain(&primary, &f2);
    assert_bit_exact(&primary_reg, &follower_reg);

    // The resumed follower caught up through retained deltas alone.
    let f2_stats = f2.stats();
    assert_eq!(f2_stats.full_syncs, 0, "cursor resume must not full-sync");
    assert!(f2_stats.batches_applied > 0);
    assert!(!f2_stats.halted);

    // And the read-only serving path answers the same numbers.
    let mut fclient = SketchClient::connect(f2.local_addr()).unwrap();
    assert_eq!(fclient.global_estimate().unwrap(), primary_reg.global_estimate());
    let (sample_key, sample_est) = primary_reg.estimates()[0];
    assert_eq!(fclient.estimate(sample_key).unwrap(), Some(sample_est));

    assert!(log.stats().sealed_batches > 0);
    assert!(primary.stats().delta_batches_sent > 0);
    f2.shutdown();
    primary.shutdown();
}

#[test]
fn stale_cursor_falls_back_to_full_sync() {
    // retain_bytes = 1 keeps only the newest sealed batch, so any
    // cursor more than one batch behind is stale by construction.
    let (primary, primary_reg) = replicating_server(ReplicationConfig {
        capture_interval: Duration::from_millis(5),
        retain_bytes: 1,
        ..ReplicationConfig::default()
    });
    let log = primary.replication_log().unwrap();
    let mut client = SketchClient::connect(primary.local_addr()).unwrap();

    // Seal a run of batches one key at a time, far past retention. The
    // background capture thread may race the manual captures for a
    // key, so wait for the final seal rather than asserting instantly.
    for key in 0u64..20 {
        let words: Vec<u32> = (0..200u32).map(|w| w.wrapping_mul(key as u32 * 31 + 7)).collect();
        client.insert_batch(key, &words).unwrap();
        log.capture(&primary_reg, 1);
    }
    wait_for(|| log.latest_seq() >= 20, "all per-key batches to seal");
    assert_eq!(log.stats().retained_batches, 1);

    // A fresh follower (cursor 0) can only bootstrap via full sync.
    let follower_reg = SketchRegistry::shared(small_cfg()).unwrap();
    let follower = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
    )
    .unwrap();
    drain(&primary, &follower);
    assert_bit_exact(&primary_reg, &follower_reg);
    let stats = follower.stats();
    assert!(stats.full_syncs >= 1);
    assert!(!stats.halted);
    assert!(primary.stats().full_syncs_sent >= 1);

    // Kill it, rotate the log well past its cursor, resume: the stale
    // cursor must trigger another full sync — and still converge.
    let cursor: ReplicaCursor = follower.shutdown();
    for key in 100u64..120 {
        let words: Vec<u32> = (0..200u32).map(|w| w.wrapping_add(key as u32 * 91_000)).collect();
        client.insert_batch(key, &words).unwrap();
        log.capture(&primary_reg, 1);
    }
    let resumed = FollowerServer::start_at_cursor(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
        cursor,
    )
    .unwrap();
    drain(&primary, &resumed);
    assert_bit_exact(&primary_reg, &follower_reg);
    assert!(resumed.stats().full_syncs >= 1, "stale cursor must full-sync");
    resumed.shutdown();
    primary.shutdown();
}

#[test]
fn follower_serves_reads_and_rejects_writes_with_typed_readonly() {
    let (primary, _primary_reg) = replicating_server(ReplicationConfig::default());
    let mut producer = SketchClient::connect(primary.local_addr()).unwrap();
    producer.insert_batch(5, &[1, 2, 3, 4]).unwrap();

    let follower_reg = SketchRegistry::shared(small_cfg()).unwrap();
    let follower = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg,
        FollowerConfig::default(),
    )
    .unwrap();
    drain(&primary, &follower);

    let mut client = SketchClient::connect(follower.local_addr()).unwrap();
    // Reads serve normally.
    client.ping().unwrap();
    assert!(client.estimate(5).unwrap().is_some());
    assert!(client.global_estimate().unwrap().is_some());
    assert_eq!(client.stats().unwrap().keys, 1);

    // Every mutating RPC is a typed ReadOnly error, and the connection
    // survives each one.
    let expect_read_only = |res: Result<(), ClientError>, what: &str| match res {
        Err(ClientError::Remote { code, .. }) => {
            assert_eq!(code, ErrorCode::ReadOnly, "{what}")
        }
        other => panic!("{what}: expected remote ReadOnly, got {other:?}"),
    };
    expect_read_only(client.insert_batch(9, &[1]).map(|_| ()), "insert");
    let sketch = HllSketch::new(small_cfg().hll);
    expect_read_only(client.merge_sketch(9, &sketch), "merge");
    expect_read_only(client.evict(EvictPolicy::Key(5)).map(|_| ()), "evict");
    expect_read_only(client.snapshot().map(|_| ()), "snapshot");
    assert_eq!(client.estimate(9).unwrap(), None, "rejected writes must not create keys");
    client.ping().unwrap();

    follower.shutdown();
    primary.shutdown();
}

#[test]
fn config_mismatched_stream_halts_follower_without_panicking() {
    // Primary hashes with seed 7; the follower registry is seed 0. The
    // very first full sync cannot apply — the follower must record a
    // typed error, halt replication, and keep serving reads.
    let primary_reg = SketchRegistry::shared(RegistryConfig {
        hll: HllConfig::new(12, HashKind::H64).unwrap().with_seed(7),
        shards: 16,
        ..RegistryConfig::default()
    })
    .unwrap();
    let primary = SketchServer::start(
        "127.0.0.1:0",
        primary_reg.clone(),
        ServerConfig {
            replication: Some(ReplicationConfig::default()),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let mut producer = SketchClient::connect(primary.local_addr()).unwrap();
    producer.insert_batch(1, &[1, 2, 3]).unwrap();

    let follower_reg = SketchRegistry::shared(small_cfg()).unwrap();
    let follower = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
    )
    .unwrap();
    let deadline = Instant::now() + Duration::from_secs(20);
    while !follower.stats().halted {
        assert!(Instant::now() < deadline, "follower never halted on the mismatch");
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = follower.stats();
    assert!(stats.last_error.is_some(), "the rejection must be recorded");
    assert_eq!(stats.cursor, 0, "nothing may apply from a mismatched stream");
    assert!(follower_reg.is_empty());

    // Still alive and serving (empty) reads.
    let mut client = SketchClient::connect(follower.local_addr()).unwrap();
    client.ping().unwrap();
    assert_eq!(client.estimate(1).unwrap(), None);
    follower.shutdown();
    primary.shutdown();
}

#[test]
fn replication_frames_against_the_wrong_server_are_typed_errors() {
    use std::io::Write;

    // Subscribe to a server that is not a replication primary.
    let plain_reg = SketchRegistry::shared(small_cfg()).unwrap();
    let plain =
        SketchServer::start("127.0.0.1:0", plain_reg, ServerConfig::default()).unwrap();
    {
        let mut raw = TcpStream::connect(plain.local_addr()).unwrap();
        raw.write_all(&Request::Subscribe { epoch: 0, cursor: 0 }.encode()).unwrap();
        match protocol::read_response(&mut raw).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::Unsupported),
            other => panic!("expected Unsupported, got {other:?}"),
        }
        // The connection stays in sync and usable.
        raw.write_all(&Request::Ping.encode()).unwrap();
        assert_eq!(protocol::read_response(&mut raw).unwrap(), Response::Pong);
    }

    // A ReplicaAck outside a subscription is Malformed, and survivable.
    {
        let mut raw = TcpStream::connect(plain.local_addr()).unwrap();
        raw.write_all(&Request::ReplicaAck { cursor: 3 }.encode()).unwrap();
        match protocol::read_response(&mut raw).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::Malformed),
            other => panic!("expected Malformed, got {other:?}"),
        }
        raw.write_all(&Request::Ping.encode()).unwrap();
        assert_eq!(protocol::read_response(&mut raw).unwrap(), Response::Pong);
    }
    plain.shutdown();
}

#[test]
fn raw_subscriber_gets_a_restorable_full_sync_image() {
    use std::io::Write;

    let (primary, primary_reg) = replicating_server(ReplicationConfig::default());
    let mut producer = SketchClient::connect(primary.local_addr()).unwrap();
    for key in 0u64..12 {
        let words: Vec<u32> = (0..300u32).map(|w| w.wrapping_mul(key as u32 + 13)).collect();
        producer.insert_batch(key, &words).unwrap();
    }

    // Hand-rolled follower: subscribe at cursor 0, read one frame.
    let mut raw = TcpStream::connect(primary.local_addr()).unwrap();
    raw.write_all(&Request::Subscribe { epoch: 0, cursor: 0 }.encode()).unwrap();
    match protocol::read_response(&mut raw).unwrap() {
        Response::FullSync { epoch, cursor, body } => {
            // The image is a valid HLLSNAP2 snapshot that restores a
            // fresh registry to the primary's exact state (the export
            // walks the live registry, so it holds all 12 keys whether
            // or not the capture thread has sealed them yet).
            let fresh = SketchRegistry::shared(small_cfg()).unwrap();
            assert_eq!(restore_from_bytes(&fresh, &body).unwrap(), 12);
            assert_eq!(fresh.merge_all(), primary_reg.merge_all());
            assert_eq!(fresh.global_estimate(), primary_reg.global_estimate());
            // The sync carries the log's incarnation id, and its cursor
            // never runs ahead of what the log has sealed.
            assert_eq!(epoch, primary.replication_log().unwrap().epoch());
            assert!(cursor <= primary.replication_log().unwrap().latest_seq());
        }
        other => panic!("bootstrap must answer FullSync, got {other:?}"),
    }
    primary.shutdown();
}
