//! End-to-end tests for the primary→follower replication subsystem,
//! over real loopback TCP sockets: bit-exact convergence with a
//! follower killed and resumed mid-stream (cursor resume), stale-cursor
//! full-sync fallback, eviction tombstones and register-diff deltas
//! (wire v3) keeping an evicting/sweeping primary convergent, read-only
//! follower behavior, and hostile inputs (config-mismatched delta
//! streams, replication frames aimed at the wrong server) — all typed
//! errors, never a panic.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hll_fpga::hll::{HashKind, HllConfig, HllSketch};
use hll_fpga::net::KeyedFlowGen;
use hll_fpga::registry::{RegistryConfig, SketchDelta, SketchRegistry, WallClock};
use hll_fpga::replica::{FollowerConfig, FollowerServer, ReplicaCursor, ReplicationConfig};
use hll_fpga::server::{
    protocol, restore_from_bytes, ClientError, ErrorCode, EvictPolicy, Request, Response,
    ServerConfig, SketchClient, SketchServer, SweeperConfig,
};

/// Registries in these tests use p=12 (4 KiB register files): delta
/// frames carry full dense sketches, and the paper config's 64 KiB per
/// key would make socket-heavy tests needlessly slow on CI.
fn small_cfg() -> RegistryConfig {
    RegistryConfig {
        hll: HllConfig::new(12, HashKind::H64).unwrap(),
        shards: 16,
        ..RegistryConfig::default()
    }
}

fn replicating_server(rcfg: ReplicationConfig) -> (SketchServer, Arc<SketchRegistry<u64>>) {
    let registry = SketchRegistry::shared(small_cfg()).unwrap();
    let server = SketchServer::start(
        "127.0.0.1:0",
        registry.clone(),
        ServerConfig { replication: Some(rcfg), ..ServerConfig::default() },
    )
    .unwrap();
    (server, registry)
}

fn wait_for(mut cond: impl FnMut() -> bool, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(20);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Force-seal everything dirty ([`hll_fpga::replica::ReplicationLog::seal_all`],
/// the deterministic drain barrier), then wait until the follower has
/// applied up to the final log head — what every convergence assertion
/// sits behind.
fn drain(primary: &SketchServer, follower: &FollowerServer) {
    let log = primary.replication_log().expect("primary must replicate");
    let head = log.seal_all(primary.registry(), Duration::from_secs(20));
    wait_for(|| follower.cursor() >= head, "follower to reach the final log head");
}

/// The strongest convergence check for tests that evict: identical key
/// sets and *register-identical* per-key sketches. (The global union is
/// deliberately not compared here — words ingested into a key that is
/// evicted before the next capture reach the primary's global sketch
/// but can never reach the follower's; live-key state is what
/// tombstoned replication guarantees, and it must be bit-exact.)
fn assert_live_state_identical(
    primary: &Arc<SketchRegistry<u64>>,
    follower: &Arc<SketchRegistry<u64>>,
) {
    let mut p = primary.export_sketches();
    let mut f = follower.export_sketches();
    p.sort_by_key(|(k, _)| *k);
    f.sort_by_key(|(k, _)| *k);
    assert_eq!(
        p.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
        f.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
        "key sets must match"
    );
    assert_eq!(p, f, "per-key register files must be identical");
    assert_eq!(follower.merge_all(), primary.merge_all());
    for (key, want) in primary.estimates() {
        assert_eq!(follower.estimate(&key), Some(want), "key {key}");
    }
}

fn assert_bit_exact(primary: &Arc<SketchRegistry<u64>>, follower: &Arc<SketchRegistry<u64>>) {
    for (key, want) in primary.estimates() {
        assert_eq!(follower.estimate(&key), Some(want), "key {key}");
    }
    assert_eq!(follower.len(), primary.len());
    assert_eq!(follower.merge_all(), primary.merge_all(), "per-key unions must be register-identical");
    assert_eq!(
        follower.global_estimate(),
        primary.global_estimate(),
        "global unions must match"
    );
}

#[test]
fn follower_converges_bit_exactly_with_kill_and_cursor_resume() {
    let (primary, primary_reg) = replicating_server(ReplicationConfig {
        capture_interval: Duration::from_millis(5),
        ..ReplicationConfig::default()
    });
    let log = primary.replication_log().unwrap();
    let mut client = SketchClient::connect(primary.local_addr()).unwrap();

    let batches = KeyedFlowGen::new(200, 1.07, 0x5EED).batched(30_000, 4096);
    let third = batches.len().div_ceil(3);

    // Phase 1: a follower streams while the primary ingests.
    let follower_reg = SketchRegistry::shared(small_cfg()).unwrap();
    let f1 = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
    )
    .unwrap();
    client.pipeline_insert(&batches[..third]).unwrap();
    // Kill the follower mid-stream, once it has demonstrably applied
    // some of it (cursor > 0 ⇒ the resume below exercises real resume,
    // not a second bootstrap).
    wait_for(|| f1.cursor() > 0, "follower to apply its first batches");
    let f1_stats = f1.stats();
    assert!(f1_stats.full_syncs >= 1, "bootstrap must full-sync");
    let cursor = f1.shutdown();
    assert!(cursor.seq > 0);
    assert_eq!(cursor.epoch, log.epoch(), "cursor must carry the primary's epoch");

    // Phase 2: the primary keeps ingesting while the follower is down.
    client.pipeline_insert(&batches[third..2 * third]).unwrap();

    // Phase 3: resume from the saved cursor against the same registry,
    // with more ingest arriving concurrently with the catch-up stream.
    let f2 = FollowerServer::start_at_cursor(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
        cursor,
    )
    .unwrap();
    client.pipeline_insert(&batches[2 * third..]).unwrap();

    drain(&primary, &f2);
    assert_bit_exact(&primary_reg, &follower_reg);

    // The resumed follower caught up through retained deltas alone.
    let f2_stats = f2.stats();
    assert_eq!(f2_stats.full_syncs, 0, "cursor resume must not full-sync");
    assert!(f2_stats.batches_applied > 0);
    assert!(!f2_stats.halted);

    // And the read-only serving path answers the same numbers.
    let mut fclient = SketchClient::connect(f2.local_addr()).unwrap();
    assert_eq!(fclient.global_estimate().unwrap(), primary_reg.global_estimate());
    let (sample_key, sample_est) = primary_reg.estimates()[0];
    assert_eq!(fclient.estimate(sample_key).unwrap(), Some(sample_est));

    assert!(log.stats().sealed_batches > 0);
    assert!(primary.stats().delta_batches_sent > 0);
    f2.shutdown();
    primary.shutdown();
}

#[test]
fn stale_cursor_falls_back_to_full_sync() {
    // retain_bytes = 1 keeps only the newest sealed batch, so any
    // cursor more than one batch behind is stale by construction.
    let (primary, primary_reg) = replicating_server(ReplicationConfig {
        capture_interval: Duration::from_millis(5),
        retain_bytes: 1,
        ..ReplicationConfig::default()
    });
    let log = primary.replication_log().unwrap();
    let mut client = SketchClient::connect(primary.local_addr()).unwrap();

    // Seal a run of batches one key at a time, far past retention. The
    // background capture thread may race the manual captures for a
    // key, so wait for the final seal rather than asserting instantly.
    for key in 0u64..20 {
        let words: Vec<u32> = (0..200u32).map(|w| w.wrapping_mul(key as u32 * 31 + 7)).collect();
        client.insert_batch(key, &words).unwrap();
        log.capture(&primary_reg, 1);
    }
    wait_for(|| log.latest_seq() >= 20, "all per-key batches to seal");
    assert_eq!(log.stats().retained_batches, 1);

    // A fresh follower (cursor 0) can only bootstrap via full sync.
    let follower_reg = SketchRegistry::shared(small_cfg()).unwrap();
    let follower = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
    )
    .unwrap();
    drain(&primary, &follower);
    assert_bit_exact(&primary_reg, &follower_reg);
    let stats = follower.stats();
    assert!(stats.full_syncs >= 1);
    assert!(!stats.halted);
    assert!(primary.stats().full_syncs_sent >= 1);

    // Kill it, evict a key the follower already holds, rotate the log
    // well past its cursor, resume: the stale cursor must trigger
    // another full sync — one that *replaces* state, so the eviction
    // whose tombstone rotated out of retention still takes effect.
    let cursor: ReplicaCursor = follower.shutdown();
    assert_eq!(client.evict(EvictPolicy::Key(3)).unwrap(), 1);
    for key in 100u64..120 {
        let words: Vec<u32> = (0..200u32).map(|w| w.wrapping_add(key as u32 * 91_000)).collect();
        client.insert_batch(key, &words).unwrap();
        log.capture(&primary_reg, 1);
    }
    let resumed = FollowerServer::start_at_cursor(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
        cursor,
    )
    .unwrap();
    drain(&primary, &resumed);
    assert_bit_exact(&primary_reg, &follower_reg);
    assert_eq!(
        follower_reg.estimate(&3),
        None,
        "a key evicted while the follower was rotated out must not survive the resync"
    );
    assert!(resumed.stats().full_syncs >= 1, "stale cursor must full-sync");
    resumed.shutdown();
    primary.shutdown();
}

#[test]
fn follower_serves_reads_and_rejects_writes_with_typed_readonly() {
    let (primary, _primary_reg) = replicating_server(ReplicationConfig::default());
    let mut producer = SketchClient::connect(primary.local_addr()).unwrap();
    producer.insert_batch(5, &[1, 2, 3, 4]).unwrap();

    let follower_reg = SketchRegistry::shared(small_cfg()).unwrap();
    let follower = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg,
        FollowerConfig::default(),
    )
    .unwrap();
    drain(&primary, &follower);

    let mut client = SketchClient::connect(follower.local_addr()).unwrap();
    // Reads serve normally.
    client.ping().unwrap();
    assert!(client.estimate(5).unwrap().is_some());
    assert!(client.global_estimate().unwrap().is_some());
    assert_eq!(client.stats().unwrap().keys, 1);

    // Every mutating RPC is a typed ReadOnly error, and the connection
    // survives each one.
    let expect_read_only = |res: Result<(), ClientError>, what: &str| match res {
        Err(ClientError::Remote { code, .. }) => {
            assert_eq!(code, ErrorCode::ReadOnly, "{what}")
        }
        other => panic!("{what}: expected remote ReadOnly, got {other:?}"),
    };
    expect_read_only(client.insert_batch(9, &[1]).map(|_| ()), "insert");
    let sketch = HllSketch::new(small_cfg().hll);
    expect_read_only(client.merge_sketch(9, &sketch), "merge");
    expect_read_only(client.evict(EvictPolicy::Key(5)).map(|_| ()), "evict");
    expect_read_only(client.snapshot().map(|_| ()), "snapshot");
    assert_eq!(client.estimate(9).unwrap(), None, "rejected writes must not create keys");
    client.ping().unwrap();

    follower.shutdown();
    primary.shutdown();
}

#[test]
fn config_mismatched_stream_halts_follower_without_panicking() {
    // Primary hashes with seed 7; the follower registry is seed 0. The
    // very first full sync cannot apply — the follower must record a
    // typed error, halt replication, and keep serving reads.
    let primary_reg = SketchRegistry::shared(RegistryConfig {
        hll: HllConfig::new(12, HashKind::H64).unwrap().with_seed(7),
        shards: 16,
        ..RegistryConfig::default()
    })
    .unwrap();
    let primary = SketchServer::start(
        "127.0.0.1:0",
        primary_reg.clone(),
        ServerConfig {
            replication: Some(ReplicationConfig::default()),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let mut producer = SketchClient::connect(primary.local_addr()).unwrap();
    producer.insert_batch(1, &[1, 2, 3]).unwrap();

    let follower_reg = SketchRegistry::shared(small_cfg()).unwrap();
    let follower = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
    )
    .unwrap();
    let deadline = Instant::now() + Duration::from_secs(20);
    while !follower.stats().halted {
        assert!(Instant::now() < deadline, "follower never halted on the mismatch");
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = follower.stats();
    assert!(stats.last_error.is_some(), "the rejection must be recorded");
    assert_eq!(stats.cursor, 0, "nothing may apply from a mismatched stream");
    assert!(follower_reg.is_empty());

    // Still alive and serving (empty) reads.
    let mut client = SketchClient::connect(follower.local_addr()).unwrap();
    client.ping().unwrap();
    assert_eq!(client.estimate(1).unwrap(), None);
    follower.shutdown();
    primary.shutdown();
}

#[test]
fn replication_frames_against_the_wrong_server_are_typed_errors() {
    use std::io::Write;

    // Subscribe to a server that is not a replication primary.
    let plain_reg = SketchRegistry::shared(small_cfg()).unwrap();
    let plain =
        SketchServer::start("127.0.0.1:0", plain_reg, ServerConfig::default()).unwrap();
    {
        let mut raw = TcpStream::connect(plain.local_addr()).unwrap();
        raw.write_all(&Request::Subscribe { epoch: 0, cursor: 0, wire: protocol::DELTA_WIRE_V3 }.encode()).unwrap();
        match protocol::read_response(&mut raw).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::Unsupported),
            other => panic!("expected Unsupported, got {other:?}"),
        }
        // The connection stays in sync and usable.
        raw.write_all(&Request::Ping.encode()).unwrap();
        assert_eq!(protocol::read_response(&mut raw).unwrap(), Response::Pong);
    }

    // A ReplicaAck outside a subscription is Malformed, and survivable.
    {
        let mut raw = TcpStream::connect(plain.local_addr()).unwrap();
        raw.write_all(&Request::ReplicaAck { cursor: 3 }.encode()).unwrap();
        match protocol::read_response(&mut raw).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::Malformed),
            other => panic!("expected Malformed, got {other:?}"),
        }
        raw.write_all(&Request::Ping.encode()).unwrap();
        assert_eq!(protocol::read_response(&mut raw).unwrap(), Response::Pong);
    }
    plain.shutdown();
}

#[test]
fn evictions_and_reingest_converge_bit_exactly() {
    let (primary, primary_reg) = replicating_server(ReplicationConfig {
        capture_interval: Duration::from_millis(5),
        ..ReplicationConfig::default()
    });
    let mut client = SketchClient::connect(primary.local_addr()).unwrap();
    let follower_reg = SketchRegistry::shared(small_cfg()).unwrap();
    let follower = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
    )
    .unwrap();

    // Regression for the drain-drops-evicted-keys bug: an insert acked
    // to the client, evicted before the capture tick, must reach the
    // stream as a tombstone (not silently vanish) — either way the
    // follower must not end up holding key 100.
    client.insert_batch(100, &[1, 2, 3]).unwrap();
    assert_eq!(client.evict(EvictPolicy::Key(100)).unwrap(), 1);

    // A spread of keys, including one dense enough to take the
    // register-diff path (p=12 upgrades past ~512 sparse entries).
    let dense_words: Vec<u32> = (0..3_000u32).map(|w| w.wrapping_mul(2_654_435_761)).collect();
    client.insert_batch(50, &dense_words).unwrap();
    for key in 0u64..20 {
        let words: Vec<u32> = (0..200u32).map(|w| w.wrapping_mul(key as u32 * 97 + 11)).collect();
        client.insert_batch(key, &words).unwrap();
    }
    drain(&primary, &follower);
    assert_eq!(follower_reg.estimate(&100), None, "evicted-before-capture key must not exist");

    // Touch the dense key again: only the changed registers may ship.
    let fresh: Vec<u32> = (0..80u32).map(|w| w.wrapping_mul(77_777_777).wrapping_add(13)).collect();
    client.insert_batch(50, &fresh).unwrap();
    drain(&primary, &follower);
    assert!(
        follower.stats().diff_entries_applied > 0,
        "steady-state dense updates must travel as register diffs"
    );

    // Evict half the keys over RPC, re-create some under the same name
    // with different content — the tombstone-then-resend ordering must
    // leave the follower with exactly the new incarnation's registers.
    for key in 0u64..10 {
        assert_eq!(client.evict(EvictPolicy::Key(key)).unwrap(), 1, "key {key}");
    }
    for key in 0u64..3 {
        let reborn: Vec<u32> =
            (0..50u32).map(|w| w.wrapping_mul(key as u32 + 5).wrapping_add(1_000_003)).collect();
        client.insert_batch(key, &reborn).unwrap();
    }
    drain(&primary, &follower);
    assert_live_state_identical(&primary_reg, &follower_reg);
    assert!(primary_reg.estimate(&15).is_some(), "untouched keys must survive");
    assert_eq!(follower_reg.estimate(&4), None, "evicted keys must be gone on the follower");
    let fstats = follower.stats();
    assert!(fstats.tombstones_applied >= 7, "evictions must arrive as tombstones");
    assert!(!fstats.halted);

    // And the whole sequence kept serving reads on the follower.
    let mut fclient = SketchClient::connect(follower.local_addr()).unwrap();
    assert_eq!(fclient.estimate(4).unwrap(), None);
    assert_eq!(fclient.estimate(50).unwrap(), primary_reg.estimate(&50));
    follower.shutdown();
    primary.shutdown();
}

#[test]
fn sweeper_on_primary_stays_convergent_across_kill_and_reconnect() {
    // TTL eviction runs on the primary's background sweeper (manual
    // wall clock) while a follower replicates; the follower is killed
    // mid-test and resumed from its cursor with sweeps happening while
    // it is down — tombstones must flow through the retained delta log
    // and leave live state register-identical.
    let (wall, clock) = WallClock::manual(1_000);
    let primary_reg = Arc::new(
        SketchRegistry::with_wall_clock(small_cfg(), wall).unwrap(),
    );
    let primary = SketchServer::start(
        "127.0.0.1:0",
        primary_reg.clone(),
        ServerConfig {
            replication: Some(ReplicationConfig {
                capture_interval: Duration::from_millis(5),
                ..ReplicationConfig::default()
            }),
            sweeper: Some(SweeperConfig {
                interval: Duration::from_millis(20),
                idle_max_age: Some(Duration::from_secs(30 * 60)),
                idle_max_ticks: None,
                enforce_budget: false,
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let mut client = SketchClient::connect(primary.local_addr()).unwrap();

    // 30 keys live at wall second 1000; a follower converges on them.
    for key in 0u64..30 {
        let words: Vec<u32> = (0..150u32).map(|w| w.wrapping_mul(key as u32 * 31 + 7)).collect();
        client.insert_batch(key, &words).unwrap();
    }
    let follower_reg = SketchRegistry::shared(small_cfg()).unwrap();
    let f1 = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
    )
    .unwrap();
    drain(&primary, &f1);
    assert_eq!(follower_reg.len(), 30);

    // Kill the follower mid-stream, then let an hour pass. Keys 25..30
    // are touched after the jump (they survive the 30-minute TTL), a
    // fresh key arrives, and the sweeper reaps the 25 idle keys — all
    // while the follower is down.
    let cursor = f1.shutdown();
    assert!(cursor.seq > 0);
    clock.store(1_000 + 3_600, std::sync::atomic::Ordering::Relaxed);
    for key in 25u64..30 {
        client.insert_batch(key, &[key as u32, key as u32 + 1]).unwrap();
    }
    client.insert_batch(777, &[1, 2, 3, 4]).unwrap();
    wait_for(|| primary_reg.len() == 6, "sweeper to reap the idle keys");

    // Resume from the saved cursor: tombstones and the survivors' new
    // touches arrive as retained deltas (no full sync), and live state
    // converges register-identically.
    let f2 = FollowerServer::start_at_cursor(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
        cursor,
    )
    .unwrap();
    drain(&primary, &f2);
    assert_live_state_identical(&primary_reg, &follower_reg);
    assert_eq!(follower_reg.len(), 6);
    let stats = f2.stats();
    assert_eq!(stats.full_syncs, 0, "cursor resume must ride the delta log");
    assert!(stats.tombstones_applied >= 25, "sweeper evictions must arrive as tombstones");
    assert!(!stats.halted);

    // Sweeps that reap nothing new keep the pair stable.
    drain(&primary, &f2);
    assert_live_state_identical(&primary_reg, &follower_reg);
    f2.shutdown();
    primary.shutdown();
}

#[test]
fn raw_subscriber_sees_typed_v3_tombstone_frames() {
    use std::io::Write;

    let (primary, primary_reg) = replicating_server(ReplicationConfig {
        capture_interval: Duration::from_millis(5),
        ..ReplicationConfig::default()
    });
    let mut producer = SketchClient::connect(primary.local_addr()).unwrap();
    producer.insert_batch(1, &[10, 20, 30]).unwrap();
    let log = primary.replication_log().unwrap();
    wait_for(|| primary_reg.dirty_keys() == 0 && log.latest_seq() > 0, "first capture");

    // Hand-rolled follower positioned at the log head: the next frames
    // it reads are deltas, not a bootstrap image.
    let mut raw = TcpStream::connect(primary.local_addr()).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let head = log.latest_seq();
    raw.write_all(&Request::Subscribe { epoch: log.epoch(), cursor: head, wire: protocol::DELTA_WIRE_V3 }.encode()).unwrap();

    // Evict key 1 and re-create it: the wire must carry a DELTA_BATCH_V3
    // with the tombstone strictly before the re-created key's sketch.
    producer.evict(EvictPolicy::Key(1)).unwrap();
    producer.insert_batch(1, &[40, 50]).unwrap();
    let mut seen: Vec<(u64, SketchDelta)> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(15);
    while seen.iter().filter(|(k, _)| *k == 1).count() < 2 {
        assert!(Instant::now() < deadline, "tombstone + resend never arrived; saw {seen:?}");
        match protocol::read_response(&mut raw).unwrap() {
            Response::DeltaBatchV3 { entries, .. } => seen.extend(entries),
            other => panic!("expected DeltaBatchV3 frames, got {other:?}"),
        }
    }
    // We subscribed at the head, past the original sketch's batch, so
    // key 1's frames here are exactly the eviction and the rebirth — in
    // that order, whether they sealed into one batch or two.
    let key1: Vec<&SketchDelta> = seen.iter().filter(|(k, _)| *k == 1).map(|(_, d)| d).collect();
    assert_eq!(key1[0], &SketchDelta::Tombstone, "tombstone must precede the resend: {key1:?}");
    assert!(
        matches!(key1[1], SketchDelta::Full(_)),
        "re-created key must follow as a full resend: {key1:?}"
    );
    primary.shutdown();
}

#[test]
fn legacy_v2_subscriber_gets_downgraded_full_sketch_frames() {
    use std::io::Write;

    let (primary, primary_reg) = replicating_server(ReplicationConfig {
        capture_interval: Duration::from_millis(5),
        ..ReplicationConfig::default()
    });
    let mut producer = SketchClient::connect(primary.local_addr()).unwrap();
    // A dense key, so steady-state touches seal as register diffs.
    let dense: Vec<u32> = (0..3_000u32).map(|w| w.wrapping_mul(2_654_435_761)).collect();
    producer.insert_batch(9, &dense).unwrap();
    let log = primary.replication_log().unwrap();
    wait_for(|| primary_reg.dirty_keys() == 0 && log.latest_seq() > 0, "first capture");

    // Subscribe with a hand-rolled *16-byte* legacy payload (epoch +
    // cursor, no wire field) — what a pre-v3 follower sends.
    let mut raw = TcpStream::connect(primary.local_addr()).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let head = log.latest_seq();
    let mut legacy = Vec::new();
    legacy.extend_from_slice(&protocol::MAGIC);
    legacy.push(protocol::PROTO_VERSION);
    legacy.push(protocol::opcodes::SUBSCRIBE);
    legacy.extend_from_slice(&16u32.to_le_bytes());
    legacy.extend_from_slice(&log.epoch().to_le_bytes());
    legacy.extend_from_slice(&head.to_le_bytes());
    raw.write_all(&legacy).unwrap();

    // A fresh-word touch on the dense key seals as a register diff; the
    // legacy subscriber must receive it as a v2 DELTA_BATCH entry
    // inflated to a full sketch holding only the changed registers.
    let fresh: Vec<u32> = (0..50u32).map(|w| w.wrapping_mul(97_003).wrapping_add(7)).collect();
    producer.insert_batch(9, &fresh).unwrap();
    let deadline = Instant::now() + Duration::from_secs(15);
    let mut got: Option<HllSketch> = None;
    while got.is_none() {
        assert!(Instant::now() < deadline, "downgraded diff never arrived");
        match protocol::read_response(&mut raw).unwrap() {
            Response::DeltaBatch { entries, .. } => {
                for (key, bytes) in entries {
                    if key == 9 {
                        got = Some(HllSketch::from_bytes(&bytes).unwrap());
                    }
                }
            }
            other => {
                panic!("legacy subscriber must only see v2 DeltaBatch frames, got {other:?}")
            }
        }
    }
    let sketch = got.unwrap();
    let nonzero = sketch.registers().iter().filter(|&&r| r != 0).count();
    assert!(
        nonzero > 0 && nonzero <= 50,
        "inflated diff must hold only the changed registers, got {nonzero}"
    );
    primary.shutdown();
}

#[test]
fn global_union_converges_despite_evict_before_capture() {
    // The closed ROADMAP gap: words ingested into a key that is evicted
    // *before the next capture tick* used to die with the key (the
    // follower's live-key state converged, its global union silently
    // lagged). The global sketch's own changed-register dirty tracking
    // now ships them as a GLOBAL_DIFF entry. A huge capture interval
    // keeps the background thread out of the window so the
    // evict-before-capture ordering is deterministic; `drain` forces
    // the seals.
    let (primary, primary_reg) = replicating_server(ReplicationConfig {
        capture_interval: Duration::from_secs(3_600),
        ..ReplicationConfig::default()
    });
    let mut client = SketchClient::connect(primary.local_addr()).unwrap();
    let follower_reg = SketchRegistry::shared(small_cfg()).unwrap();
    let follower = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
    )
    .unwrap();
    drain(&primary, &follower);
    // Pin the bootstrap before any ingest: the gap below must then be
    // closed by a GLOBAL_DIFF delta entry, not absorbed into the
    // bootstrap image by a lucky race.
    wait_for(|| follower.stats().full_syncs >= 1, "bootstrap full sync");

    // Key 100 lives and dies entirely between captures.
    client.insert_batch(100, &(0..500u32).map(|w| w.wrapping_mul(2_654_435_761)).collect::<Vec<_>>()).unwrap();
    assert_eq!(client.evict(EvictPolicy::Key(100)).unwrap(), 1);
    // A surviving key too, so the batch carries ordinary entries
    // alongside the tombstone and the global diff.
    client.insert_batch(7, &[1, 2, 3, 4, 5]).unwrap();
    drain(&primary, &follower);

    assert_eq!(follower_reg.estimate(&100), None, "the dead key must not exist");
    assert_eq!(
        follower_reg.global_estimate(),
        primary_reg.global_estimate(),
        "the dead key's words must still reach the follower's global union"
    );
    // Strictly more than the live keys alone can explain: rebuilding
    // the union from live keys undercounts, the replicated global
    // sketch does not.
    assert!(
        follower_reg.global_estimate().unwrap() > follower_reg.merge_all().estimate(),
        "global must exceed the live-key union once a key died with unique words"
    );
    let fstats = follower.stats();
    assert!(fstats.global_diffs_applied >= 1, "the gap closes via GLOBAL_DIFF entries");
    assert!(!fstats.halted);

    // Kill / resume: global diffs ride the retained delta log across a
    // reconnect like any other entry, still without a full sync.
    let cursor = follower.shutdown();
    client.insert_batch(200, &(0..300u32).map(|w| w.wrapping_mul(97_003).wrapping_add(1)).collect::<Vec<_>>()).unwrap();
    assert_eq!(client.evict(EvictPolicy::Key(200)).unwrap(), 1);
    let resumed = FollowerServer::start_at_cursor(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
        cursor,
    )
    .unwrap();
    drain(&primary, &resumed);
    assert_eq!(resumed.stats().full_syncs, 0, "cursor resume must ride the delta log");
    assert_eq!(follower_reg.estimate(&200), None);
    assert_eq!(follower_reg.global_estimate(), primary_reg.global_estimate());
    assert_live_state_identical(&primary_reg, &follower_reg);
    resumed.shutdown();
    primary.shutdown();
}

#[test]
fn raw_subscriber_gets_a_restorable_full_sync_image() {
    use std::io::Write;

    let (primary, primary_reg) = replicating_server(ReplicationConfig::default());
    let mut producer = SketchClient::connect(primary.local_addr()).unwrap();
    for key in 0u64..12 {
        let words: Vec<u32> = (0..300u32).map(|w| w.wrapping_mul(key as u32 + 13)).collect();
        producer.insert_batch(key, &words).unwrap();
    }

    // Hand-rolled follower: subscribe at cursor 0, read one frame.
    let mut raw = TcpStream::connect(primary.local_addr()).unwrap();
    raw.write_all(&Request::Subscribe { epoch: 0, cursor: 0, wire: protocol::DELTA_WIRE_V3 }.encode()).unwrap();
    match protocol::read_response(&mut raw).unwrap() {
        Response::FullSync { epoch, cursor, body } => {
            // The image is a valid HLLSNAP2 snapshot that restores a
            // fresh registry to the primary's exact state (the export
            // walks the live registry, so it holds all 12 keys whether
            // or not the capture thread has sealed them yet).
            let fresh = SketchRegistry::shared(small_cfg()).unwrap();
            assert_eq!(restore_from_bytes(&fresh, &body).unwrap(), 12);
            assert_eq!(fresh.merge_all(), primary_reg.merge_all());
            assert_eq!(fresh.global_estimate(), primary_reg.global_estimate());
            // The sync carries the log's incarnation id, and its cursor
            // never runs ahead of what the log has sealed.
            assert_eq!(epoch, primary.replication_log().unwrap().epoch());
            assert!(cursor <= primary.replication_log().unwrap().latest_seq());
        }
        other => panic!("bootstrap must answer FullSync, got {other:?}"),
    }
    primary.shutdown();
}
