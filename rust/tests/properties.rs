//! Cross-module property tests (proptest_lite): system-level invariants
//! that no single module's unit tests pin down.

use hll_fpga::coordinator::{run_stream, CoordinatorConfig};
use hll_fpga::fpga::ParallelHll;
use hll_fpga::hll::{estimate, HashKind, HllConfig, HllSketch};
use hll_fpga::proptest_lite::Runner;

#[test]
fn any_slicing_any_batching_same_sketch() {
    // The fundamental Fig-3 invariant, fuzzed: for random streams, any
    // (pipelines, batch_size) coordinator configuration produces the
    // same register file as the serial sketch.
    Runner::new("slicing_invariance").cases(20).run(|g| {
        let n = g.usize_in(0..=5000);
        let words: Vec<u32> = (0..n).map(|_| g.u32()).collect();
        let pipelines = g.usize_in(1..=8);
        let batch_size = g.usize_in(1..=2048);
        let cfg = CoordinatorConfig {
            pipelines,
            batch_size,
            queue_depth: g.usize_in(1..=4),
            ..CoordinatorConfig::default()
        };
        let summary = run_stream(cfg, None, &words).unwrap();
        let mut serial = HllSketch::new(cfg.hll);
        serial.insert_batch(&words);
        assert_eq!(summary.sketch, serial, "pipelines={pipelines} batch={batch_size} n={n}");
    });
}

#[test]
fn fpga_engine_equals_software_for_any_k() {
    Runner::new("fpga_vs_software").cases(15).run(|g| {
        let n = g.usize_in(0..=3000);
        let words: Vec<u32> = (0..n).map(|_| g.u32()).collect();
        let k = g.usize_in(1..=16);
        let cfg = HllConfig::PAPER;
        let mut engine = ParallelHll::new(cfg, k);
        engine.feed(&words);
        let result = engine.finish();
        let mut sw = HllSketch::new(cfg);
        sw.insert_batch(&words);
        assert_eq!(result.sketch, sw, "k={k} n={n}");
    });
}

#[test]
fn estimate_never_nan_or_negative() {
    // Any syntactically valid register file must produce a finite,
    // non-negative estimate — all four correction branches included.
    Runner::new("estimate_total_function").cases(60).run(|g| {
        let p = *g.choose(&[4u8, 8, 12, 14, 16]);
        let h = if g.bool() { HashKind::H32 } else { HashKind::H64 };
        let cfg = HllConfig::new(p, h).unwrap();
        let max_rank = cfg.max_rank();
        let regs: Vec<u8> = (0..cfg.m())
            .map(|_| g.u32_in(0..=max_rank as u32) as u8)
            .collect();
        let b = estimate(&cfg, &regs);
        assert!(b.estimate.is_finite(), "{cfg:?}");
        assert!(b.estimate >= 0.0, "{cfg:?}");
        assert!(b.raw.is_finite() && b.raw > 0.0);
        assert!(b.zero_registers <= cfg.m());
    });
}

#[test]
fn serialization_roundtrip_any_state() {
    Runner::new("serde_roundtrip").cases(30).run(|g| {
        let p = *g.choose(&[4u8, 10, 16]);
        let h = if g.bool() { HashKind::H32 } else { HashKind::H64 };
        let cfg = HllConfig::new(p, h).unwrap();
        let mut s = HllSketch::new(cfg);
        let n = g.usize_in(0..=2000);
        for _ in 0..n {
            s.insert_u32(g.u32());
        }
        let restored = HllSketch::from_bytes(&s.to_bytes()).unwrap();
        assert_eq!(s, restored);
    });
}

#[test]
fn merge_of_subsets_never_exceeds_whole() {
    // Monotonicity across the merge lattice: register-wise, merged
    // partials equal the whole-stream sketch (tested elsewhere) and any
    // partial is register-wise <= the whole.
    Runner::new("merge_monotone").cases(20).run(|g| {
        let n = g.usize_in(1..=4000);
        let words: Vec<u32> = (0..n).map(|_| g.u32()).collect();
        let split = g.usize_in(0..=n);
        let cfg = HllConfig::PAPER;
        let mut whole = HllSketch::new(cfg);
        whole.insert_batch(&words);
        let mut part = HllSketch::new(cfg);
        part.insert_batch(&words[..split]);
        for (pr, wr) in part.registers().iter().zip(whole.registers()) {
            assert!(pr <= wr);
        }
    });
}

#[test]
fn duplicate_saturation() {
    // Feeding the same multiset twice (any order) never changes state.
    Runner::new("duplicate_saturation").cases(20).run(|g| {
        let n = g.usize_in(1..=2000);
        let mut words: Vec<u32> = (0..n).map(|_| g.u32()).collect();
        let cfg = HllConfig::new(12, HashKind::H64).unwrap();
        let mut s = HllSketch::new(cfg);
        s.insert_batch(&words);
        let snapshot = s.clone();
        // Re-insert in a different order.
        words.reverse();
        s.insert_batch(&words);
        assert_eq!(s, snapshot);
    });
}
