//! Cross-layer integration: the PJRT-executed JAX/Pallas artifacts must
//! agree with the native Rust implementation — bit-exact registers,
//! estimate to f64 round-off.
//!
//! Requires `make artifacts`; tests are skipped (with a note) otherwise.

use hll_fpga::coordinator::{run_keyed_stream, run_keyed_stream_with_engine, CoordinatorConfig};
use hll_fpga::hll::{HashKind, HllConfig, HllSketch};
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::runtime::{Engine, EngineKind, Manifest, NativeEngine, XlaEngine, XlaService};
use hll_fpga::util::Xoshiro256StarStar;
use std::sync::Arc;

fn artifacts_ready() -> bool {
    let ok = Manifest::default_dir().join("manifest.tsv").exists();
    if !ok {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    ok
}

fn service() -> XlaService {
    XlaService::start().expect("start xla device service")
}

#[test]
fn registers_bit_exact_paper_config() {
    if !artifacts_ready() {
        return;
    }
    let svc = service();
    let cfg = HllConfig::PAPER;
    let xla = XlaEngine::new(svc.handle(), cfg, 8192).unwrap();
    let native = NativeEngine;

    let mut rng = Xoshiro256StarStar::seed_from_u64(0xF00D);
    // Multiple batch sizes incl. non-multiples of the artifact shapes.
    for (round, n) in [8192usize, 1024, 3000, 12345, 1].into_iter().enumerate() {
        let batch: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        let mut s_native = HllSketch::new(cfg);
        let mut s_xla = HllSketch::new(cfg);
        native.aggregate(&batch, &mut s_native).unwrap();
        xla.aggregate(&batch, &mut s_xla).unwrap();
        assert_eq!(
            s_native.registers(),
            s_xla.registers(),
            "register mismatch at round {round} (n={n})"
        );

        let e_native = native.estimate(&s_native).unwrap();
        let e_xla = xla.estimate(&s_xla).unwrap();
        assert_eq!(e_native.zero_registers, e_xla.zero_registers);
        let rel = (e_native.estimate - e_xla.estimate).abs() / e_native.estimate.max(1.0);
        assert!(rel < 1e-9, "estimate drift {rel} at round {round}");
    }
}

#[test]
fn registers_accumulate_across_calls() {
    if !artifacts_ready() {
        return;
    }
    let svc = service();
    let cfg = HllConfig::PAPER;
    let xla = XlaEngine::new(svc.handle(), cfg, 1024).unwrap();
    let native = NativeEngine;

    let mut rng = Xoshiro256StarStar::seed_from_u64(0xBEEF);
    let mut s_native = HllSketch::new(cfg);
    let mut s_xla = HllSketch::new(cfg);
    for _ in 0..5 {
        let batch: Vec<u32> = (0..1024).map(|_| rng.next_u32()).collect();
        native.aggregate(&batch, &mut s_native).unwrap();
        xla.aggregate(&batch, &mut s_xla).unwrap();
    }
    assert_eq!(s_native.registers(), s_xla.registers());
}

#[test]
fn variant_configs_agree() {
    if !artifacts_ready() {
        return;
    }
    let svc = service();
    let native = NativeEngine;
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xCAFE);
    for (p, h) in [(16u8, HashKind::H32), (14, HashKind::H64)] {
        let cfg = HllConfig::new(p, h).unwrap();
        let xla = XlaEngine::new(svc.handle(), cfg, 8192).unwrap();
        let batch: Vec<u32> = (0..8192).map(|_| rng.next_u32()).collect();
        let mut s_native = HllSketch::new(cfg);
        let mut s_xla = HllSketch::new(cfg);
        native.aggregate(&batch, &mut s_native).unwrap();
        xla.aggregate(&batch, &mut s_xla).unwrap();
        assert_eq!(
            s_native.registers(),
            s_xla.registers(),
            "mismatch for p={p} H={}",
            h.bits()
        );
    }
}

#[test]
fn merge_artifact_matches_native() {
    if !artifacts_ready() {
        return;
    }
    let svc = service();
    let cfg = HllConfig::PAPER;
    let xla = XlaEngine::new(svc.handle(), cfg, 1024).unwrap();
    let native = NativeEngine;

    let mut rng = Xoshiro256StarStar::seed_from_u64(0xD00D);
    let mk = |rng: &mut Xoshiro256StarStar| {
        let mut s = HllSketch::new(cfg);
        let batch: Vec<u32> = (0..2048).map(|_| rng.next_u32()).collect();
        native.aggregate(&batch, &mut s).unwrap();
        s
    };
    let a = mk(&mut rng);
    let b = mk(&mut rng);

    let mut m_native = a.clone();
    native.merge(&mut m_native, &b).unwrap();
    let mut m_xla = a.clone();
    xla.merge(&mut m_xla, &b).unwrap();
    assert_eq!(m_native.registers(), m_xla.registers());
}

#[test]
fn empty_batch_is_noop() {
    if !artifacts_ready() {
        return;
    }
    let svc = service();
    let cfg = HllConfig::PAPER;
    let xla = XlaEngine::new(svc.handle(), cfg, 8192).unwrap();
    let mut s = HllSketch::new(cfg);
    xla.aggregate(&[], &mut s).unwrap();
    assert_eq!(s.zero_registers(), cfg.m());
}

fn keyed_pairs(n: usize, keys: u64, seed: u64) -> Vec<(u64, u32)> {
    let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
    (0..n).map(|_| (rng.next_u64_below(keys), rng.next_u32())).collect()
}

fn fresh_registry() -> Arc<SketchRegistry<u64>> {
    SketchRegistry::shared(RegistryConfig { shards: 16, ..RegistryConfig::default() }).unwrap()
}

/// Keyed batched ingest through the native engine backend must land the
/// registry in the same state as the direct registry backend: identical
/// union registers and — the Ertl estimator being a pure function of
/// each key's register file — identical per-key estimates. Ungated: the
/// native engine needs no artifacts.
#[test]
fn keyed_batched_ingest_native_engine_matches_registry_path() {
    let pairs = keyed_pairs(40_000, 300, 0x5EED);
    let cfg = CoordinatorConfig { pipelines: 4, batch_size: 1024, ..Default::default() };

    let direct = fresh_registry();
    run_keyed_stream(&cfg, direct.clone(), &pairs).unwrap();
    let engined = fresh_registry();
    run_keyed_stream_with_engine(&cfg, engined.clone(), None, &pairs).unwrap();

    assert_eq!(engined.len(), direct.len());
    assert_eq!(engined.merge_all(), direct.merge_all());
    assert_eq!(engined.global_estimate(), direct.global_estimate());
    for (key, est) in direct.estimates() {
        assert_eq!(engined.estimate(&key), Some(est), "key {key}");
    }
}

/// Same parity through the XLA engine backend: keyed runs aggregated by
/// the AOT Pallas artifacts, max-merged into the registry.
#[test]
fn keyed_batched_ingest_xla_engine_matches_registry_path() {
    if !artifacts_ready() {
        return;
    }
    let svc = service();
    let pairs = keyed_pairs(20_000, 100, 0xFACE);
    let cfg = CoordinatorConfig {
        pipelines: 2,
        batch_size: 2048,
        engine: EngineKind::Xla,
        ..Default::default()
    };

    let direct = fresh_registry();
    // The registry backend ignores cfg.engine; same routing either way.
    run_keyed_stream(&cfg, direct.clone(), &pairs).unwrap();
    let engined = fresh_registry();
    run_keyed_stream_with_engine(&cfg, engined.clone(), Some(svc.handle()), &pairs).unwrap();

    assert_eq!(engined.len(), direct.len());
    assert_eq!(engined.merge_all(), direct.merge_all());
    for (key, est) in direct.estimates() {
        assert_eq!(engined.estimate(&key), Some(est), "key {key}");
    }
}

#[test]
fn estimate_accuracy_through_xla_path() {
    if !artifacts_ready() {
        return;
    }
    let svc = service();
    let cfg = HllConfig::PAPER;
    let xla = XlaEngine::new(svc.handle(), cfg, 65536).unwrap();
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xACE);
    let n = 200_000usize;
    let mut seen = std::collections::HashSet::with_capacity(n);
    while seen.len() < n {
        seen.insert(rng.next_u32());
    }
    let batch: Vec<u32> = seen.into_iter().collect();
    let mut s = HllSketch::new(cfg);
    xla.aggregate(&batch, &mut s).unwrap();
    let est = xla.estimate(&s).unwrap().estimate;
    let rel = (est - n as f64).abs() / n as f64;
    assert!(rel < 0.02, "xla-path estimate {est} vs {n}: rel {rel}");
}
