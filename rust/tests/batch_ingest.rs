//! Differential tests for the batch-first ingest hot path: every batch
//! entry point must be *bit-exact* with the word-at-a-time reference —
//! not just estimates, but sketch tiers, memory accounting and the
//! replication deltas a dirty-tracking drain produces. The batch path
//! restructures hashing (one tight loop), shard routing (group-by-key
//! runs) and register stores (run folds under one lock), so these tests
//! are the contract that none of that restructuring is observable.

use hll_fpga::hll::{HllConfig, HllSketch};
use hll_fpga::registry::{RegistryConfig, SketchDelta, SketchRegistry};
use hll_fpga::util::Xoshiro256StarStar;

fn registry(shards: usize) -> SketchRegistry<u64> {
    SketchRegistry::new(RegistryConfig {
        hll: HllConfig::PAPER,
        shards,
        track_global: true,
        ..RegistryConfig::default()
    })
    .unwrap()
}

/// Drain both registries and compare delta-for-delta. Shard iteration
/// and in-shard map order are nondeterministic, so entries sort by key
/// first — *stably*, because a tombstone-then-full pair for one key is
/// two entries whose relative order is part of the contract.
fn assert_drains_equal(batch: &SketchRegistry<u64>, scalar: &SketchRegistry<u64>, ctx: &str) {
    let mut a = batch.drain_dirty_deltas();
    let mut b = scalar.drain_dirty_deltas();
    a.sort_by_key(|e| e.0);
    b.sort_by_key(|e| e.0);
    assert_eq!(a, b, "{ctx}: drained deltas diverge");
}

/// Full-state comparison: per-key estimates, union registers, global
/// union, and the stats block (tier counts, words, memory accounting —
/// batch ingest must not even change sparse-capacity growth cadence).
fn assert_registries_equal(batch: &SketchRegistry<u64>, scalar: &SketchRegistry<u64>, ctx: &str) {
    assert_eq!(batch.len(), scalar.len(), "{ctx}: key count");
    assert_eq!(batch.merge_all(), scalar.merge_all(), "{ctx}: union registers");
    assert_eq!(batch.global_sketch(), scalar.global_sketch(), "{ctx}: global union");
    for (key, est) in scalar.estimates() {
        assert_eq!(batch.estimate(&key), Some(est), "{ctx}: key {key}");
    }
    let (bs, ss) = (batch.stats(), scalar.stats());
    assert_eq!(bs.words(), ss.words(), "{ctx}: words accounting");
    assert_eq!(bs.sparse_keys(), ss.sparse_keys(), "{ctx}: sparse tier population");
    assert_eq!(bs.packed_keys(), ss.packed_keys(), "{ctx}: packed tier population");
    assert_eq!(bs.dense_keys(), ss.dense_keys(), "{ctx}: dense tier population");
    assert_eq!(bs.memory_bytes(), ss.memory_bytes(), "{ctx}: memory accounting");
}

#[test]
fn batched_pairs_match_scalar_word_at_a_time_with_dirty_drains() {
    let batch = registry(8);
    let scalar = registry(8);
    batch.enable_dirty_tracking();
    scalar.enable_dirty_tracking();

    let mut rng = Xoshiro256StarStar::seed_from_u64(0xBA7C);
    // 250 keys of mixed weight: key 0 is heavy enough to promote out of
    // sparse mid-stream, the rest stay small.
    let pairs: Vec<(u64, u32)> = (0..30_000)
        .map(|_| {
            let key = if rng.next_u32() % 3 == 0 { 0 } else { rng.next_u64_below(250) };
            (key, rng.next_u32())
        })
        .collect();

    // Interleave drains with ingest so deltas are compared at several
    // capture points, not only after everything settled.
    for (i, chunk) in pairs.chunks(1_000).enumerate() {
        batch.ingest_pairs(chunk);
        for &(k, w) in chunk {
            scalar.ingest(k, &[w]);
        }
        if i % 5 == 4 {
            assert_drains_equal(&batch, &scalar, &format!("chunk {i}"));
        }
    }
    assert_drains_equal(&batch, &scalar, "final drain");
    assert_registries_equal(&batch, &scalar, "after full stream");
}

#[test]
fn one_key_promotes_sparse_to_packed_inside_a_single_batch() {
    let batch = registry(4);
    let scalar = registry(4);
    batch.enable_dirty_tracking();
    scalar.enable_dirty_tracking();

    // 60k distinct random words blow past the sparse budget well inside
    // one call: the promotion happens mid-batch on the batch path and
    // mid-stream on the scalar path, and both must land the same tier
    // at the same word with the same dirty state (Full — the promotion
    // ran through sparse inserts, which register tracking cannot see).
    let mut rng = Xoshiro256StarStar::seed_from_u64(0x9E0);
    let words: Vec<u32> = (0..60_000).map(|_| rng.next_u32()).collect();
    batch.ingest(7, &words);
    for &w in &words {
        scalar.ingest(7, &[w]);
    }
    assert_eq!(batch.stats().packed_keys(), 1, "heavy key must be packed");
    let drained = batch.drain_dirty_deltas();
    assert_eq!(drained.len(), 1);
    assert!(
        matches!(drained[0].1, SketchDelta::Full(_)),
        "promotion through sparse must drain Full, got {:?}",
        drained[0].1
    );
    let _ = scalar.drain_dirty_deltas();
    assert_registries_equal(&batch, &scalar, "after one-batch promotion");
}

#[test]
fn dense_key_batch_runs_drain_identical_register_diffs() {
    let batch = registry(8);
    let scalar = registry(8);
    batch.enable_dirty_tracking();
    scalar.enable_dirty_tracking();

    // Build a register file the packed tier cannot hold: alternating
    // far-apart values defeat its 7-wide offset window, so from_dense
    // lands the key in the dense tier on both registries.
    let cfg = HllConfig::PAPER;
    let mut bimodal = HllSketch::new(cfg);
    for idx in 0..cfg.m() {
        bimodal.update_register(idx, if idx % 2 == 0 { 1 } else { 40 });
    }
    batch.merge_sketch(9, bimodal.clone()).unwrap();
    scalar.merge_sketch(9, bimodal).unwrap();
    assert_eq!(batch.stats().dense_keys(), 1, "bimodal file must resident dense");
    // Clear the merge's Full markers so the next drain shows only what
    // the ingest below changes.
    assert_drains_equal(&batch, &scalar, "post-merge drain");

    // Now stream keyed batches over the dense key (plus bystanders):
    // the dense arm of the run fold captures changed registers in bulk,
    // and the drained diff must match the scalar per-word capture
    // byte-for-byte.
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xD1FF);
    let pairs: Vec<(u64, u32)> = (0..8_000)
        .map(|_| {
            let key = if rng.next_u32() % 2 == 0 { 9 } else { rng.next_u64_below(10) };
            (key, rng.next_u32())
        })
        .collect();
    batch.ingest_pairs(&pairs);
    for &(k, w) in &pairs {
        scalar.ingest(k, &[w]);
    }

    let mut drained = batch.drain_dirty_deltas();
    drained.sort_by_key(|e| e.0);
    let dense_delta = drained.iter().find(|(k, _)| *k == 9).expect("dense key drained");
    assert!(
        matches!(dense_delta.1, SketchDelta::RegisterDiff(_)),
        "dense key must drain a register diff, got {:?}",
        dense_delta.1
    );
    let mut scalar_drained = scalar.drain_dirty_deltas();
    scalar_drained.sort_by_key(|e| e.0);
    assert_eq!(drained, scalar_drained, "dense diff capture diverges");
    assert_registries_equal(&batch, &scalar, "after dense-tier batches");
}

#[test]
fn evicted_then_recreated_key_drains_tombstone_before_full_in_batch() {
    let batch = registry(4);
    let scalar = registry(4);
    batch.enable_dirty_tracking();
    scalar.enable_dirty_tracking();

    for reg in [&batch, &scalar] {
        reg.ingest(5, &[1, 2, 3]);
    }
    assert_drains_equal(&batch, &scalar, "setup drain");

    // Evict, then re-create through a *batch* that also carries other
    // keys: the batch path's rare Evicted arm must produce the same
    // tombstone-then-full pair the scalar path does.
    batch.evict(&5);
    scalar.evict(&5);
    let pairs: Vec<(u64, u32)> = vec![(5, 9), (6, 11), (5, 10), (6, 12), (5, 13)];
    batch.ingest_pairs(&pairs);
    for &(k, w) in &pairs {
        scalar.ingest(k, &[w]);
    }

    let mut drained = batch.drain_dirty_deltas();
    drained.sort_by_key(|e| e.0);
    let key5: Vec<&SketchDelta> = drained.iter().filter(|(k, _)| *k == 5).map(|(_, d)| d).collect();
    assert_eq!(key5.len(), 2, "evict + recreate is two entries");
    assert_eq!(*key5[0], SketchDelta::Tombstone, "tombstone must precede the resend");
    assert!(matches!(*key5[1], SketchDelta::Full(_)));
    let mut scalar_drained = scalar.drain_dirty_deltas();
    scalar_drained.sort_by_key(|e| e.0);
    assert_eq!(drained, scalar_drained);
    assert_registries_equal(&batch, &scalar, "after evict/recreate batch");
}

#[test]
fn sharded_and_routed_entry_points_match_pairs() {
    // The coordinator-facing entry points (`ingest_sharded`,
    // `ingest_routed_run`) must agree with `ingest_pairs` and the
    // scalar path for the same stream.
    let by_pairs = registry(8);
    let by_sharded = registry(8);
    let by_routed = registry(8);

    let mut rng = Xoshiro256StarStar::seed_from_u64(0x570);
    let pairs: Vec<(u64, u32)> =
        (0..20_000).map(|_| (rng.next_u64_below(120), rng.next_u32())).collect();

    by_pairs.ingest_pairs(&pairs);

    // Group by shard (preserving input order per key) the way a keyed
    // worker would, then push whole shard groups through each routed
    // entry point.
    let shards = by_sharded.config().shards;
    let mut grouped: Vec<Vec<(u64, u32)>> = vec![Vec::new(); shards];
    for &(k, w) in &pairs {
        grouped[by_sharded.shard_of(&k)].push((k, w));
    }
    for (shard, group) in grouped.iter().enumerate() {
        if group.is_empty() {
            continue;
        }
        by_sharded.ingest_sharded(shard, group);
        let routed: Vec<(usize, u64, u32)> =
            group.iter().map(|&(k, w)| (shard, k, w)).collect();
        by_routed.ingest_routed_run(&routed);
    }

    for (key, est) in by_pairs.estimates() {
        assert_eq!(by_sharded.estimate(&key), Some(est), "sharded: key {key}");
        assert_eq!(by_routed.estimate(&key), Some(est), "routed: key {key}");
    }
    assert_eq!(by_pairs.merge_all(), by_sharded.merge_all());
    assert_eq!(by_pairs.merge_all(), by_routed.merge_all());
    assert_eq!(by_pairs.stats().words(), by_sharded.stats().words());
    assert_eq!(by_pairs.stats().words(), by_routed.stats().words());
}
