//! Hostile-IO and scale tests for the event-driven serving core, over
//! real loopback TCP sockets: slow-loris clients trickling one byte per
//! frame, pipelining clients that refuse to read replies (write
//! backpressure must not wedge other connections), subscribers that
//! never drain their stream, hundreds of concurrent idle connections on
//! a single loop thread, idle-timeout reaping, and the client's typed
//! socket timeouts.
//!
//! Every scenario runs once per kernel [`PollerBackend`] this host
//! supports (`epoll` + `poll` on Linux, `poll` elsewhere): the hostile
//! IO must be survived by each backend, not just the default.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hll_fpga::hll::{HashKind, HllConfig};
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::replica::ReplicationConfig;
use hll_fpga::server::{
    protocol, ClientError, PollerBackend, Request, Response, ServerConfig, SketchClient,
    SketchServer,
};

fn start_server(cfg: ServerConfig) -> (SketchServer, Arc<SketchRegistry<u64>>) {
    let registry = SketchRegistry::shared(RegistryConfig {
        shards: 16,
        ..RegistryConfig::default()
    })
    .unwrap();
    let server = SketchServer::start("127.0.0.1:0", registry.clone(), cfg).unwrap();
    (server, registry)
}

/// Run `test` once per available poller backend, passing a base
/// `ServerConfig` pinned to that backend (tests layer their own fields
/// on top with struct update syntax).
fn for_each_backend(test: impl Fn(ServerConfig)) {
    for &backend in PollerBackend::available() {
        eprintln!("--- poller backend: {} ---", backend.label());
        test(ServerConfig { poller_backend: backend, ..ServerConfig::default() });
    }
}

fn wait_for(mut cond: impl FnMut() -> bool, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(20);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn slow_loris_one_byte_per_write_is_served_not_parked() {
    for_each_backend(|cfg| {
        let (server, _registry) = start_server(cfg);
        let mut raw = TcpStream::connect(server.local_addr()).unwrap();
        raw.set_nodelay(true).unwrap();

        // A ping frame trickled one byte per write: the decoder must
        // reassemble and answer it (the blocking server parked a thread
        // in read_exact for the whole trickle; the loop just buffers 8
        // bytes).
        for &b in &Request::Ping.encode() {
            raw.write_all(&[b]).unwrap();
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(protocol::read_response(&mut raw).unwrap(), Response::Pong);

        // Same treatment for a frame with a payload.
        for &b in &Request::InsertBatch { key: 9, words: vec![1, 2, 3] }.encode() {
            raw.write_all(&[b]).unwrap();
            std::thread::sleep(Duration::from_millis(2));
        }
        match protocol::read_response(&mut raw).unwrap() {
            Response::Ingested { words } => assert_eq!(words, 3),
            other => panic!("expected Ingested, got {other:?}"),
        }

        // ≥ 1, not == 2: a heavily-delayed CI scheduler could let one
        // frame's bytes coalesce into a single read, but 28 bytes over
        // ~56 ms of trickling cannot all land in one.
        let stats = server.stats();
        assert!(
            stats.partial_frames_resumed >= 1,
            "trickled frames must count as resumed partial reads, got {}",
            stats.partial_frames_resumed
        );
        assert_eq!(stats.error_frames, 0);
        server.shutdown();
    });
}

#[test]
fn pipelining_client_that_never_reads_cannot_wedge_other_connections() {
    for_each_backend(|cfg| {
        let (server, _registry) = start_server(cfg);
        let addr = server.local_addr();

        // A client that floods pipelined Stats requests and reads
        // nothing: 50k requests → ~2.4 MiB of replies, far past the
        // server's backpressure threshold and any socket buffer, so the
        // server is guaranteed to park this connection's replies in its
        // outbound queue and flip its read interest off — without
        // blocking the loop thread. The flood runs on its own thread
        // (its blocking writes are *supposed* to stall once the server
        // stops reading from it).
        let total = 50_000usize;
        let hog = TcpStream::connect(addr).unwrap();
        hog.set_nodelay(true).unwrap();
        let mut hog_write = hog.try_clone().unwrap();
        let writer = std::thread::spawn(move || {
            let frame = Request::Stats.encode();
            let mut burst = Vec::with_capacity(frame.len() * 1_000);
            for _ in 0..1_000 {
                burst.extend_from_slice(&frame);
            }
            for _ in 0..total / 1_000 {
                hog_write.write_all(&burst).unwrap();
            }
        });

        // While the flood is in progress (and the hog's unread replies
        // pin its connection in the paused state), a well-behaved client
        // on the same single loop thread is served normally, repeatedly.
        let mut polite = SketchClient::connect(addr).unwrap();
        for round in 0..20 {
            polite.ping().unwrap();
            polite.insert_batch(1, &[round, round + 1]).unwrap();
            assert!(polite.estimate(1).unwrap().is_some());
            std::thread::sleep(Duration::from_millis(5));
        }

        // Now drain the hog's replies: every one of the 50k must
        // arrive, in order, none lost to the pause/resume cycle.
        let mut hog_read = hog;
        hog_read.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        for i in 0..total {
            match protocol::read_response(&mut hog_read) {
                Ok(Response::Stats(_)) => {}
                other => panic!("reply {i}: expected Stats, got {other:?}"),
            }
        }
        writer.join().unwrap();
        let stats = server.stats();
        assert!(stats.frames >= total as u64);
        assert_eq!(stats.error_frames, 0);
        server.shutdown();
    });
}

#[test]
fn half_close_after_backpressured_pipeline_still_answers_every_request() {
    // Pipeline enough Stats requests to queue well past the server's
    // read-pause threshold, send FIN (shutdown the write half), and
    // only then read: every single reply must still arrive — the
    // half-close must not discard requests the decoder had buffered
    // while reads were paused — followed by a clean EOF.
    for_each_backend(|cfg| {
        let (server, _registry) = start_server(cfg);
        let mut raw = TcpStream::connect(server.local_addr()).unwrap();
        raw.set_nodelay(true).unwrap();
        let total = 10_000usize;
        let frame = Request::Stats.encode();
        let mut wire = Vec::with_capacity(frame.len() * total);
        for _ in 0..total {
            wire.extend_from_slice(&frame);
        }
        raw.write_all(&wire).unwrap();
        raw.shutdown(std::net::Shutdown::Write).unwrap();

        raw.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        for i in 0..total {
            match protocol::read_response(&mut raw) {
                Ok(Response::Stats(_)) => {}
                other => panic!("reply {i}: expected Stats, got {other:?}"),
            }
        }
        let mut tail = [0u8; 8];
        match raw.read(&mut tail) {
            Ok(0) => {}
            other => panic!("expected EOF after the final reply, got {other:?}"),
        }
        wait_for(|| server.stats().connections_open == 0, "the half-closed conn to be reaped");
        server.shutdown();
    });
}

#[test]
fn subscriber_that_never_reads_does_not_wedge_ingest_or_shutdown() {
    for_each_backend(|cfg| {
        let registry = SketchRegistry::shared(RegistryConfig {
            hll: HllConfig::new(12, HashKind::H64).unwrap(),
            shards: 16,
            ..RegistryConfig::default()
        })
        .unwrap();
        let server = SketchServer::start(
            "127.0.0.1:0",
            registry.clone(),
            ServerConfig {
                replication: Some(ReplicationConfig {
                    capture_interval: Duration::from_millis(5),
                    ..ReplicationConfig::default()
                }),
                ..cfg
            },
        )
        .unwrap();

        // A subscriber that sends SUBSCRIBE and then never reads a
        // byte: its stream backs up (bounded by the pump's byte budget
        // and the socket buffers), which must not stall the capture
        // thread, the loop, or other connections.
        let mut dead_sub = TcpStream::connect(server.local_addr()).unwrap();
        dead_sub
            .write_all(
                &Request::Subscribe { epoch: 0, cursor: 0, wire: protocol::DELTA_WIRE_V3 }
                    .encode(),
            )
            .unwrap();

        let mut producer = SketchClient::connect(server.local_addr()).unwrap();
        for key in 0u64..200 {
            let words: Vec<u32> =
                (0..400u32).map(|w| w.wrapping_mul(key as u32 * 37 + 11)).collect();
            producer.insert_batch(key, &words).unwrap();
        }
        // The registry took everything and queries stay live while the
        // dead subscriber's bytes rot in its buffers.
        assert_eq!(registry.len(), 200);
        assert!(producer.estimate(7).unwrap().is_some());
        wait_for(|| server.stats().full_syncs_sent >= 1, "bootstrap full sync to be queued");

        // Graceful shutdown must complete despite the wedged stream
        // (the old server's blocking write path could park here
        // forever).
        drop(dead_sub);
        server.shutdown();
    });
}

/// Best-effort `RLIMIT_NOFILE` raise so the 500-connection test has fd
/// headroom for both socket ends in one process; returns the resulting
/// soft limit.
#[cfg(target_os = "linux")]
fn raise_nofile_limit() -> u64 {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut r = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
            return 0;
        }
        let want = r.max.min(8_192);
        if r.cur < want {
            let bumped = RLimit { cur: want, max: r.max };
            let _ = setrlimit(RLIMIT_NOFILE, &bumped);
            let _ = getrlimit(RLIMIT_NOFILE, &mut r);
        }
        r.cur
    }
}

#[cfg(not(target_os = "linux"))]
fn raise_nofile_limit() -> u64 {
    u64::MAX
}

#[test]
fn one_loop_thread_sustains_five_hundred_concurrent_connections() {
    // Both socket ends live in this process: ~2 fds per connection plus
    // harness overhead. Skip (loudly) only if the fd limit cannot cover
    // it even after a raise attempt.
    let limit = raise_nofile_limit();
    if limit < 1_200 {
        eprintln!("skipping: RLIMIT_NOFILE={limit} is too low for 2×520 sockets");
        return;
    }

    for_each_backend(|cfg| {
        let (server, _registry) = start_server(ServerConfig {
            event_loop_threads: 1,
            max_connections: 2_048,
            ..cfg
        });
        let addr = server.local_addr();

        // Open 520 connections and keep every one alive and idle.
        let total = 520usize;
        let mut socks: Vec<TcpStream> = Vec::with_capacity(total);
        for i in 0..total {
            let s = TcpStream::connect(addr).unwrap_or_else(|e| panic!("connect {i}: {e}"));
            s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
            socks.push(s);
        }
        wait_for(
            || server.stats().connections_open as usize >= total,
            "the loop to adopt all connections",
        );
        let stats = server.stats();
        assert!(stats.connections_peak as usize >= total);

        // Every single connection answers a ping — none starved, none
        // dropped, all multiplexed through the one loop thread.
        let ping = Request::Ping.encode();
        for (i, s) in socks.iter_mut().enumerate() {
            s.write_all(&ping).unwrap_or_else(|e| panic!("write {i}: {e}"));
            match protocol::read_response(s) {
                Ok(Response::Pong) => {}
                other => panic!("conn {i}: expected Pong, got {other:?}"),
            }
        }
        // And real work still flows while the 520 sit connected.
        let mut client = SketchClient::connect(addr).unwrap();
        client.insert_batch(42, &[1, 2, 3, 4]).unwrap();
        assert!(client.estimate(42).unwrap().is_some());

        drop(socks);
        wait_for(|| server.stats().connections_open <= 1, "closed connections to be reaped");
        server.shutdown();
    });
}

#[test]
fn idle_timeout_reaps_quiet_connections_but_not_active_ones() {
    for_each_backend(|cfg| {
        let (server, _registry) = start_server(ServerConfig {
            idle_timeout: Some(Duration::from_millis(150)),
            ..cfg
        });
        let addr = server.local_addr();

        // An idle connection is dropped after the timeout: the next
        // read observes EOF (clean close), not a hang.
        let mut quiet = TcpStream::connect(addr).unwrap();
        quiet.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        wait_for(|| server.stats().connections_open == 0, "the idle connection to be reaped");
        let mut buf = [0u8; 8];
        match quiet.read(&mut buf) {
            Ok(0) => {}
            other => panic!("expected EOF after the idle reap, got {other:?}"),
        }

        // A connection that keeps talking inside the window survives
        // far past the timeout.
        let mut chatty = SketchClient::connect(addr).unwrap();
        for _ in 0..8 {
            chatty.ping().unwrap();
            std::thread::sleep(Duration::from_millis(50));
        }
        chatty.ping().unwrap();
        server.shutdown();
    });
}

#[test]
fn client_read_timeout_is_a_typed_error_that_poisons() {
    // A listener that accepts and then never answers: the bounded
    // client must fail with Timeout, not block its caller forever.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let mut client = SketchClient::connect_with_timeouts(
        addr,
        Some(Duration::from_millis(100)),
        Some(Duration::from_millis(100)),
    )
    .unwrap();
    let (_held, _) = listener.accept().unwrap();
    let t0 = Instant::now();
    match client.ping() {
        Err(ClientError::Timeout) => {}
        other => panic!("expected Timeout, got {other:?}"),
    }
    assert!(t0.elapsed() < Duration::from_secs(5), "timeout must be bounded");
    // The timed-out reply could still arrive later: the connection is
    // poisoned until the caller reconnects.
    match client.ping() {
        Err(ClientError::Poisoned) => {}
        other => panic!("expected Poisoned after a timeout, got {other:?}"),
    }

    // Against a live server, the same bounded client works normally —
    // timeouts are a ceiling, not a latency floor — on every backend.
    for_each_backend(|cfg| {
        let (server, _registry) = start_server(cfg);
        let mut bounded = SketchClient::connect_with_timeouts(
            server.local_addr(),
            Some(Duration::from_secs(10)),
            Some(Duration::from_secs(10)),
        )
        .unwrap();
        bounded.ping().unwrap();
        bounded.insert_batch(5, &[1, 2, 3]).unwrap();
        assert!(bounded.estimate(5).unwrap().is_some());
        server.shutdown();
    });
}
