//! Distributed scale-out: HLL's "trivially parallelizable" property
//! (Section II-A) at cluster granularity — N shards sketch their local
//! streams independently; a leader gathers the 48 KiB partials over the
//! serialization format and folds them, exactly like BigQuery-style
//! scale-out (Heule et al., cited as [3]).
//!
//! Run: `cargo run --release --example distributed_merge`

use hll_fpga::hll::HllSketch;
use hll_fpga::stats::DistinctStream;
use hll_fpga::util::fmt;

fn main() {
    let shards = 8usize;
    let per_shard = 500_000u64;
    let overlap_seed = 42; // some values appear on several shards

    println!("=== distributed COUNT(DISTINCT): {shards} shards ===");

    // Each "node" sketches its local stream and ships the serialized
    // sketch (to_bytes) to the leader — 64 KiB of registers + an 11 B
    // header (version, p, hash width, seed) per shard, independent of
    // stream length.
    let mut wires: Vec<Vec<u8>> = Vec::new();
    let mut exact = std::collections::HashSet::new();
    for shard in 0..shards {
        let mut local = HllSketch::paper();
        // Half the values are shard-private, half drawn from a shared
        // pool (cross-shard duplicates the merge must not double-count).
        for v in DistinctStream::new(per_shard / 2, shard as u64 + 1000) {
            local.insert_u32(v);
            exact.insert(v);
        }
        for v in DistinctStream::new(per_shard / 2, overlap_seed) {
            local.insert_u32(v);
            exact.insert(v);
        }
        let bytes = local.to_bytes();
        println!(
            "  shard {shard}: {} values sketched, wire size {} B",
            fmt::count(per_shard),
            bytes.len()
        );
        wires.push(bytes);
    }

    // Leader: parse + fold.
    let mut global = HllSketch::paper();
    for wire in &wires {
        let partial = HllSketch::from_bytes(wire).expect("valid wire format");
        global.merge(&partial).expect("same config");
    }

    let est = global.estimate();
    let truth = exact.len() as f64;
    println!("\nglobal estimate: {est:.0}");
    println!("exact distinct:  {}", fmt::count(truth as u64));
    println!("error:           {:.3}% (sigma = 0.41%)", (est - truth).abs() / truth * 100.0);
    println!(
        "\nbytes moved to the leader: {} (vs {} values = {} raw)",
        fmt::count(wires.iter().map(|w| w.len() as u64).sum()),
        fmt::count(shards as u64 * per_shard),
        fmt::count(shards as u64 * per_shard * 4),
    );
    assert!((est - truth).abs() / truth < 0.02);
}
