//! Deployment (a): the FPGA as a PCIe co-processor (Section VI).
//!
//! Sweeps pipeline counts through the XDMA/PCIe model (Fig 4a) and runs
//! one functional multi-pipeline engine end to end, demonstrating that
//! the simulated dataflow architecture computes the exact sketch.
//!
//! Run: `cargo run --release --example pcie_coprocessor`

use hll_fpga::fpga::ParallelHll;
use hll_fpga::hll::HllConfig;
use hll_fpga::pcie::CoProcessorModel;
use hll_fpga::repro::fig4;
use hll_fpga::stats::DistinctStream;

fn main() {
    // --- Fig 4(a): throughput vs #pipelines against the PCIe bound ---
    let rows = fig4::fig4a_rows(256 << 20);
    println!("{}", fig4::render_fig4a(&rows));

    let model = CoProcessorModel::default();
    println!(
        "PCIe saturation at {} pipelines (paper: 10).\n",
        model.saturation_pipelines()
    );

    // --- Functional run: 10-pipeline engine over 2M distinct values ---
    let n = 2_000_000u64;
    let words: Vec<u32> = DistinctStream::new(n, 7).collect();
    let mut engine = ParallelHll::new(HllConfig::PAPER, 10);
    engine.feed(&words);
    let result = engine.finish();

    println!("functional 10-pipeline run over {n} distinct values:");
    println!("  estimate:          {:.0}", result.breakdown.estimate);
    println!(
        "  error:             {:.3}%",
        (result.breakdown.estimate - n as f64).abs() / n as f64 * 100.0
    );
    println!(
        "  aggregation time:  {} (simulated @322 MHz)",
        hll_fpga::util::fmt::duration_s(result.aggregation_seconds())
    );
    println!(
        "  drain (constant):  {}",
        hll_fpga::util::fmt::duration_s(result.clock.cycles_to_seconds(result.drain_cycles))
    );
    println!(
        "  sim throughput:    {}",
        hll_fpga::util::fmt::gbytes_per_s(result.throughput_bytes_per_s())
    );

    // Model a full co-processor invocation (PCIe transfer + compute).
    let run = model.run(&HllConfig::PAPER, 10, (n * 4) as u64);
    println!(
        "  incl. PCIe model:  {} end-to-end ({} effective)",
        hll_fpga::util::fmt::duration_s(run.total_seconds),
        hll_fpga::util::fmt::gbytes_per_s(run.throughput_bytes_per_s())
    );
}
