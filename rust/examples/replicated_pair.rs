//! Tour of the replication subsystem: a primary and a read-only
//! follower on loopback TCP, keyed ingest streaming across as delta
//! batches, a mid-stream kill + cursor resume, and the bit-exactness
//! check that makes HLL replication conflict-free by construction.
//!
//! Run: `cargo run --release --example replicated_pair`

use std::time::{Duration, Instant};

use hll_fpga::net::KeyedFlowGen;
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::replica::{FollowerConfig, FollowerServer, ReplicationConfig};
use hll_fpga::server::{ClientError, ErrorCode, ServerConfig, SketchClient, SketchServer};

fn main() {
    // --- Primary: a normal sketch server with replication enabled.
    let primary_reg = SketchRegistry::shared(RegistryConfig::default()).unwrap();
    let primary = SketchServer::start(
        "127.0.0.1:0",
        primary_reg.clone(),
        ServerConfig {
            replication: Some(ReplicationConfig {
                capture_interval: Duration::from_millis(5),
                ..ReplicationConfig::default()
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let log = primary.replication_log().unwrap();
    println!("primary serving on {}", primary.local_addr());

    // --- Follower: replicates the primary, serves read-only.
    let follower_reg = SketchRegistry::shared(RegistryConfig::default()).unwrap();
    let follower = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
    )
    .unwrap();
    println!("follower serving read-only on {}\n", follower.local_addr());

    // --- Ingest keyed zipf flows through the primary.
    let mut producer = SketchClient::connect(primary.local_addr()).unwrap();
    let batches = KeyedFlowGen::new(100, 1.07, 0xFEED).batched(100_000, 4096);
    producer.pipeline_insert(&batches[..batches.len() / 2]).unwrap();

    // --- Kill the follower mid-stream; remember its cursor.
    // Drain barrier: force-seal dirty state (`seal_all` loops past
    // in-flight background captures) and wait for the follower to
    // apply it all.
    let drain = |f: &FollowerServer| {
        let head = log.seal_all(&primary_reg, Duration::from_secs(30));
        let deadline = Instant::now() + Duration::from_secs(30);
        while f.cursor() < head {
            assert!(Instant::now() < deadline, "follower never caught up");
            std::thread::sleep(Duration::from_millis(2));
        }
    };
    drain(&follower);
    let cursor = follower.shutdown();
    println!(
        "follower killed at cursor {} of epoch {} (half the stream ingested)",
        cursor.seq, cursor.epoch
    );

    // --- The primary keeps ingesting while the follower is down...
    producer.pipeline_insert(&batches[batches.len() / 2..]).unwrap();

    // --- ...and a resumed follower catches up from its cursor: only
    // the retained delta batches ship, no second bootstrap.
    let follower = FollowerServer::start_at_cursor(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
        cursor,
    )
    .unwrap();
    drain(&follower);
    let stats = follower.stats();
    println!(
        "follower resumed: cursor {} → {}, {} delta batches applied, {} full syncs\n",
        cursor.seq, stats.cursor, stats.batches_applied, stats.full_syncs
    );

    // --- Convergence is bit-exact, per key and globally.
    let mut reader = SketchClient::connect(follower.local_addr()).unwrap();
    let mut checked = 0;
    for (key, want) in primary_reg.estimates() {
        assert_eq!(reader.estimate(key).unwrap(), Some(want), "key {key}");
        checked += 1;
    }
    assert_eq!(follower_reg.merge_all(), primary_reg.merge_all());
    println!("{checked} per-key estimates bit-identical on primary and follower");
    println!(
        "global estimate: primary {:.1} == follower {:.1}",
        primary_reg.global_estimate().unwrap(),
        reader.global_estimate().unwrap().unwrap()
    );

    // --- Writes to the follower are rejected with a typed frame.
    match reader.insert_batch(1, &[1, 2, 3]) {
        Err(ClientError::Remote { code: ErrorCode::ReadOnly, .. }) => {
            println!("write to follower rejected with typed ReadOnly error: ok")
        }
        other => panic!("expected ReadOnly, got {other:?}"),
    }

    follower.shutdown();
    primary.shutdown();
}
