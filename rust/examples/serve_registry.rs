//! End-to-end serving demo: a TCP sketch server in front of the
//! multi-tenant registry, a remote client ingesting keyed streams,
//! estimate/stats queries, eviction policies over RPC, and a full
//! snapshot → restart → restore cycle.
//!
//! Run: `cargo run --release --example serve_registry`

use std::sync::Arc;

use hll_fpga::net::KeyedFlowGen;
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::server::{EvictPolicy, ServerConfig, SketchClient, SketchServer};
use hll_fpga::util::fmt::{count, TextTable};

fn main() {
    // 1. A registry shared between ingest and queries, served over TCP.
    let registry = SketchRegistry::shared(RegistryConfig {
        shards: 32,
        ..RegistryConfig::default()
    })
    .expect("valid config");
    let snapshot_path = std::env::temp_dir().join(format!(
        "hll_serve_registry_{}.snap",
        std::process::id()
    ));
    let server = SketchServer::start(
        "127.0.0.1:0",
        registry.clone(),
        ServerConfig { snapshot_path: Some(snapshot_path.clone()), ..ServerConfig::default() },
    )
    .expect("bind loopback");
    let addr = server.local_addr();
    println!("serving the sketch registry on {addr}");

    // 2. A remote producer: 10k tenants, zipf-skewed keyed stream,
    //    pipelined ingest batches.
    let mut client = SketchClient::connect(addr).expect("connect");
    client.ping().expect("ping");
    let mut gen = KeyedFlowGen::new(10_000, 1.07, 42);
    let batches = gen.batched(200_000, usize::MAX);
    let words = client.pipeline_insert(&batches).expect("pipelined ingest");
    println!("ingested {} words across {} tenants", count(words), count(batches.len() as u64));

    // 3. Queries: hottest tenants and the global union.
    let mut table = TextTable::new(vec!["tenant", "words sent", "distinct estimate"]);
    let mut sorted: Vec<&(u64, Vec<u32>)> = batches.iter().collect();
    sorted.sort_by_key(|(_, w)| std::cmp::Reverse(w.len()));
    for (key, sent) in sorted.iter().take(5) {
        let est = client.estimate(*key).expect("estimate").unwrap_or(0.0);
        table.row(vec![key.to_string(), count(sent.len() as u64), format!("{est:.1}")]);
    }
    print!("{}", table.render());
    let global = client.global_estimate().expect("global").unwrap_or(0.0);
    println!("global distinct estimate: {global:.0}");
    let stats = client.stats().expect("stats");
    println!(
        "registry: {} keys ({} sparse / {} packed / {} dense), {} sketch-heap bytes, estimator {}",
        count(stats.keys),
        count(stats.sparse_keys),
        count(stats.packed_keys),
        count(stats.dense_keys),
        count(stats.memory_bytes),
        if stats.estimator == 0 { "ertl" } else { "legacy" },
    );

    // 4. Lifecycle over RPC: TTL sweep + memory budget.
    let aged = client.evict(EvictPolicy::Idle { max_age: 1_000_000 }).expect("ttl");
    let budget = stats.memory_bytes / 2;
    let squeezed = client
        .evict(EvictPolicy::Budget { max_memory_bytes: budget })
        .expect("budget");
    println!(
        "evicted {aged} idle tenants, then {squeezed} more to fit a {}-byte budget",
        count(budget)
    );

    // 5. Snapshot, restart, restore: the new server answers identically.
    // Probe a tenant that *survived* the evictions above, so the
    // before/after comparison is a real estimate, not None == None.
    let (probe_key, probe_before) = batches
        .iter()
        .find_map(|(key, _)| {
            client.estimate(*key).expect("probe scan").map(|est| (*key, Some(est)))
        })
        .expect("some tenant survived the evictions");
    let (snap_keys, snap_bytes) = client.snapshot().expect("snapshot");
    println!("snapshot: {} keys, {} bytes -> {}", snap_keys, count(snap_bytes), snapshot_path.display());
    drop(client);
    server.shutdown();

    let restored: Arc<SketchRegistry<u64>> = SketchRegistry::shared(RegistryConfig {
        shards: 32,
        ..RegistryConfig::default()
    })
    .expect("valid config");
    let applied =
        hll_fpga::server::restore_registry(&restored, &snapshot_path).expect("restore");
    let server2 = SketchServer::start("127.0.0.1:0", restored, ServerConfig::default())
        .expect("bind restarted server");
    let mut client2 = SketchClient::connect(server2.local_addr()).expect("reconnect");
    let probe_after = client2.estimate(probe_key).expect("probe after restore");
    println!(
        "restarted with {applied} restored keys; tenant {probe_key} estimate {} -> {} ({})",
        probe_before.unwrap_or(0.0),
        probe_after.unwrap_or(0.0),
        if probe_before == probe_after { "identical" } else { "MISMATCH" }
    );
    assert_eq!(probe_before, probe_after, "restore must be lossless");

    server2.shutdown();
    let _ = std::fs::remove_file(&snapshot_path);
}
