//! End-to-end serving demo: a TCP sketch server in front of the
//! multi-tenant registry, a remote client ingesting keyed streams,
//! estimate/stats queries, eviction policies over RPC, and a full
//! snapshot → restart → restore cycle.
//!
//! Run: `cargo run --release --example serve_registry`
//!
//! Flags:
//! - `--metrics-every N`: print the server's metrics exposition every
//!   N seconds from a background thread while the demo runs.
//! - `--smoke`: after the demo queries, scrape metrics over the wire
//!   (`MetricsDump` RPC), validate every line of the exposition, and
//!   exit nonzero if any expected series is missing or malformed —
//!   then run the trace gate: negotiate tracing, stamp one traced
//!   ingest, pull the flight recorder over `TraceDump`, and validate
//!   the request's span chain.

use std::sync::Arc;

use hll_fpga::net::KeyedFlowGen;
use hll_fpga::obs::{EventKind, Stage, EXPOSITION_HEADER};
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::server::{EvictPolicy, ServerConfig, SketchClient, SketchServer};
use hll_fpga::util::fmt::{count, TextTable};

/// Scrape metrics over the `MetricsDump` RPC and validate the text:
/// versioned header, every line machine-parseable, and the series the
/// demo must have produced all present. Exits the process on failure
/// so CI can run this as a gate.
fn metrics_smoke(client: &mut SketchClient) {
    let text = client.metrics_dump().expect("metrics dump RPC");
    let mut lines = text.lines();
    if lines.next() != Some(EXPOSITION_HEADER) {
        eprintln!("metrics smoke FAILED: missing exposition header");
        std::process::exit(1);
    }
    let mut parsed = 0usize;
    for line in lines {
        if hll_fpga::obs::registry::parse_line(line).is_none() {
            eprintln!("metrics smoke FAILED: unparseable line {line:?}");
            std::process::exit(1);
        }
        parsed += 1;
    }
    // Series the demo traffic must have produced by this point.
    let expected = [
        "rpc_total{op=\"ping\"}",
        "rpc_total{op=\"insert_batch\"}",
        "rpc_latency_ns{op=\"insert_batch\",quantile=\"0.99\"}",
        "rpc_payload_bytes{op=\"insert_batch\",quantile=\"0.5\"}",
        "loop_poll_wait_ns{loop=\"0\",quantile=\"0.99\"}",
        "server_connections_total",
        "server_words_ingested_total",
        "registry_keys",
        "registry_tier_keys{tier=\"sparse\"}",
        "registry_memory_bytes",
    ];
    for needle in expected {
        if !text.contains(needle) {
            eprintln!("metrics smoke FAILED: missing series {needle:?}");
            std::process::exit(1);
        }
    }
    println!("metrics smoke: {parsed} series lines parsed, all expected series present");
}

/// Trace gate: negotiate tracing on the live connection, stamp one
/// traced ingest, pull the flight recorder over the `TraceDump` RPC,
/// and validate the request's span chain — every stage present under
/// the stamped trace id, each begin paired with an end, begins
/// monotonic. Exits the process on failure so CI can run this as a
/// gate.
fn trace_smoke(client: &mut SketchClient) {
    match client.negotiate_tracing() {
        Ok(true) => {}
        Ok(false) => {
            eprintln!("trace smoke FAILED: live server refused the tracing probe");
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("trace smoke FAILED: negotiation error: {e}");
            std::process::exit(1);
        }
    }
    let (_, trace_id) =
        client.insert_batch_traced(424_242, &[1, 2, 3, 4, 5]).expect("traced ingest");
    if trace_id == 0 {
        eprintln!("trace smoke FAILED: negotiated connection stamped no trace id");
        std::process::exit(1);
    }
    let events = client.trace_dump().expect("trace dump RPC");
    let chain = [Stage::ClientSend, Stage::Decode, Stage::Dispatch, Stage::ShardIngest];
    let mut prev_begin = 0u64;
    for stage in chain {
        let begin = events.iter().find(|e| {
            e.trace_id == trace_id && e.stage == stage as u8 && e.kind == EventKind::Begin as u8
        });
        let Some(begin) = begin else {
            eprintln!("trace smoke FAILED: missing {} begin for trace {trace_id:016x}", stage.name());
            std::process::exit(1);
        };
        let end = events.iter().find(|e| {
            e.trace_id == trace_id && e.stage == stage as u8 && e.kind == EventKind::End as u8
        });
        let Some(end) = end else {
            eprintln!("trace smoke FAILED: missing {} end for trace {trace_id:016x}", stage.name());
            std::process::exit(1);
        };
        if end.ns < begin.ns {
            eprintln!("trace smoke FAILED: {} span ends before it begins", stage.name());
            std::process::exit(1);
        }
        if begin.ns < prev_begin {
            eprintln!("trace smoke FAILED: {} began before its upstream stage", stage.name());
            std::process::exit(1);
        }
        prev_begin = begin.ns;
    }
    println!(
        "trace smoke: trace {trace_id:016x} spans client_send -> decode -> dispatch -> \
         shard_ingest, begins monotonic"
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let metrics_every: Option<u64> = args
        .iter()
        .position(|a| a == "--metrics-every")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok());
    // 1. A registry shared between ingest and queries, served over TCP.
    let registry = SketchRegistry::shared(RegistryConfig {
        shards: 32,
        ..RegistryConfig::default()
    })
    .expect("valid config");
    let snapshot_path = std::env::temp_dir().join(format!(
        "hll_serve_registry_{}.snap",
        std::process::id()
    ));
    let server = SketchServer::start(
        "127.0.0.1:0",
        registry.clone(),
        ServerConfig { snapshot_path: Some(snapshot_path.clone()), ..ServerConfig::default() },
    )
    .expect("bind loopback");
    let addr = server.local_addr();
    println!("serving the sketch registry on {addr}");
    if let Some(secs) = metrics_every {
        // Periodic exposition dump. The registry Arc outlives the
        // server handle, so the printer keeps working across the demo's
        // restart; the detached thread dies with the process.
        let metrics = server.metrics().clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_secs(secs.max(1)));
            println!("--- metrics ---\n{}", metrics.render());
        });
    }

    // 2. A remote producer: 10k tenants, zipf-skewed keyed stream,
    //    pipelined ingest batches.
    let mut client = SketchClient::connect(addr).expect("connect");
    client.ping().expect("ping");
    let mut gen = KeyedFlowGen::new(10_000, 1.07, 42);
    let batches = gen.batched(200_000, usize::MAX);
    let words = client.pipeline_insert(&batches).expect("pipelined ingest");
    println!("ingested {} words across {} tenants", count(words), count(batches.len() as u64));

    // 3. Queries: hottest tenants and the global union.
    let mut table = TextTable::new(vec!["tenant", "words sent", "distinct estimate"]);
    let mut sorted: Vec<&(u64, Vec<u32>)> = batches.iter().collect();
    sorted.sort_by_key(|(_, w)| std::cmp::Reverse(w.len()));
    for (key, sent) in sorted.iter().take(5) {
        let est = client.estimate(*key).expect("estimate").unwrap_or(0.0);
        table.row(vec![key.to_string(), count(sent.len() as u64), format!("{est:.1}")]);
    }
    print!("{}", table.render());
    let global = client.global_estimate().expect("global").unwrap_or(0.0);
    println!("global distinct estimate: {global:.0}");
    let stats = client.stats().expect("stats");
    println!(
        "registry: {} keys ({} sparse / {} packed / {} dense), {} sketch-heap bytes, estimator {}",
        count(stats.keys),
        count(stats.sparse_keys),
        count(stats.packed_keys),
        count(stats.dense_keys),
        count(stats.memory_bytes),
        if stats.estimator == 0 { "ertl" } else { "legacy" },
    );
    if smoke {
        metrics_smoke(&mut client);
        trace_smoke(&mut client);
    }

    // 4. Lifecycle over RPC: TTL sweep + memory budget.
    let aged = client.evict(EvictPolicy::Idle { max_age: 1_000_000 }).expect("ttl");
    let budget = stats.memory_bytes / 2;
    let squeezed = client
        .evict(EvictPolicy::Budget { max_memory_bytes: budget })
        .expect("budget");
    println!(
        "evicted {aged} idle tenants, then {squeezed} more to fit a {}-byte budget",
        count(budget)
    );

    // 5. Snapshot, restart, restore: the new server answers identically.
    // Probe a tenant that *survived* the evictions above, so the
    // before/after comparison is a real estimate, not None == None.
    let (probe_key, probe_before) = batches
        .iter()
        .find_map(|(key, _)| {
            client.estimate(*key).expect("probe scan").map(|est| (*key, Some(est)))
        })
        .expect("some tenant survived the evictions");
    let (snap_keys, snap_bytes) = client.snapshot().expect("snapshot");
    println!("snapshot: {} keys, {} bytes -> {}", snap_keys, count(snap_bytes), snapshot_path.display());
    drop(client);
    server.shutdown();

    let restored: Arc<SketchRegistry<u64>> = SketchRegistry::shared(RegistryConfig {
        shards: 32,
        ..RegistryConfig::default()
    })
    .expect("valid config");
    let applied =
        hll_fpga::server::restore_registry(&restored, &snapshot_path).expect("restore");
    let server2 = SketchServer::start("127.0.0.1:0", restored, ServerConfig::default())
        .expect("bind restarted server");
    let mut client2 = SketchClient::connect(server2.local_addr()).expect("reconnect");
    let probe_after = client2.estimate(probe_key).expect("probe after restore");
    println!(
        "restarted with {applied} restored keys; tenant {probe_key} estimate {} -> {} ({})",
        probe_before.unwrap_or(0.0),
        probe_after.unwrap_or(0.0),
        if probe_before == probe_after { "identical" } else { "MISMATCH" }
    );
    assert_eq!(probe_before, probe_after, "restore must be lossless");

    server2.shutdown();
    let _ = std::fs::remove_file(&snapshot_path);
}
