//! Deployment (b): HLL on an FPGA-based NIC behind a 100 Gbit/s TCP/IP
//! stack (Section VII, Fig 5, Table IV).
//!
//! Regenerates the Table IV sweep on the discrete-event network
//! simulator and runs one functional stream through the coupled
//! NIC + multi-pipeline engine.
//!
//! Run: `cargo run --release --example network_nic`

use hll_fpga::net::{run_with_data, NicConfig};
use hll_fpga::repro::table4;
use hll_fpga::stats::DistinctStream;

fn main() {
    // --- Table IV: sustained throughput vs #pipelines ---
    let rows = table4::rows(16 << 20);
    println!("{}", table4::render(&rows));

    // --- Functional NIC run: 1M distinct values through 16 pipelines ---
    let n = 1_000_000u64;
    let words: Vec<u32> = DistinctStream::new(n, 99).collect();
    let cfg = NicConfig::paper(16);
    let run = run_with_data(&cfg, &words);
    let hll = run.hll.as_ref().expect("functional run");

    println!("functional NIC run ({n} distinct values, 16 pipelines):");
    println!(
        "  network goodput:  {}",
        hll_fpga::util::fmt::gbytes_per_s(run.throughput_bytes_per_s())
    );
    println!(
        "  drops/RTOs:       {} / {}",
        run.tcp.drops, run.tcp.timeouts
    );
    println!("  estimate:         {:.0}", hll.breakdown.estimate);
    println!(
        "  error:            {:.3}%",
        (hll.breakdown.estimate - n as f64).abs() / n as f64 * 100.0
    );
    println!(
        "  drain (constant): {}  <- the paper's 203 us",
        hll_fpga::util::fmt::duration_s(run.drain_seconds)
    );

    // The paper's Section VII headline: the NIC deployment beats the
    // 16-core CPU by ~35% at the same statistical guarantees.
    let cpu64_32t = hll_fpga::cpu_baseline::ScalingModel::paper_xeon()
        .rate(hll_fpga::hll::HashKind::H64, 32);
    println!(
        "\nNIC vs 16-core CPU (64-bit hash): {:.2}x (paper: ~1.35x)",
        run.throughput_bytes_per_s() / cpu64_32t
    );
}
