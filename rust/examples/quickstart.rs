//! Quickstart: the core HLL public API in five minutes.
//!
//! Run: `cargo run --release --example quickstart`

use hll_fpga::hll::{AdaptiveSketch, HashKind, HllConfig, HllSketch};

fn main() {
    // 1. The paper's hardware configuration: p=16, 64-bit Murmur3.
    let mut sketch = HllSketch::paper();

    // 2. Insert 32-bit stream words (the paper's data type) ...
    for v in 0u32..100_000 {
        sketch.insert_u32(v.wrapping_mul(2_654_435_761)); // distinct values
    }
    // ... and arbitrary byte strings (URLs, user IDs, ...).
    sketch.insert_bytes(b"https://systems.ethz.ch");
    sketch.insert_bytes(b"https://systems.ethz.ch"); // duplicate: no effect

    let b = sketch.estimate_breakdown();
    println!("estimate:       {:.0} (truth: 100,001)", b.estimate);
    println!("raw estimate:   {:.0}", b.raw);
    println!("correction:     {:?}", b.correction);
    println!("zero registers: {}", b.zero_registers);
    println!(
        "error:          {:.3}% (expected sigma = {:.2}%)",
        (b.estimate - 100_001.0).abs() / 100_001.0 * 100.0,
        sketch.config().standard_error() * 100.0
    );

    // 3. Distributed counting: sketches merge losslessly (Fig 3).
    let mut east = HllSketch::paper();
    let mut west = HllSketch::paper();
    for v in 0u32..50_000 {
        east.insert_u32(v);
    }
    for v in 25_000u32..75_000 {
        west.insert_u32(v); // 25k overlap
    }
    east.merge(&west).expect("same config");
    println!("\nmerged estimate: {:.0} (truth: 75,000)", east.estimate());

    // 4. Other configurations: any p in [4,16], 32- or 64-bit hash.
    let small = HllConfig::new(12, HashKind::H32).expect("valid");
    println!(
        "\np=12/H32 footprint: {:.1} KiB (paper eq. (3)), sigma {:.2}%",
        small.footprint_kib(),
        small.standard_error() * 100.0
    );

    // 5. Memory-adaptive sketch: starts sparse, upgrades to dense.
    let mut adaptive = AdaptiveSketch::new(HllConfig::PAPER);
    for v in 0u32..100 {
        adaptive.insert_u32(v);
    }
    println!(
        "adaptive (100 values): sparse={} estimate={:.1}",
        adaptive.is_sparse(),
        adaptive.estimate()
    );
}
