//! End-to-end driver: the full three-layer system on a realistic
//! workload, proving all layers compose.
//!
//! Workload: a synthetic web-access log — Zipf-distributed user IDs over
//! a large domain (the paper's motivating scenario: "how many different
//! users are utilizing a given service"). The stream is replayed through
//! the streaming coordinator twice:
//!
//!   1. `native` engine — pure-Rust pipeline workers;
//!   2. `xla` engine — workers execute the AOT-lowered JAX/Pallas
//!      artifacts via PJRT (Layer 1+2 on the data path, Python absent).
//!
//! The two register files must agree bit-exactly; the estimate is
//! compared against the exact distinct-user count; throughput of both
//! engines is reported. Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example e2e_stream [-- --quick]`

use std::collections::HashSet;

use hll_fpga::coordinator::{run_stream, CoordinatorConfig};
use hll_fpga::runtime::{EngineKind, Manifest, XlaService};
use hll_fpga::util::fmt;
use hll_fpga::util::{Xoshiro256StarStar, Zipf};

/// Generate an access log: `events` requests from a Zipf(1.07) user
/// population of `users`. Returns (stream of user-IDs, exact distinct
/// count).
fn access_log(events: usize, users: u64, seed: u64) -> (Vec<u32>, usize) {
    let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
    let zipf = Zipf::new(users, 1.07);
    // Map Zipf ranks to scattered 32-bit user IDs via an affine bijection
    // so IDs look realistic rather than being 1..users.
    let mut stream = Vec::with_capacity(events);
    let mut distinct = HashSet::new();
    for _ in 0..events {
        let rank = zipf.sample(&mut rng) as u32;
        let user_id = rank.wrapping_mul(2_654_435_761).rotate_left(13);
        stream.push(user_id);
        distinct.insert(user_id);
    }
    (stream, distinct.len())
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let events = if quick { 400_000 } else { 4_000_000 };
    let users = if quick { 100_000 } else { 1_000_000 };

    println!("=== end-to-end driver: distinct users in a web access log ===");
    println!("generating {} events from a Zipf(1.07) population of {} users...", events, users);
    let (stream, truth) = access_log(events, users, 0xACCE55);
    println!("exact distinct users: {}\n", fmt::count(truth as u64));

    let base = CoordinatorConfig {
        pipelines: 4,
        batch_size: 8192,
        ..CoordinatorConfig::default()
    };

    // --- Engine 1: native Rust workers ---
    let native = run_stream(
        CoordinatorConfig { engine: EngineKind::Native, ..base },
        None,
        &stream,
    )
    .expect("native run");
    report("native", &native, truth);

    // --- Engine 2: PJRT-executed JAX/Pallas artifacts ---
    if Manifest::default_dir().join("manifest.tsv").exists() {
        let service = XlaService::start().expect("xla service");
        let xla = run_stream(
            CoordinatorConfig { engine: EngineKind::Xla, ..base },
            Some(service.handle()),
            &stream,
        )
        .expect("xla run");
        report("xla (JAX/Pallas via PJRT)", &xla, truth);

        // --- Cross-layer verification: bit-exact register parity ---
        assert_eq!(
            native.sketch.registers(),
            xla.sketch.registers(),
            "native and XLA register files must be BIT-EXACT"
        );
        println!("[ok] native and XLA register files are bit-exact ({} registers)", 1 << 16);
        let drift = (native.estimate.estimate - xla.estimate.estimate).abs()
            / native.estimate.estimate.max(1.0);
        println!("[ok] estimate drift between engines: {drift:.2e} (f64 round-off)\n");
    } else {
        println!("(artifacts not built — run `make artifacts` to exercise the XLA engine)\n");
    }

    println!("all layers compose: L1 Pallas kernels -> L2 JAX graph -> HLO text ->");
    println!("PJRT runtime -> L3 rust coordinator, with Python never on the data path.");
}

fn report(label: &str, summary: &hll_fpga::coordinator::RunSummary, truth: usize) {
    let est = summary.estimate.estimate;
    let err = (est - truth as f64).abs() / truth as f64;
    println!("--- engine: {label} ---");
    println!("  estimate:     {est:.0} (truth {})", fmt::count(truth as u64));
    println!("  error:        {:.3}% (sigma = 0.41%)", err * 100.0);
    println!("  elapsed:      {}", fmt::duration_s(summary.elapsed.as_secs_f64()));
    println!(
        "  throughput:   {} ({:.1} Mwords/s)",
        fmt::gbytes_per_s(summary.throughput_bytes_per_s()),
        summary.metrics.words_in as f64 / summary.elapsed.as_secs_f64() / 1e6
    );
    println!("  backpressure: {} stalls", summary.metrics.backpressure_stalls);
    let busiest = summary
        .workers
        .iter()
        .map(|w| w.busy.as_secs_f64())
        .fold(0.0, f64::max);
    println!("  worker busy:  max {}\n", fmt::duration_s(busiest));
    assert!(err < 0.02, "estimate error {err} exceeds 2%");
}
