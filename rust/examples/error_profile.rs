//! The statistical profiling study of Section IV (Fig 1): relative
//! estimation error across cardinalities for (p, H) ∈ {14,16} × {32,64}.
//!
//! Run: `cargo run --release --example error_profile [-- --quick]`
//! `--quick` sweeps to 10^6 with 3 trials (CI-friendly); the default
//! goes to 10^7 with 5 trials; `--full` matches the paper's 10^9 reach.

use hll_fpga::repro::fig1::{check_claims, curves, render, Fig1Options};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let full = args.iter().any(|a| a == "--full");

    let opts = Fig1Options {
        full,
        trials: if quick { 3 } else { 5 },
        max_exp: if quick { Some(6) } else { None },
    };
    let curves = curves(&opts);

    println!("{}", render(&curves));
    println!("claims:");
    for (claim, holds, detail) in check_claims(&curves) {
        println!("  [{}] {claim} ({detail})", if holds { "ok" } else { "MISS" });
    }
}
