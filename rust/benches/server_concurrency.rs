//! Event-loop serving at connection scale: ingest throughput, resident
//! memory, and per-tick loop cost as thousands of mostly-idle
//! connections ride one loop thread — the workload shape the reactor
//! rewrite exists for (the paper's datapath multiplexes flows; a server
//! must multiplex tenants).
//!
//! For each poller backend and connection count N, N clients connect
//! and stay connected; a small active subset drives pipelined ingest
//! while the rest sit idle. The old thread-per-connection model's cost
//! scaled with N (one OS thread + stack per connection); `poll(2)`'s
//! scales with N too (the kernel rescans every registered descriptor
//! per tick); epoll's scales with the *ready* subset only. The sweep
//! prints per-backend throughput/RSS/p99 columns plus the event loop's
//! own tick telemetry (`loop_poll_wait_ns`, `loop_saturation_permille`)
//! so the flat-in-N claim is read off the server's live histograms, not
//! inferred.
//!
//! `--smoke` runs only the cross-backend parity gate — identical
//! traffic through every available backend must leave bit-identical
//! registry state with clean frame accounting. That is the CI
//! invocation; the full sweep (including the 10 000-connection tier)
//! is for workstation runs. `HLL_BENCH_QUICK=1` shrinks the sweep.

use hll_fpga::bench_harness::{bench_main, quick_mode};
use hll_fpga::hll::HllSketch;
use hll_fpga::net::KeyedFlowGen;
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::server::{PollerBackend, ServerConfig, SketchClient, SketchServer};

/// VmRSS from /proc/self/status, in KiB (`None` off Linux).
fn resident_kib() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmRSS:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// Raise the soft RLIMIT_NOFILE toward the hard limit (capped at 32k —
/// both socket ends of every connection live in this process, so the
/// 10 000-connection tier needs ~20k descriptors plus slack). Returns
/// the effective soft limit.
#[cfg(target_os = "linux")]
fn raise_nofile_limit() -> u64 {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut r = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
            return 0;
        }
        let want = r.max.min(32_768);
        if r.cur < want {
            let bumped = RLimit { cur: want, max: r.max };
            let _ = setrlimit(RLIMIT_NOFILE, &bumped);
            let _ = getrlimit(RLIMIT_NOFILE, &mut r);
        }
        r.cur
    }
}

#[cfg(not(target_os = "linux"))]
fn raise_nofile_limit() -> u64 {
    u64::MAX
}

/// One (backend, connection-count) sweep point, read back for the
/// summary table and the flatness assertions.
struct Tier {
    backend: &'static str,
    conns: usize,
    mitems_per_s: f64,
    dispatch_p99_us: f64,
    rss_delta_kib: Option<u64>,
    poll_wait_p50_us: f64,
    saturation_permille: u64,
}

/// Parity gate (the `--smoke` CI invocation): identical keyed traffic
/// through a server on every available poller backend must produce
/// bit-identical registry state — same merged sketch, same key count —
/// with zero error frames. A backend that drops, reorders into
/// corruption, or double-applies a frame diverges here.
fn smoke_parity() {
    const WORDS: usize = 20_000;
    let mut gen = KeyedFlowGen::new(500, 1.07, 0xFEED);
    let batches = gen.batched(WORDS, 1_024);
    let mut results: Vec<(&'static str, HllSketch, usize)> = Vec::new();
    for &backend in PollerBackend::available() {
        let registry = SketchRegistry::shared(RegistryConfig {
            shards: 8,
            ..RegistryConfig::default()
        })
        .unwrap();
        let server = SketchServer::start(
            "127.0.0.1:0",
            registry.clone(),
            ServerConfig {
                poller_backend: backend,
                event_loop_threads: 1,
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let mut clients: Vec<SketchClient> = (0..4)
            .map(|_| SketchClient::connect(server.local_addr()).unwrap())
            .collect();
        let chunk = batches.len().div_ceil(clients.len());
        let mut total = 0u64;
        for (client, slice) in clients.iter_mut().zip(batches.chunks(chunk)) {
            total += client.pipeline_insert(slice).unwrap();
        }
        assert_eq!(total as usize, WORDS, "{}: every word must be acked", backend.label());
        let stats = server.stats();
        assert_eq!(
            stats.error_frames,
            0,
            "{}: frame accounting must be clean",
            backend.label()
        );
        let merged = registry.merge_all();
        let keys = registry.len();
        server.shutdown();
        results.push((backend.label(), merged, keys));
    }
    let (first_label, first_sketch, first_keys) = &results[0];
    for (label, sketch, keys) in &results[1..] {
        assert_eq!(
            sketch, first_sketch,
            "merged registry sketch diverges between {label} and {first_label}"
        );
        assert_eq!(
            keys, first_keys,
            "registry key count diverges between {label} and {first_label}"
        );
    }
    println!(
        "smoke parity: {} backend(s) left bit-identical registry state over {WORDS} words",
        results.len()
    );
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    smoke_parity();
    if smoke {
        return;
    }

    let b = bench_main("server concurrency — poller backends vs connection count");
    let words: usize = if quick_mode() { 40_000 } else { 200_000 };
    let mut conn_counts: Vec<usize> = if quick_mode() {
        vec![16, 128]
    } else {
        vec![16, 512, 10_000]
    };
    const ACTIVE: usize = 8;

    // Both socket ends of every connection live in this process.
    let fd_limit = raise_nofile_limit();
    conn_counts.retain(|&conns| {
        let need = 2 * conns as u64 + 512;
        if fd_limit < need {
            eprintln!(
                "SKIPPING {conns}-connection tier: RLIMIT_NOFILE={fd_limit} < {need} \
                 (raise the hard limit to include it)"
            );
            false
        } else {
            true
        }
    });

    let mut gen = KeyedFlowGen::new(1_000, 1.07, 0xC0FE);
    let batches = gen.batched(words, 4096);
    println!(
        "{words} words in {} batches, 1000 keys (zipf 1.07); {ACTIVE} active producers; \
         backends: {:?}\n",
        batches.len(),
        PollerBackend::available()
            .iter()
            .map(|bk| bk.label())
            .collect::<Vec<_>>()
    );

    let baseline_rss = resident_kib();
    let mut tiers: Vec<Tier> = Vec::new();
    for &backend in PollerBackend::available() {
        for &conns in &conn_counts {
            let registry = SketchRegistry::shared(RegistryConfig {
                shards: 64,
                ..RegistryConfig::default()
            })
            .unwrap();
            let server = SketchServer::start(
                "127.0.0.1:0",
                registry.clone(),
                ServerConfig {
                    poller_backend: backend,
                    event_loop_threads: 1,
                    max_connections: conns + 64,
                    ..ServerConfig::default()
                },
            )
            .unwrap();
            let addr = server.local_addr();

            // N resident connections; the first ACTIVE of them produce.
            // A periodic ping during connect keeps the accept queue
            // drained so the 10k tier cannot overflow the backlog.
            let mut clients: Vec<SketchClient> = Vec::with_capacity(conns);
            for i in 0..conns {
                clients.push(SketchClient::connect(addr).unwrap());
                if i % 512 == 511 {
                    clients[i].ping().unwrap();
                }
            }
            // Touch every connection once so all are adopted and live.
            for c in clients.iter_mut() {
                c.ping().unwrap();
            }
            assert!(server.stats().connections_open as usize >= conns);

            let chunk = batches.len().div_ceil(ACTIVE);
            let m = b.run_items(
                &format!("[{}] {conns:>5} conns, {ACTIVE} active", backend.label()),
                words as u64,
                || {
                    registry.clear();
                    let mut total = 0u64;
                    for (client, slice) in clients.iter_mut().zip(batches.chunks(chunk)) {
                        total += client.pipeline_insert(slice).unwrap();
                    }
                    total
                },
            );
            println!("{}", m.report_line());
            // Per-opcode dispatch latency straight from the server's
            // live histogram (same `(name, label)` returns the same
            // cell the event loop records into).
            let dispatch = server
                .metrics()
                .histogram("rpc_latency_ns", Some(("op", "insert_batch".to_string())))
                .snapshot();
            let (p50, p99) = (dispatch.quantile(0.5), dispatch.quantile(0.99));
            assert!(dispatch.count > 0, "ingest must have recorded dispatch latencies");
            assert!(p99 > 0, "p99 dispatch latency must be nonzero");
            println!(
                "      insert_batch dispatch: p50 {:.1}us  p99 {:.1}us  max {:.1}us over {} frames",
                p50 as f64 / 1e3,
                p99 as f64 / 1e3,
                dispatch.max as f64 / 1e3,
                dispatch.count
            );
            // Per-tick loop telemetry: with one loop thread the whole
            // story is in the `loop="0"` cells. `loop_poll_wait_ns`
            // includes the kernel's readiness scan, so poll(2) shows
            // its O(N) rescans here while epoll stays flat.
            let wait = server
                .metrics()
                .histogram("loop_poll_wait_ns", Some(("loop", "0".to_string())))
                .snapshot();
            let saturation = server
                .metrics()
                .gauge("loop_saturation_permille", Some(("loop", "0".to_string())))
                .get();
            println!(
                "      loop tick: poll-wait p50 {:.1}us p99 {:.1}us over {} ticks; \
                 saturation {saturation} permille",
                wait.quantile(0.5) as f64 / 1e3,
                wait.quantile(0.99) as f64 / 1e3,
                wait.count
            );
            let rss_delta_kib = match (baseline_rss, resident_kib()) {
                (Some(base), Some(now)) => {
                    let threads_model_kib = conns as u64 * 8 * 1024; // 8 MiB stack reservation each
                    println!(
                        "      rss now {now} KiB (+{} KiB over baseline); thread-per-conn model \
                         would reserve {threads_model_kib} KiB of stacks for {conns} conns",
                        now.saturating_sub(base)
                    );
                    Some(now.saturating_sub(base))
                }
                _ => {
                    println!("      rss unavailable on this platform");
                    None
                }
            };

            // Every idle connection is still alive after the ingest storm.
            for c in clients.iter_mut() {
                c.ping().unwrap();
            }
            let stats = server.stats();
            assert_eq!(stats.error_frames, 0);
            assert!(stats.connections_peak as usize >= conns);
            tiers.push(Tier {
                backend: backend.label(),
                conns,
                mitems_per_s: m.throughput_items_per_s().unwrap_or(0.0) / 1e6,
                dispatch_p99_us: p99 as f64 / 1e3,
                rss_delta_kib,
                poll_wait_p50_us: wait.quantile(0.5) as f64 / 1e3,
                saturation_permille: saturation,
            });
            server.shutdown();
        }
    }

    println!("\nbackend   conns   Mwords/s   p99(us)   tick-wait p50(us)   saturation(permille)   rss+KiB");
    for t in &tiers {
        println!(
            "{:<8} {:>6}   {:>8.2}   {:>7.1}   {:>17.1}   {:>20}   {}",
            t.backend,
            t.conns,
            t.mitems_per_s,
            t.dispatch_p99_us,
            t.poll_wait_p50_us,
            t.saturation_permille,
            t.rss_delta_kib.map_or_else(|| "n/a".to_string(), |k| k.to_string()),
        );
    }

    // Flat-in-N gate: on epoll, per-tick loop cost must not grow with
    // the resident connection count — same active load, more idle
    // descriptors. Saturation is a 5 s busy-fraction window, so allow a
    // generous additive margin; a kernel-scan regression (poll-shaped
    // behaviour) overshoots it by an order of magnitude.
    let epoll: Vec<&Tier> = tiers.iter().filter(|t| t.backend == "epoll").collect();
    if epoll.len() >= 2 {
        let smallest = epoll.first().unwrap();
        let largest = epoll.last().unwrap();
        assert!(
            largest.saturation_permille <= smallest.saturation_permille + 400,
            "epoll loop saturation grew with idle connections: {} permille at {} conns vs \
             {} permille at {} conns",
            largest.saturation_permille,
            largest.conns,
            smallest.saturation_permille,
            smallest.conns
        );
        if let Some(poll_peer) = tiers
            .iter()
            .find(|t| t.backend == "poll" && t.conns == largest.conns)
        {
            println!(
                "\nat {} conns: epoll saturation {} permille vs poll {} permille; \
                 tick-wait p50 {:.1}us vs {:.1}us",
                largest.conns,
                largest.saturation_permille,
                poll_peer.saturation_permille,
                largest.poll_wait_p50_us,
                poll_peer.poll_wait_p50_us
            );
            assert!(
                largest.saturation_permille <= poll_peer.saturation_permille + 400,
                "epoll must not be busier than poll at the same load: {} vs {} permille",
                largest.saturation_permille,
                poll_peer.saturation_permille
            );
        }
    }
}
