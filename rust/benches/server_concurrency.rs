//! Event-loop serving at connection scale: ingest throughput and
//! resident memory as hundreds of mostly-idle connections ride one loop
//! thread — the workload shape the reactor rewrite exists for (the
//! paper's datapath multiplexes flows; a server must multiplex tenants).
//!
//! For each connection count N, N clients connect and stay connected;
//! a small active subset drives pipelined ingest while the rest sit
//! idle. The old thread-per-connection model's cost scaled with N (one
//! OS thread + stack per connection, 8 MiB of address space reserved
//! each by default); the event loop's scales with the *active* subset.
//! A reference figure for the old model's per-connection reservation is
//! printed alongside measured RSS.
//!
//! Run: `cargo bench --bench server_concurrency` (HLL_BENCH_QUICK=1
//! shrinks the sweep).

use hll_fpga::bench_harness::{bench_main, quick_mode};
use hll_fpga::net::KeyedFlowGen;
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::server::{ServerConfig, SketchClient, SketchServer};

/// VmRSS from /proc/self/status, in KiB (`None` off Linux).
fn resident_kib() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmRSS:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

fn main() {
    let b = bench_main("server concurrency — one event loop vs connection count");
    let words: usize = if quick_mode() { 40_000 } else { 200_000 };
    let conn_counts: &[usize] = if quick_mode() { &[16, 128] } else { &[16, 128, 512] };
    const ACTIVE: usize = 8;

    let mut gen = KeyedFlowGen::new(1_000, 1.07, 0xC0FE);
    let batches = gen.batched(words, 4096);
    println!(
        "{words} words in {} batches, 1000 keys (zipf 1.07); {ACTIVE} active producers\n",
        batches.len()
    );

    let baseline_rss = resident_kib();
    for &conns in conn_counts {
        let registry = SketchRegistry::shared(RegistryConfig {
            shards: 64,
            ..RegistryConfig::default()
        })
        .unwrap();
        let server = SketchServer::start(
            "127.0.0.1:0",
            registry.clone(),
            ServerConfig {
                event_loop_threads: 1,
                max_connections: conns + 64,
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let addr = server.local_addr();

        // N resident connections; the first ACTIVE of them produce.
        let mut clients: Vec<SketchClient> = Vec::with_capacity(conns);
        for _ in 0..conns {
            clients.push(SketchClient::connect(addr).unwrap());
        }
        // Touch every connection once so all are adopted and live.
        for c in clients.iter_mut() {
            c.ping().unwrap();
        }
        assert!(server.stats().connections_open as usize >= conns);

        let chunk = batches.len().div_ceil(ACTIVE);
        let m = b.run_items(&format!("{conns:>4} conns, {ACTIVE} active"), words as u64, || {
            registry.clear();
            let mut total = 0u64;
            for (client, slice) in clients.iter_mut().zip(batches.chunks(chunk)) {
                total += client.pipeline_insert(slice).unwrap();
            }
            total
        });
        println!("{}", m.report_line());
        // Per-opcode dispatch latency straight from the server's live
        // histogram (same `(name, label)` returns the same cell the
        // event loop records into).
        let dispatch = server
            .metrics()
            .histogram("rpc_latency_ns", Some(("op", "insert_batch".to_string())))
            .snapshot();
        let (p50, p99) = (dispatch.quantile(0.5), dispatch.quantile(0.99));
        assert!(dispatch.count > 0, "ingest must have recorded dispatch latencies");
        assert!(p99 > 0, "p99 dispatch latency must be nonzero");
        println!(
            "      insert_batch dispatch: p50 {:.1}us  p99 {:.1}us  max {:.1}us over {} frames",
            p50 as f64 / 1e3,
            p99 as f64 / 1e3,
            dispatch.max as f64 / 1e3,
            dispatch.count
        );
        match (baseline_rss, resident_kib()) {
            (Some(base), Some(now)) => {
                let threads_model_kib = conns as u64 * 8 * 1024; // 8 MiB stack reservation each
                println!(
                    "      rss now {now} KiB (+{} KiB over baseline); thread-per-conn model \
                     would reserve {threads_model_kib} KiB of stacks for {conns} conns",
                    now.saturating_sub(base)
                );
            }
            _ => println!("      rss unavailable on this platform"),
        }

        // Every idle connection is still alive after the ingest storm.
        for c in clients.iter_mut() {
            c.ping().unwrap();
        }
        let stats = server.stats();
        assert_eq!(stats.error_frames, 0);
        assert!(stats.connections_peak as usize >= conns);
        server.shutdown();
    }
}
