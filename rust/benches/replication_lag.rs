//! Replication bench: primary ingest throughput with a live follower
//! streaming deltas, plus convergence lag (last primary write → the
//! follower has applied everything) — ending in a bit-exactness assert.
//!
//! Run: `cargo bench --bench replication_lag` (HLL_BENCH_QUICK=1
//! shrinks the volume).

use std::time::{Duration, Instant};

use hll_fpga::bench_harness::{bench_main, quick_mode};
use hll_fpga::hll::{HashKind, HllConfig, HllSketch};
use hll_fpga::net::KeyedFlowGen;
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::replica::{FollowerConfig, FollowerServer, ReplicationConfig, ReplicationLog};
use hll_fpga::server::{ServerConfig, SketchClient, SketchServer};

fn main() {
    let b = bench_main("replication — delta shipping throughput & convergence lag");
    let words: usize = if quick_mode() { 40_000 } else { 400_000 };

    // p=12 keeps each per-key delta frame at ~4 KiB instead of the
    // paper config's 64 KiB — the bench measures shipping mechanics,
    // not serialization volume.
    let cfg = RegistryConfig {
        hll: HllConfig::new(12, HashKind::H64).unwrap(),
        shards: 64,
        ..RegistryConfig::default()
    };
    let primary_reg = SketchRegistry::shared(cfg).unwrap();
    let primary = SketchServer::start(
        "127.0.0.1:0",
        primary_reg.clone(),
        ServerConfig {
            replication: Some(ReplicationConfig {
                capture_interval: Duration::from_millis(5),
                ..ReplicationConfig::default()
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let log = primary.replication_log().unwrap();

    let follower_reg = SketchRegistry::shared(cfg).unwrap();
    let follower = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
    )
    .unwrap();

    let mut gen = KeyedFlowGen::new(1_000, 1.07, 0xFACE);
    let batches = gen.batched(words, 4096);
    println!("{words} words in {} batches, 1000 keys (zipf 1.07), p=12\n", batches.len());
    let mut client = SketchClient::connect(primary.local_addr()).unwrap();

    // --- Throughput: pipelined ingest while the follower streams.
    // Repeated iterations re-dirty the same keys (registers saturate),
    // so this measures steady-state capture + shipping cost, not
    // first-touch growth.
    let m = b.run_items("primary pipelined ingest, live follower", words as u64, || {
        client.pipeline_insert(&batches).unwrap()
    });
    println!("{}", m.report_line());

    // --- Convergence lag: one fresh burst of never-before-seen words,
    // then the time until the follower holds everything. The natural
    // pipeline is capture interval + batch shipping + apply + ack.
    let burst = KeyedFlowGen::new(1_000, 1.07, 0xD1CE).batched(words / 4, 4096);
    let t0 = Instant::now();
    client.pipeline_insert(&burst).unwrap();
    let ingested = t0.elapsed();
    let deadline = Instant::now() + Duration::from_secs(120);
    while primary_reg.dirty_keys() > 0 || follower.cursor() < log.latest_seq() {
        assert!(Instant::now() < deadline, "replication never drained");
        std::thread::sleep(Duration::from_millis(1));
    }
    let converged = t0.elapsed();
    println!(
        "\nconvergence: burst of {} words ingested in {:?}; follower drained {:?} after \
         the first write ({:?} after the last)",
        words / 4,
        ingested,
        converged,
        converged.saturating_sub(ingested)
    );

    // --- Acceptance: force-seal any residue (`seal_all` loops past
    // in-flight background captures) and assert bit-exactness.
    let head = log.seal_all(&primary_reg, Duration::from_secs(120));
    while follower.cursor() < head {
        assert!(Instant::now() < deadline, "follower never reached the log head");
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(
        follower_reg.merge_all(),
        primary_reg.merge_all(),
        "follower union diverged from primary"
    );
    assert_eq!(follower_reg.global_estimate(), primary_reg.global_estimate());
    println!("follower bit-identical to primary: ok");

    let fstats = follower.stats();
    println!(
        "follower: cursor {}, {} batches / {} entries applied, {} full syncs, {} reconnects",
        fstats.cursor,
        fstats.batches_applied,
        fstats.entries_applied,
        fstats.full_syncs,
        fstats.reconnects
    );
    let lstats = log.stats();
    println!(
        "log: {} batches / {} entries sealed, {} retained ({} bytes)",
        lstats.sealed_batches,
        lstats.sealed_entries,
        lstats.retained_batches,
        lstats.retained_bytes
    );
    let pstats = primary.stats();
    println!(
        "primary: {} delta batches and {} full syncs streamed",
        pstats.delta_batches_sent, pstats.full_syncs_sent
    );
    // Seal-to-apply lag straight from the follower's live histogram:
    // batch seal timestamp on the primary → entries applied here. Same
    // `(name, label)` returns the cell `apply_frame` records into.
    let lag = follower.metrics().histogram("replica_seal_to_apply_ns", None).snapshot();
    assert!(lag.count > 0, "follower must have recorded seal-to-apply samples");
    let (p50, p99) = (lag.quantile(0.5), lag.quantile(0.99));
    assert!(p99 > 0, "p99 seal-to-apply lag must be nonzero");
    println!(
        "seal-to-apply lag: p50 {:.2}ms  p99 {:.2}ms  max {:.2}ms over {} batches",
        p50 as f64 / 1e6,
        p99 as f64 / 1e6,
        lag.max as f64 / 1e6,
        lag.count
    );
    println!(
        "log entry mix: {} diffs / {} fulls / {} tombstones / {} global diffs, {} entry bytes sealed",
        lstats.sealed_diff_entries,
        lstats.sealed_full_entries,
        lstats.sealed_tombstones,
        lstats.sealed_global_diffs,
        lstats.sealed_bytes
    );
    follower.shutdown();
    primary.shutdown();

    delta_compaction_bytes_per_key();
}

/// Delta-compaction metric: entry bytes per replicated key on a
/// low-churn steady state (~1% of registers touched per capture),
/// register-diff wire v3 against what full-sketch wire v2 shipped for
/// the same drains — asserting the diff path stays under 10% of the
/// full-resend cost.
fn delta_compaction_bytes_per_key() {
    let hll = HllConfig::new(12, HashKind::H64).unwrap();
    // No global union: this metric counts *per-key* entries exactly,
    // and the global union's own GLOBAL_DIFF entry per capture would
    // fold a second (tiny) stream into the accounting.
    let cfg = RegistryConfig { hll, shards: 16, track_global: false, ..RegistryConfig::default() };
    let reg = SketchRegistry::new(cfg).unwrap();
    reg.enable_dirty_tracking();
    let log = ReplicationLog::new();
    let keys = 64u64;

    // Densify every key (p=12 upgrades past ~512 sparse entries), then
    // flush the first-touch full resends out of the accounting.
    for key in 0..keys {
        let words: Vec<u32> =
            (0..6_000u32).map(|w| w.wrapping_mul(2_654_435_761).wrapping_add(key as u32)).collect();
        reg.ingest(key, &words);
    }
    log.capture(&reg, usize::MAX);
    let base = log.stats();

    // Steady state: ~40 fresh words per key per capture — at m=4096
    // that touches ≤1% of each key's registers.
    let rounds = 10u32;
    for round in 0..rounds {
        for key in 0..keys {
            let words: Vec<u32> = (0..40u32)
                .map(|i| {
                    (round * 40 + i)
                        .wrapping_mul(77_777_777)
                        .wrapping_add(key as u32 * 1_000_003)
                })
                .collect();
            reg.ingest(key, &words);
        }
        log.capture(&reg, usize::MAX);
    }
    let stats = log.stats();
    let entries = stats.sealed_entries - base.sealed_entries;
    let diff_bytes = stats.sealed_bytes - base.sealed_bytes;
    assert_eq!(
        stats.sealed_diff_entries - base.sealed_diff_entries,
        entries,
        "every steady-state dense update must seal as a register diff"
    );
    // What wire v2 shipped for the same drains: each dirty key's full
    // dense sketch plus its 12-byte entry header.
    let v2_bytes = entries * (12 + HllSketch::wire_len(&hll)) as u64;
    let ratio = diff_bytes as f64 / v2_bytes as f64;
    println!(
        "\ndelta compaction ({} keys × {rounds} captures, ~1% registers touched):\n\
         v3 register diffs: {diff_bytes} bytes ({:.0} B/key-capture)\n\
         v2 full sketches:  {v2_bytes} bytes ({:.0} B/key-capture)\n\
         diff/full ratio:   {:.3}",
        keys,
        diff_bytes as f64 / entries as f64,
        v2_bytes as f64 / entries as f64,
        ratio
    );
    assert!(
        diff_bytes * 10 < v2_bytes,
        "register diffs must ship <10% of full-sketch bytes on a low-churn workload \
         (got {ratio:.3})"
    );
}
