//! Replication bench: primary ingest throughput with a live follower
//! streaming deltas, plus convergence lag (last primary write → the
//! follower has applied everything) — ending in a bit-exactness assert.
//!
//! Run: `cargo bench --bench replication_lag` (HLL_BENCH_QUICK=1
//! shrinks the volume).

use std::time::{Duration, Instant};

use hll_fpga::bench_harness::{bench_main, quick_mode};
use hll_fpga::hll::{HashKind, HllConfig};
use hll_fpga::net::KeyedFlowGen;
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::replica::{FollowerConfig, FollowerServer, ReplicationConfig};
use hll_fpga::server::{ServerConfig, SketchClient, SketchServer};

fn main() {
    let b = bench_main("replication — delta shipping throughput & convergence lag");
    let words: usize = if quick_mode() { 40_000 } else { 400_000 };

    // p=12 keeps each per-key delta frame at ~4 KiB instead of the
    // paper config's 64 KiB — the bench measures shipping mechanics,
    // not serialization volume.
    let cfg = RegistryConfig {
        hll: HllConfig::new(12, HashKind::H64).unwrap(),
        shards: 64,
        ..RegistryConfig::default()
    };
    let primary_reg = SketchRegistry::shared(cfg).unwrap();
    let primary = SketchServer::start(
        "127.0.0.1:0",
        primary_reg.clone(),
        ServerConfig {
            replication: Some(ReplicationConfig {
                capture_interval: Duration::from_millis(5),
                ..ReplicationConfig::default()
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let log = primary.replication_log().unwrap();

    let follower_reg = SketchRegistry::shared(cfg).unwrap();
    let follower = FollowerServer::start(
        "127.0.0.1:0",
        primary.local_addr(),
        follower_reg.clone(),
        FollowerConfig::default(),
    )
    .unwrap();

    let mut gen = KeyedFlowGen::new(1_000, 1.07, 0xFACE);
    let batches = gen.batched(words, 4096);
    println!("{words} words in {} batches, 1000 keys (zipf 1.07), p=12\n", batches.len());
    let mut client = SketchClient::connect(primary.local_addr()).unwrap();

    // --- Throughput: pipelined ingest while the follower streams.
    // Repeated iterations re-dirty the same keys (registers saturate),
    // so this measures steady-state capture + shipping cost, not
    // first-touch growth.
    let m = b.run_items("primary pipelined ingest, live follower", words as u64, || {
        client.pipeline_insert(&batches).unwrap()
    });
    println!("{}", m.report_line());

    // --- Convergence lag: one fresh burst of never-before-seen words,
    // then the time until the follower holds everything. The natural
    // pipeline is capture interval + batch shipping + apply + ack.
    let burst = KeyedFlowGen::new(1_000, 1.07, 0xD1CE).batched(words / 4, 4096);
    let t0 = Instant::now();
    client.pipeline_insert(&burst).unwrap();
    let ingested = t0.elapsed();
    let deadline = Instant::now() + Duration::from_secs(120);
    while primary_reg.dirty_keys() > 0 || follower.cursor() < log.latest_seq() {
        assert!(Instant::now() < deadline, "replication never drained");
        std::thread::sleep(Duration::from_millis(1));
    }
    let converged = t0.elapsed();
    println!(
        "\nconvergence: burst of {} words ingested in {:?}; follower drained {:?} after \
         the first write ({:?} after the last)",
        words / 4,
        ingested,
        converged,
        converged.saturating_sub(ingested)
    );

    // --- Acceptance: force-seal any residue (looping past in-flight
    // background captures) and assert bit-exactness.
    loop {
        log.capture(&primary_reg, usize::MAX);
        let latest = log.latest_seq();
        while follower.cursor() < latest {
            assert!(Instant::now() < deadline, "follower never reached the log head");
            std::thread::sleep(Duration::from_millis(1));
        }
        if primary_reg.dirty_keys() == 0
            && log.captures_in_flight() == 0
            && log.latest_seq() == latest
        {
            break;
        }
        assert!(Instant::now() < deadline, "replication never fully drained");
    }
    assert_eq!(
        follower_reg.merge_all(),
        primary_reg.merge_all(),
        "follower union diverged from primary"
    );
    assert_eq!(follower_reg.global_estimate(), primary_reg.global_estimate());
    println!("follower bit-identical to primary: ok");

    let fstats = follower.stats();
    println!(
        "follower: cursor {}, {} batches / {} entries applied, {} full syncs, {} reconnects",
        fstats.cursor,
        fstats.batches_applied,
        fstats.entries_applied,
        fstats.full_syncs,
        fstats.reconnects
    );
    let lstats = log.stats();
    println!(
        "log: {} batches / {} entries sealed, {} retained ({} bytes)",
        lstats.sealed_batches,
        lstats.sealed_entries,
        lstats.retained_batches,
        lstats.retained_bytes
    );
    let pstats = primary.stats();
    println!(
        "primary: {} delta batches and {} full syncs streamed",
        pstats.delta_batches_sent, pstats.full_syncs_sent
    );
    follower.shutdown();
    primary.shutdown();
}
