//! Registry scaling bench: keyed-ingest throughput vs thread count and
//! key cardinality, the batched-vs-scalar comparison on the dense tier,
//! plus the bit-exactness checks that anchor the whole concurrent
//! design (N-thread shared-sketch ingest == sequential; batch ingest ==
//! word-at-a-time).
//!
//! Run: `cargo bench --bench registry_scale` (HLL_BENCH_QUICK=1 shrinks
//! the word volume but keeps the 1M-key / 4-thread coverage).
//! `--smoke` runs only the batch/scalar parity gate — the CI invocation
//! (exits nonzero on any divergence, measures no throughput).

use std::sync::Arc;

use hll_fpga::bench_harness::{bench_main, quick_mode};
use hll_fpga::coordinator::{run_keyed_stream, CoordinatorConfig};
use hll_fpga::hll::{ConcurrentHllSketch, HllConfig, HllSketch};
use hll_fpga::net::KeyedFlowGen;
use hll_fpga::registry::{RegistryConfig, SketchRegistry};

/// A register file the packed tier cannot hold — alternating far-apart
/// values defeat its 7-wide offset window — so `merge_sketch` residents
/// the key in the dense tier. This is how the dense-tier comparison
/// gets resident dense keys without streaming millions of words first.
fn bimodal_dense(cfg: HllConfig) -> HllSketch {
    let mut s = HllSketch::new(cfg);
    for idx in 0..cfg.m() {
        s.update_register(idx, if idx % 2 == 0 { 1 } else { 40 });
    }
    s
}

/// Fresh registry with `keys` pre-promoted dense-tier keys.
fn dense_registry(keys: u64) -> Arc<SketchRegistry<u64>> {
    let registry = SketchRegistry::shared(RegistryConfig {
        shards: 64,
        ..RegistryConfig::default()
    })
    .unwrap();
    let dense = bimodal_dense(HllConfig::PAPER);
    for key in 0..keys {
        registry.merge_sketch(key, dense.clone()).unwrap();
    }
    assert_eq!(registry.stats().dense_keys(), keys as usize, "keys must resident dense");
    registry
}

/// Word-at-a-time reference: one `ingest` call per (key, word) pair.
fn scalar_ingest(registry: &SketchRegistry<u64>, pairs: &[(u64, u32)], threads: usize) {
    let chunk = pairs.len().div_ceil(threads);
    std::thread::scope(|scope| {
        for slice in pairs.chunks(chunk) {
            scope.spawn(move || {
                for &(k, w) in slice {
                    registry.ingest(k, &[w]);
                }
            });
        }
    });
}

/// Batch path: whole routed batches through `ingest_pairs`.
fn batched_ingest(registry: &SketchRegistry<u64>, pairs: &[(u64, u32)], threads: usize) {
    let chunk = pairs.len().div_ceil(threads);
    std::thread::scope(|scope| {
        for slice in pairs.chunks(chunk) {
            scope.spawn(move || {
                for batch in slice.chunks(8192) {
                    registry.ingest_pairs(batch);
                }
            });
        }
    });
}

/// Parity gate (the `--smoke` CI invocation): batch ingest — registry
/// entry points and the keyed coordinator — must be bit-exact with the
/// word-at-a-time reference, estimates AND replication deltas. Any
/// mismatch panics, which exits the bench nonzero.
fn smoke_parity() {
    let mk = || {
        SketchRegistry::shared(RegistryConfig { shards: 16, ..RegistryConfig::default() }).unwrap()
    };
    let mut gen = KeyedFlowGen::new(500, 1.07, 42);
    let pairs = gen.batch(30_000);

    let batched = mk();
    let scalar = mk();
    batched.enable_dirty_tracking();
    scalar.enable_dirty_tracking();
    for chunk in pairs.chunks(4_096) {
        batched.ingest_pairs(chunk);
    }
    for &(k, w) in &pairs {
        scalar.ingest(k, &[w]);
    }
    assert_eq!(batched.merge_all(), scalar.merge_all(), "union registers diverge");
    assert_eq!(batched.len(), scalar.len(), "key population diverges");
    for (key, est) in scalar.estimates() {
        assert_eq!(batched.estimate(&key), Some(est), "estimate diverges for key {key}");
    }
    let mut a = batched.drain_dirty_deltas();
    let mut s = scalar.drain_dirty_deltas();
    a.sort_by_key(|e| e.0);
    s.sort_by_key(|e| e.0);
    assert_eq!(a, s, "replication deltas diverge");

    // The keyed coordinator (sorted worker batches over routed runs)
    // lands the identical union.
    let keyed = mk();
    let cfg = CoordinatorConfig { pipelines: 4, batch_size: 1_024, ..Default::default() };
    run_keyed_stream(&cfg, keyed.clone(), &pairs).unwrap();
    assert_eq!(keyed.merge_all(), scalar.merge_all(), "keyed coordinator diverges");
    println!("  batched-ingest parity: PASS (30k words, 500 keys)");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let b = bench_main("registry scale — keyed ingest");
    smoke_parity();
    if smoke {
        return;
    }
    let words_per_run: usize = if quick_mode() { 200_000 } else { 2_000_000 };

    // --- Concurrent sketch: thread scaling + bit-exactness ---
    println!("concurrent sketch ingest (one shared register file, CAS-max):");
    let mut gen = KeyedFlowGen::new(1, 1.07, 0xC0FFEE);
    let words: Vec<u32> = gen.batch(words_per_run).into_iter().map(|(_, w)| w).collect();
    let mut serial = HllSketch::new(HllConfig::PAPER);
    serial.insert_batch(&words);
    for threads in [1usize, 2, 4, 8] {
        let m = b.run_bytes(
            &format!("concurrent insert_batch threads={threads}"),
            (words.len() * 4) as u64,
            || {
                let shared = ConcurrentHllSketch::paper();
                let chunk = words.len().div_ceil(threads);
                std::thread::scope(|scope| {
                    for slice in words.chunks(chunk) {
                        let shared = &shared;
                        scope.spawn(move || shared.insert_batch(slice));
                    }
                });
                shared
            },
        );
        println!("{}", m.report_line());
        // Acceptance: the N-thread result is bit-identical to the
        // sequential reference on the same input, every time.
        let shared = ConcurrentHllSketch::paper();
        let chunk = words.len().div_ceil(threads);
        std::thread::scope(|scope| {
            for slice in words.chunks(chunk) {
                let shared = &shared;
                scope.spawn(move || shared.insert_batch(slice));
            }
        });
        assert_eq!(
            shared.snapshot(),
            serial,
            "threads={threads}: concurrent sketch diverged from sequential"
        );
        println!("  bit-identical to sequential insert_batch: ok (threads={threads})");
    }

    // --- Keyed registry ingest: threads × key cardinality ---
    for key_card in [1_000u64, 100_000, 1_000_000] {
        println!("\nkeyed registry ingest, {key_card} keys (zipf 1.07):");
        let mut gen = KeyedFlowGen::new(key_card, 1.07, key_card);
        let pairs = gen.batch(words_per_run);
        for threads in [1usize, 2, 4, 8] {
            let cfg = CoordinatorConfig {
                pipelines: threads,
                batch_size: 8192,
                ..CoordinatorConfig::default()
            };
            let m = b.run_items(
                &format!("keyed ingest keys={key_card} threads={threads}"),
                pairs.len() as u64,
                || {
                    let registry = SketchRegistry::shared(RegistryConfig {
                        shards: 64,
                        ..RegistryConfig::default()
                    })
                    .unwrap();
                    run_keyed_stream(&cfg, registry.clone(), &pairs).unwrap();
                    registry
                },
            );
            println!("{}", m.report_line());
        }
        // Report the population the last run produced.
        let registry: Arc<SketchRegistry<u64>> = SketchRegistry::shared(RegistryConfig {
            shards: 64,
            ..RegistryConfig::default()
        })
        .unwrap();
        let cfg = CoordinatorConfig { pipelines: 4, batch_size: 8192, ..Default::default() };
        let summary = run_keyed_stream(&cfg, registry.clone(), &pairs).unwrap();
        let stats = registry.stats();
        println!(
            "  population: {} keys ({} sparse / {} packed / {} dense), {} of sketch heap, \
             global estimate {:.0}, {:.2} Mpairs/s feeder-side",
            stats.keys(),
            stats.sparse_keys(),
            stats.packed_keys(),
            stats.dense_keys(),
            hll_fpga::util::fmt::count(stats.memory_bytes() as u64),
            summary.global_estimate.unwrap_or(0.0),
            summary.pairs_per_s() / 1e6,
        );
    }

    // --- Batched vs scalar keyed ingest on the dense tier ---
    // The tentpole comparison: the same routed stream through the batch
    // entry point (`ingest_pairs`: one hash pass, one lock and one map
    // lookup per key run) against the word-at-a-time path (one `ingest`
    // call per word). Keys are pre-promoted dense so the measured delta
    // is pure per-word overhead, not tier churn.
    println!("\nbatched vs scalar keyed ingest, 64 dense-tier keys (zipf 1.07):");
    let dense_keys = 64u64;
    let mut gen = KeyedFlowGen::new(dense_keys, 1.07, 0xDE5E);
    let dense_pairs = gen.batch(words_per_run / 2);
    let registry = dense_registry(dense_keys);
    for threads in [1usize, 8] {
        let scalar = b.run_items(
            &format!("scalar word-at-a-time threads={threads}"),
            dense_pairs.len() as u64,
            || scalar_ingest(&registry, &dense_pairs, threads),
        );
        println!("{}", scalar.report_line());
        let batched = b.run_items(
            &format!("batched ingest_pairs threads={threads}"),
            dense_pairs.len() as u64,
            || batched_ingest(&registry, &dense_pairs, threads),
        );
        println!("{}", batched.report_line());
        let speedup = batched.throughput_items_per_s().unwrap_or(0.0)
            / scalar.throughput_items_per_s().unwrap_or(f64::INFINITY);
        println!("  batched/scalar words-per-second ratio at {threads} thread(s): {speedup:.2}x");
    }
}
