//! Registry scaling bench: keyed-ingest throughput vs thread count and
//! key cardinality, plus the bit-exactness check that anchors the whole
//! concurrent design (N-thread shared-sketch ingest == sequential).
//!
//! Run: `cargo bench --bench registry_scale` (HLL_BENCH_QUICK=1 shrinks
//! the word volume but keeps the 1M-key / 4-thread coverage).

use std::sync::Arc;

use hll_fpga::bench_harness::{bench_main, quick_mode};
use hll_fpga::coordinator::{run_keyed_stream, CoordinatorConfig};
use hll_fpga::hll::{ConcurrentHllSketch, HllConfig, HllSketch};
use hll_fpga::net::KeyedFlowGen;
use hll_fpga::registry::{RegistryConfig, SketchRegistry};

fn main() {
    let b = bench_main("registry scale — keyed ingest");
    let words_per_run: usize = if quick_mode() { 200_000 } else { 2_000_000 };

    // --- Concurrent sketch: thread scaling + bit-exactness ---
    println!("concurrent sketch ingest (one shared register file, CAS-max):");
    let mut gen = KeyedFlowGen::new(1, 1.07, 0xC0FFEE);
    let words: Vec<u32> = gen.batch(words_per_run).into_iter().map(|(_, w)| w).collect();
    let mut serial = HllSketch::new(HllConfig::PAPER);
    serial.insert_batch(&words);
    for threads in [1usize, 2, 4, 8] {
        let m = b.run_bytes(
            &format!("concurrent insert_batch threads={threads}"),
            (words.len() * 4) as u64,
            || {
                let shared = ConcurrentHllSketch::paper();
                let chunk = words.len().div_ceil(threads);
                std::thread::scope(|scope| {
                    for slice in words.chunks(chunk) {
                        let shared = &shared;
                        scope.spawn(move || shared.insert_batch(slice));
                    }
                });
                shared
            },
        );
        println!("{}", m.report_line());
        // Acceptance: the N-thread result is bit-identical to the
        // sequential reference on the same input, every time.
        let shared = ConcurrentHllSketch::paper();
        let chunk = words.len().div_ceil(threads);
        std::thread::scope(|scope| {
            for slice in words.chunks(chunk) {
                let shared = &shared;
                scope.spawn(move || shared.insert_batch(slice));
            }
        });
        assert_eq!(
            shared.snapshot(),
            serial,
            "threads={threads}: concurrent sketch diverged from sequential"
        );
        println!("  bit-identical to sequential insert_batch: ok (threads={threads})");
    }

    // --- Keyed registry ingest: threads × key cardinality ---
    for key_card in [1_000u64, 100_000, 1_000_000] {
        println!("\nkeyed registry ingest, {key_card} keys (zipf 1.07):");
        let mut gen = KeyedFlowGen::new(key_card, 1.07, key_card);
        let pairs = gen.batch(words_per_run);
        for threads in [1usize, 2, 4, 8] {
            let cfg = CoordinatorConfig {
                pipelines: threads,
                batch_size: 8192,
                ..CoordinatorConfig::default()
            };
            let m = b.run_items(
                &format!("keyed ingest keys={key_card} threads={threads}"),
                pairs.len() as u64,
                || {
                    let registry = SketchRegistry::shared(RegistryConfig {
                        shards: 64,
                        ..RegistryConfig::default()
                    })
                    .unwrap();
                    run_keyed_stream(&cfg, registry.clone(), &pairs).unwrap();
                    registry
                },
            );
            println!("{}", m.report_line());
        }
        // Report the population the last run produced.
        let registry: Arc<SketchRegistry<u64>> = SketchRegistry::shared(RegistryConfig {
            shards: 64,
            ..RegistryConfig::default()
        })
        .unwrap();
        let cfg = CoordinatorConfig { pipelines: 4, batch_size: 8192, ..Default::default() };
        let summary = run_keyed_stream(&cfg, registry.clone(), &pairs).unwrap();
        let stats = registry.stats();
        println!(
            "  population: {} keys ({} sparse / {} packed / {} dense), {} of sketch heap, \
             global estimate {:.0}, {:.2} Mpairs/s feeder-side",
            stats.keys(),
            stats.sparse_keys(),
            stats.packed_keys(),
            stats.dense_keys(),
            hll_fpga::util::fmt::count(stats.memory_bytes() as u64),
            summary.global_estimate.unwrap_or(0.0),
            summary.pairs_per_s() / 1e6,
        );
    }
}
