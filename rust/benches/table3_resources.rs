//! Bench/regeneration target for Table III: FPGA resource usage vs
//! #pipelines (analytic design property; regenerated and checked against
//! the paper's own numbers), plus the scaling-limit analysis.

use hll_fpga::fpga::{Device, ResourceModel};

fn main() {
    println!("\n=== Table III — resource usage vs #pipelines ===");
    println!("{}", hll_fpga::repro::tables::table3());

    // Exact checks against the paper's BRAM/DSP columns.
    let model = ResourceModel::paper_h64_p16();
    let expect = [
        (1usize, 12u32, 84u32),
        (2, 24, 152),
        (4, 48, 288),
        (8, 96, 560),
        (10, 120, 696),
        (16, 192, 1104),
    ];
    let mut ok = true;
    for (k, bram, dsp) in expect {
        let u = model.usage(k);
        let hit = u.bram == bram && u.dsp == dsp;
        ok &= hit;
        println!(
            "  [{}] k={k:>2}: BRAM {}={} DSP {}={}",
            if hit { "ok" } else { "MISS" },
            u.bram,
            bram,
            u.dsp,
            dsp
        );
    }
    println!(
        "\npaper BRAM/DSP columns reproduced: {}",
        if ok { "EXACT" } else { "MISMATCH" }
    );

    // Extension beyond the paper: the 32-bit-hash variant and the
    // scaling frontier on the same device.
    let h32 = ResourceModel::paper_h32_p16();
    let dev = Device::XCVU9P;
    println!(
        "H32 variant: max {} pipelines ({}-bound); H64: max {} ({}-bound)",
        h32.max_pipelines(&dev),
        h32.binding_resource(&dev),
        model.max_pipelines(&dev),
        model.binding_resource(&dev)
    );
}
