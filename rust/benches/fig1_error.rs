//! Bench/regeneration target for Fig 1: the standard-error profile.
//!
//! Regenerates both subfigures (p=14 and p=16, each with H ∈ {32,64})
//! and times the sweep. `HLL_BENCH_QUICK=1` or `--quick` reduces reach.
//!
//! Also hosts the **estimator regression gate**: a paired sweep that
//! asserts the Ertl estimator is never worse than the legacy range-split
//! estimator at any decade, and that there is no error discontinuity at
//! the old LinearCounting→raw boundary. `--smoke` runs only the gate at
//! reduced reach — this is the CI invocation.

use hll_fpga::hll::{HashKind, HllConfig};
use hll_fpga::repro::fig1::{check_claims, curves, render, Fig1Options};
use hll_fpga::stats::{log_spaced_cardinalities, measure_point_paired, transition_cardinality};
use hll_fpga::util::fmt::TextTable;

/// Sweep decades with both estimators on identical register files and
/// enforce the PR's acceptance gate. Panics on violation (the bench exit
/// code is the CI signal).
///
/// Tolerance: at decades where both estimators are near-exact (LC
/// region, errors ~1e-4) the ratio of two tiny numbers is noisy, so the
/// gate is `ertl ≤ legacy·1.15 + 1e-3` — loose enough to absorb that
/// noise, tight enough that any real regression (the legacy bias bump
/// near the transition is ~2–3% absolute) trips it. Streams are seeded
/// deterministically, so a passing gate is reproducible, not lucky.
fn estimator_gate(smoke: bool) {
    let cfg = HllConfig::new(14, HashKind::H64).unwrap();
    let (hi_exp, trials) = if smoke { (5, 3) } else { (7, 5) };
    println!(
        "\nestimator gate: Ertl vs legacy, p={} {}, 10^2..10^{hi_exp}, {trials} paired trials",
        cfg.p(),
        cfg.hash().label(),
    );

    let mut t = TextTable::new(vec![
        "cardinality",
        "ertl mean %",
        "legacy mean %",
        "ratio",
        "verdict",
    ]);
    let mut failures = Vec::new();
    for n in log_spaced_cardinalities(2, hi_exp, 1) {
        let (ertl, legacy) = measure_point_paired(cfg, n, trials);
        let bound = legacy.mean * 1.15 + 1e-3;
        let ok = ertl.mean <= bound;
        t.row(vec![
            hll_fpga::util::fmt::count(n),
            format!("{:.4}", ertl.mean * 100.0),
            format!("{:.4}", legacy.mean * 100.0),
            format!("{:.3}", ertl.mean / legacy.mean.max(1e-12)),
            String::from(if ok { "ok" } else { "WORSE" }),
        ]);
        if !ok {
            failures.push(format!(
                "n={n}: ertl mean {:.5} > bound {:.5} (legacy {:.5})",
                ertl.mean, bound, legacy.mean
            ));
        }
    }
    println!("{}", t.render());

    // No discontinuity at the old LC→raw switch point (2.5·m): the
    // legacy estimator's bias bump lives here; Ertl must sail through
    // within the analytic band.
    let boundary = transition_cardinality(&cfg);
    let band = 3.5 * cfg.standard_error() + 0.004;
    for scale in [0.7f64, 1.0, 1.3] {
        let n = (boundary as f64 * scale) as u64;
        let (ertl, _) = measure_point_paired(cfg, n, trials);
        println!(
            "  transition {:.1}×{}: ertl mean {:.4}% (band {:.4}%)",
            scale,
            hll_fpga::util::fmt::count(boundary),
            ertl.mean * 100.0,
            band * 100.0
        );
        if ertl.mean > band {
            failures.push(format!(
                "transition n={n}: ertl mean {:.5} exceeds smoothness band {:.5}",
                ertl.mean, band
            ));
        }
    }

    assert!(
        failures.is_empty(),
        "estimator regression gate FAILED:\n  {}",
        failures.join("\n  ")
    );
    println!("  estimator gate: PASS");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let quick = hll_fpga::bench_harness::quick_mode()
        || std::env::args().any(|a| a == "--quick");
    let b = hll_fpga::bench_harness::bench_main("Fig 1 — HLL standard error vs cardinality");

    estimator_gate(smoke || quick);
    if smoke {
        return;
    }

    let opts = Fig1Options {
        full: std::env::args().any(|a| a == "--full"),
        trials: if quick { 3 } else { 5 },
        max_exp: if quick { Some(5) } else { None },
    };

    let t0 = std::time::Instant::now();
    let cs = curves(&opts);
    let sweep_time = t0.elapsed();
    println!("{}", render(&cs));
    for (claim, holds, detail) in check_claims(&cs) {
        println!("  [{}] {claim} ({detail})", if holds { "ok" } else { "MISS" });
    }
    println!(
        "\nsweep wall time: {}",
        hll_fpga::util::fmt::duration_s(sweep_time.as_secs_f64())
    );

    // Time a single representative profiling point for the record.
    let cfg = HllConfig::PAPER;
    let m = b.run_items("measure_point(p16/H64, n=100k, 3 trials)", 300_000, || {
        hll_fpga::stats::measure_point(cfg, 100_000, 3)
    });
    println!("{}", m.report_line());
}
