//! Bench/regeneration target for Fig 1: the standard-error profile.
//!
//! Regenerates both subfigures (p=14 and p=16, each with H ∈ {32,64})
//! and times the sweep. `HLL_BENCH_QUICK=1` or `--quick` reduces reach.

use hll_fpga::bench_harness::bench_main;
use hll_fpga::repro::fig1::{check_claims, curves, render, Fig1Options};

fn main() {
    let quick = hll_fpga::bench_harness::quick_mode()
        || std::env::args().any(|a| a == "--quick");
    let b = bench_main("Fig 1 — HLL standard error vs cardinality");

    let opts = Fig1Options {
        full: std::env::args().any(|a| a == "--full"),
        trials: if quick { 3 } else { 5 },
        max_exp: if quick { Some(5) } else { None },
    };

    let t0 = std::time::Instant::now();
    let cs = curves(&opts);
    let sweep_time = t0.elapsed();
    println!("{}", render(&cs));
    for (claim, holds, detail) in check_claims(&cs) {
        println!("  [{}] {claim} ({detail})", if holds { "ok" } else { "MISS" });
    }
    println!(
        "\nsweep wall time: {}",
        hll_fpga::util::fmt::duration_s(sweep_time.as_secs_f64())
    );

    // Time a single representative profiling point for the record.
    let cfg = hll_fpga::hll::HllConfig::PAPER;
    let m = b.run_items("measure_point(p16/H64, n=100k, 3 trials)", 300_000, || {
        hll_fpga::stats::measure_point(cfg, 100_000, 3)
    });
    println!("{}", m.report_line());
}
