//! Serving-path bench: client→server keyed ingest throughput over real
//! loopback TCP (per-batch round trips vs pipelined flights) against
//! in-process registry ingest — the cost of the network front door.
//!
//! Run: `cargo bench --bench server_roundtrip` (HLL_BENCH_QUICK=1
//! shrinks the volume).

use hll_fpga::bench_harness::{bench_main, quick_mode};
use hll_fpga::net::KeyedFlowGen;
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::server::{ServerConfig, SketchClient, SketchServer};

fn main() {
    let b = bench_main("server roundtrip — remote vs in-process keyed ingest");
    let words: usize = if quick_mode() { 50_000 } else { 500_000 };

    // One zipf keyed stream, grouped into (key, words) batches capped at
    // 4096 words, shared by every mode.
    let mut gen = KeyedFlowGen::new(1_000, 1.07, 0xBEEF);
    let batches = gen.batched(words, 4096);
    println!("{words} words in {} batches, 1000 keys (zipf 1.07)\n", batches.len());

    let registry = SketchRegistry::shared(RegistryConfig {
        shards: 64,
        ..RegistryConfig::default()
    })
    .unwrap();

    // --- In-process baseline: same batches straight into the registry.
    let m = b.run_items("in-process ingest", words as u64, || {
        registry.clear();
        for (key, ws) in &batches {
            registry.ingest(*key, ws);
        }
        registry.len()
    });
    println!("{}", m.report_line());
    let reference = registry.merge_all();

    // --- Remote: one server, one client, a real loopback socket.
    let server =
        SketchServer::start("127.0.0.1:0", registry.clone(), ServerConfig::default()).unwrap();
    let addr = server.local_addr();

    let mut client = SketchClient::connect(addr).unwrap();
    let m = b.run_items("remote ingest, one RTT per batch", words as u64, || {
        registry.clear();
        for (key, ws) in &batches {
            client.insert_batch(*key, ws).unwrap();
        }
    });
    println!("{}", m.report_line());

    let m = b.run_items("remote ingest, pipelined flight", words as u64, || {
        registry.clear();
        client.pipeline_insert(&batches).unwrap();
    });
    println!("{}", m.report_line());

    // Acceptance: the remote path produced register-identical state.
    registry.clear();
    client.pipeline_insert(&batches).unwrap();
    assert_eq!(
        registry.merge_all(),
        reference,
        "remote ingest diverged from in-process ingest"
    );
    println!("\nremote union bit-identical to in-process ingest: ok");

    let stats = server.stats();
    println!(
        "server counters: {} connections, {} frames, {} words, {} error frames",
        stats.connections, stats.frames, stats.words_ingested, stats.error_frames
    );
    server.shutdown();
}
