//! Serving-path bench: client→server keyed ingest throughput over real
//! loopback TCP (per-batch round trips vs pipelined flights) against
//! in-process registry ingest — the cost of the network front door.
//!
//! Run: `cargo bench --bench server_roundtrip` (HLL_BENCH_QUICK=1
//! shrinks the volume).

use hll_fpga::bench_harness::{bench_main, quick_mode};
use hll_fpga::net::KeyedFlowGen;
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::server::{ServerConfig, SketchClient, SketchServer};

/// Server-side per-request latency for the mode just run, read from
/// the server's live metrics registry (no scrape round trip).
fn latency_line(mode: &str, server: &SketchServer) -> String {
    let lat = server
        .metrics()
        .histogram("rpc_latency_ns", Some(("op", "insert_batch".to_string())))
        .snapshot();
    format!(
        "  insert_batch latency ({mode}, {} frames): p50={}ns p99={}ns max={}ns",
        lat.count,
        lat.quantile(0.50),
        lat.quantile(0.99),
        lat.max
    )
}

fn main() {
    let b = bench_main("server roundtrip — remote vs in-process keyed ingest");
    let words: usize = if quick_mode() { 50_000 } else { 500_000 };

    // One zipf keyed stream, grouped into (key, words) batches capped at
    // 4096 words, shared by every mode.
    let mut gen = KeyedFlowGen::new(1_000, 1.07, 0xBEEF);
    let batches = gen.batched(words, 4096);
    println!("{words} words in {} batches, 1000 keys (zipf 1.07)\n", batches.len());

    let registry = SketchRegistry::shared(RegistryConfig {
        shards: 64,
        ..RegistryConfig::default()
    })
    .unwrap();

    // --- In-process baseline: same batches straight into the registry.
    let m = b.run_items("in-process ingest", words as u64, || {
        registry.clear();
        for (key, ws) in &batches {
            registry.ingest(*key, ws);
        }
        registry.len()
    });
    println!("{}", m.report_line());
    let reference = registry.merge_all();

    // --- Remote: a real loopback socket. Each mode gets a fresh
    // server so its live `rpc_latency_ns` histogram — the same cells
    // `MetricsDump` exposes — is that mode's distribution alone.
    let server =
        SketchServer::start("127.0.0.1:0", registry.clone(), ServerConfig::default()).unwrap();
    let mut client = SketchClient::connect(server.local_addr()).unwrap();
    let m = b.run_items("remote ingest, one RTT per batch", words as u64, || {
        registry.clear();
        for (key, ws) in &batches {
            client.insert_batch(*key, ws).unwrap();
        }
    });
    println!("{}", m.report_line());
    println!("{}", latency_line("one RTT per batch", &server));
    drop(client);
    server.shutdown();

    let server =
        SketchServer::start("127.0.0.1:0", registry.clone(), ServerConfig::default()).unwrap();
    let mut client = SketchClient::connect(server.local_addr()).unwrap();
    let m = b.run_items("remote ingest, pipelined flight", words as u64, || {
        registry.clear();
        client.pipeline_insert(&batches).unwrap();
    });
    println!("{}", m.report_line());
    println!("{}", latency_line("pipelined flight", &server));

    // Acceptance: the remote path produced register-identical state.
    registry.clear();
    client.pipeline_insert(&batches).unwrap();
    assert_eq!(
        registry.merge_all(),
        reference,
        "remote ingest diverged from in-process ingest"
    );
    println!("\nremote union bit-identical to in-process ingest: ok");

    let stats = server.stats();
    println!(
        "server counters: {} connections, {} frames, {} words, {} error frames",
        stats.connections, stats.frames, stats.words_ingested, stats.error_frames
    );
    server.shutdown();
}
