//! Bench/regeneration target for Fig 4(b): CPU throughput vs #threads
//! for both hash widths, plus the FPGA reference lines.
//!
//! Two curves are produced:
//! 1. the paper-Xeon analytic model (16C/32T dual socket) — regenerates
//!    the published figure's shape and headline ratios;
//! 2. a *measured* curve anchored to this machine's real single-thread
//!    rates (substitution note: this container exposes a single core, so
//!    thread counts > 1 exercise scheduling, not parallel speedup).

use hll_fpga::bench_harness::{bench_main, quick_mode};
use hll_fpga::cpu_baseline::{aggregate_parallel, measure_single_thread_rate, ScalingModel};
use hll_fpga::hll::{HashKind, HllConfig};
use hll_fpga::repro::fig4;
use hll_fpga::stats::DistinctStream;

fn main() {
    let b = bench_main("Fig 4(b) — CPU throughput vs #threads");

    // --- Curve 1: the paper's machine (modelled) ---
    let model = ScalingModel::paper_xeon();
    println!("{}", fig4::render_fig4b(&fig4::fig4b_rows(&model), "paper Xeon model"));

    // --- Curve 2: measured on this machine ---
    let sample = if quick_mode() { 500_000 } else { 4_000_000 };
    let r32 = measure_single_thread_rate(HashKind::H32, sample);
    let r64 = measure_single_thread_rate(HashKind::H64, sample);
    println!(
        "measured single-thread rates on this machine: 32-bit {:.2} GB/s, 64-bit {:.2} GB/s \
         (ratio {:.0}%, paper: ~60%)",
        r32 / 1e9,
        r64 / 1e9,
        100.0 * r64 / r32
    );
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let local = ScalingModel::calibrated(r32, r64, cores);
    println!(
        "{}",
        fig4::render_fig4b(&fig4::fig4b_rows(&local), "calibrated to this machine")
    );

    // --- Real thread-parallel aggregation measurements ---
    let words: Vec<u32> = DistinctStream::new(sample as u64, 8).collect();
    for hash in [HashKind::H32, HashKind::H64] {
        let cfg = HllConfig::new(16, hash).unwrap();
        for threads in [1usize, 2, 4] {
            let m = b.run_bytes(
                &format!("aggregate H={} threads={threads}", hash.bits()),
                (words.len() * 4) as u64,
                || aggregate_parallel(cfg, &words, threads).0,
            );
            println!("{}", m.report_line());
        }
    }
}
