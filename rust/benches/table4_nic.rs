//! Bench/regeneration target for Table IV: sustained NIC throughput vs
//! #pipelines over the simulated 100 Gbit/s TCP link.

use hll_fpga::bench_harness::{bench_main, quick_mode};
use hll_fpga::repro::table4;

fn main() {
    let b = bench_main("Table IV — NIC throughput vs #pipelines");
    let mb: u64 = if quick_mode() { 4 } else { 32 };
    let rows = table4::rows(mb << 20);
    println!("{}", table4::render(&rows));

    // Side-by-side factor check against the paper's own rows.
    println!("paper-vs-simulated factors (sim/paper):");
    for ((k, run), (pk, paper)) in rows.iter().zip(table4::PAPER_ROWS) {
        assert_eq!(*k, pk);
        let sim = run.throughput_bytes_per_s() / 1e9;
        println!("  k={k:>2}: {:.2}x", sim / paper);
    }

    // Wall time of one sweep (the host cost of the simulation).
    let m = b.run_items("simulate table4 sweep (6 rows)", 6, || table4::rows(2 << 20));
    println!("\n{}", m.report_line());
}
