//! Bench/regeneration target for Fig 4(a): FPGA throughput vs
//! #pipelines against the PCIe bound — simulated end-to-end (cycle-law
//! engine + XDMA model), plus a functional cycle-level run per k to show
//! the simulator agrees with the timing law.

use hll_fpga::bench_harness::{bench_main, quick_mode};
use hll_fpga::fpga::{theoretical_throughput_bytes_per_s, ParallelHll};
use hll_fpga::hll::HllConfig;
use hll_fpga::repro::fig4;
use hll_fpga::stats::DistinctStream;

fn main() {
    let b = bench_main("Fig 4(a) — FPGA throughput scaling vs PCIe bound");
    let mb: u64 = if quick_mode() { 16 } else { 256 };
    let rows = fig4::fig4a_rows(mb << 20);
    println!("{}", fig4::render_fig4a(&rows));

    // Cross-check: the functional cycle-level engine reproduces the
    // analytic law within 1% for a few representative k.
    let n_words = if quick_mode() { 200_000 } else { 1_000_000 };
    let words: Vec<u32> = DistinctStream::new(n_words, 4).collect();
    for k in [1usize, 4, 10] {
        let mut engine = ParallelHll::new(HllConfig::PAPER, k);
        engine.feed(&words);
        let r = engine.finish();
        let sim = r.throughput_bytes_per_s() / 1e9;
        let law = theoretical_throughput_bytes_per_s(k) / 1e9;
        println!(
            "  functional k={k:>2}: {sim:.2} GB/s vs law {law:.2} GB/s ({:+.2}%)",
            (sim - law) / law * 100.0
        );
    }

    // Host-side wall time of driving the simulator (not the simulated
    // time) — the cost of regenerating this figure.
    let m = b.run_items("simulate fig4a sweep (k=1..16)", 16, || {
        fig4::fig4a_rows(4 << 20)
    });
    println!("\n{}", m.report_line());
}
