//! Hot-path microbenchmarks — the inputs to the §Perf optimization pass
//! (EXPERIMENTS.md): hash rates, aggregation, estimate, merge, and the
//! PJRT engine's batch call.

use hll_fpga::bench_harness::{bench_main, quick_mode, Measurement};
use hll_fpga::cpu_baseline::{aggregate32_batched, aggregate64_batched};
use hll_fpga::hll::murmur3::{murmur3_x64_64_u32, murmur3_x86_32_u32};
use hll_fpga::hll::{AdaptiveSketch, HashKind, HllConfig, HllSketch};
use hll_fpga::runtime::{Engine, Manifest, XlaEngine, XlaService};
use hll_fpga::util::Xoshiro256StarStar;

/// Per-word cost line for the batch-ingest stages.
fn per_word(m: &Measurement, n: usize) -> String {
    format!("  -> {:.2} ns/word", m.median() * 1e9 / n as f64)
}

fn main() {
    let b = bench_main("hot path microbenchmarks");
    let n: usize = if quick_mode() { 200_000 } else { 2_000_000 };
    let mut rng = Xoshiro256StarStar::seed_from_u64(0xBEEF);
    let words: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
    let bytes = (n * 4) as u64;

    // --- Pure hash throughput (the paper's CPU bottleneck) ---
    let m = b.run_bytes("murmur3_x86_32 (scalar loop)", bytes, || {
        let mut acc = 0u32;
        for &w in &words {
            acc ^= murmur3_x86_32_u32(w, 0);
        }
        acc
    });
    println!("{}", m.report_line());
    let m = b.run_bytes("murmur3_x64_64 (scalar loop)", bytes, || {
        let mut acc = 0u64;
        for &w in &words {
            acc ^= murmur3_x64_64_u32(w, 0);
        }
        acc
    });
    println!("{}", m.report_line());

    // --- Full aggregation (hash + rank + register update) ---
    let cfg64 = HllConfig::PAPER;
    let cfg32 = HllConfig::new(16, HashKind::H32).unwrap();
    let m = b.run_bytes("insert_batch H64 (sketch hot path)", bytes, || {
        let mut s = HllSketch::new(cfg64);
        s.insert_batch(&words);
        s
    });
    println!("{}", m.report_line());
    let m = b.run_bytes("insert_batch H32", bytes, || {
        let mut s = HllSketch::new(cfg32);
        s.insert_batch(&words);
        s
    });
    println!("{}", m.report_line());
    let m = b.run_bytes("aggregate64_batched (4-lane)", bytes, || {
        let mut s = HllSketch::new(cfg64);
        aggregate64_batched(&words, &mut s);
        s
    });
    println!("{}", m.report_line());
    let m = b.run_bytes("aggregate32_batched (8-lane AVX2-style)", bytes, || {
        let mut s = HllSketch::new(cfg32);
        aggregate32_batched(&words, &mut s);
        s
    });
    println!("{}", m.report_line());

    // --- Batch ingest path (registry's split: hash once, fold runs) ---
    // The registry hot path hashes every word in one tight loop
    // (`hash_words`) and folds the pre-hashed run into register files
    // (`insert_hashes`); these time each stage and the whole split.
    let mut hashes = vec![0u64; n];
    let m = b.run_bytes("hash_words H64 (8-lane batch hash loop)", bytes, || {
        cfg64.hash_words(&words, &mut hashes);
        hashes[0]
    });
    println!("{}", m.report_line());
    println!("{}", per_word(&m, n));
    let m = b.run_bytes("hash_words H32 (8-lane batch hash loop)", bytes, || {
        cfg32.hash_words(&words, &mut hashes);
        hashes[0]
    });
    println!("{}", m.report_line());
    println!("{}", per_word(&m, n));
    cfg64.hash_words(&words, &mut hashes);
    let m = b.run_bytes("insert_hashes (pre-hashed dense fold)", bytes, || {
        let mut s = HllSketch::new(cfg64);
        s.insert_hashes(&hashes);
        s
    });
    println!("{}", m.report_line());
    println!("{}", per_word(&m, n));
    let m = b.run_bytes("hash_words + insert_hashes (full batch path)", bytes, || {
        let mut s = HllSketch::new(cfg64);
        cfg64.hash_words(&words, &mut hashes);
        s.insert_hashes(&hashes);
        s
    });
    println!("{}", m.report_line());
    println!("{}", per_word(&m, n));
    let m = b.run_bytes("adaptive insert_hashes (sparse->packed tiers)", bytes, || {
        let mut s = AdaptiveSketch::new(cfg64);
        s.insert_hashes(&hashes);
        s
    });
    println!("{}", m.report_line());
    println!("{}", per_word(&m, n));

    // --- Computation phase + merge ---
    let mut filled = HllSketch::new(cfg64);
    filled.insert_batch(&words);
    let m = b.run_items("estimate (power sum over 65536 regs)", 1, || filled.estimate());
    println!("{}", m.report_line());
    let other = filled.clone();
    let m = b.run_items("merge (bucket-wise max, 65536 regs)", 1, || {
        let mut a = filled.clone();
        a.merge(&other).unwrap();
        a
    });
    println!("{}", m.report_line());

    // --- PJRT engine batch call (8192-word artifact) ---
    if Manifest::default_dir().join("manifest.tsv").exists() {
        let svc = XlaService::start().expect("xla service");
        let eng = XlaEngine::new(svc.handle(), cfg64, 8192).unwrap();
        let batch = &words[..8192];
        let m = b.run_bytes("xla aggregate (8192-word artifact call)", 8192 * 4, || {
            let mut s = HllSketch::new(cfg64);
            eng.aggregate(batch, &mut s).unwrap();
            s
        });
        println!("{}", m.report_line());
        let m = b.run_items("xla estimate artifact call", 1, || eng.estimate(&filled).unwrap());
        println!("{}", m.report_line());
    } else {
        println!("(artifacts not built; skipping PJRT hot-path benches)");
    }
}
