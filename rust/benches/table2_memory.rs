//! Bench/regeneration target for Table II: memory footprint, plus the
//! serialization cost of shipping sketches at each configuration, plus
//! the packed-tier capacity column (this repo's three-tier extension):
//! how many resident keys a fixed byte budget holds when mid-size keys
//! land in the packed tier instead of going straight dense.

use hll_fpga::bench_harness::bench_main;
use hll_fpga::hll::{AdaptiveSketch, HashKind, HllConfig, HllSketch};
use hll_fpga::registry::{RegistryConfig, SketchRegistry};
use hll_fpga::util::fmt::TextTable;

/// Build a representative tenant: `words` distinct values, seeded per
/// key so streams are disjoint across keys.
fn tenant_words(key: u64, words: u32) -> Vec<u32> {
    (0..words)
        .map(|v| (v as u64 ^ (key << 24)).wrapping_mul(0x9E37_79B9_7F4A_7C15) as u32)
        .collect()
}

/// The packed column: measured bytes/key per tier at p=14, then an
/// end-to-end capacity run under a fixed `max_memory_bytes` budget.
/// Asserts the ≥2.5× resident-key gate (bench exit code is the signal).
fn packed_capacity_column() {
    let cfg = HllConfig::new(14, HashKind::H64).unwrap();
    let m = cfg.m();

    // Measured bytes/key for a small, a mid-size and a dense-equivalent
    // tenant. The mid-size (~2 000 distinct words) is the shape the
    // packed tier exists for: too wide for sparse, mostly-zero dense.
    let mut t = TextTable::new(vec!["tier", "tenant words", "bytes/key", "keys per MiB"]);
    let tier_bytes = |words: u32| -> (AdaptiveSketch, usize) {
        let mut sk = AdaptiveSketch::new(cfg);
        for &w in &tenant_words(1, words) {
            sk.insert_u32(w);
        }
        let bytes = sk.memory_bytes();
        (sk, bytes)
    };
    let (small, small_b) = tier_bytes(300);
    assert!(small.is_sparse(), "300-word tenant must stay sparse");
    let (mid, mid_b) = tier_bytes(2_000);
    assert!(mid.is_packed(), "2 000-word tenant must land packed");
    for (tier, words, bytes) in [
        ("sparse", 300usize, small_b),
        ("packed", 2_000, mid_b),
        ("dense", m, m),
    ] {
        t.row(vec![
            tier.to_string(),
            hll_fpga::util::fmt::count(words as u64),
            bytes.to_string(),
            format!("{:.0}", (1 << 20) as f64 / bytes as f64),
        ]);
    }
    println!("Packed-tier capacity at p=14 H64 (m = {m} B dense)\n");
    println!("{}", t.render());

    // End-to-end: fixed 1 MiB budget, 2 000-word tenants, LRU eviction.
    // Dense-only floor is budget/m = 64 resident keys; the gate demands
    // the packed tier carry ≥ 2.5× that.
    let budget = 1usize << 20;
    let registry: SketchRegistry<u64> = SketchRegistry::new(RegistryConfig {
        hll: cfg,
        shards: 8,
        track_global: false,
        max_memory_bytes: Some(budget),
        ..RegistryConfig::default()
    })
    .unwrap();
    for key in 0..400u64 {
        registry.ingest(key, &tenant_words(key, 2_000));
        registry.enforce_budget();
    }
    let resident = registry.len();
    let stats = registry.stats();
    let dense_floor = budget / m;
    println!(
        "1 MiB budget, 2 000-word tenants: {resident} resident keys \
         ({} packed / {} sparse / {} dense), dense-only floor {dense_floor} \
         → {:.2}× capacity\n",
        stats.packed_keys(),
        stats.sparse_keys(),
        stats.dense_keys(),
        resident as f64 / dense_floor as f64,
    );
    assert!(
        resident * 2 >= dense_floor * 5,
        "packed capacity gate FAILED: {resident} resident < 2.5 × {dense_floor}"
    );
}

fn main() {
    let b = bench_main("Table II — HyperLogLog memory footprint");
    println!("{}", hll_fpga::repro::tables::table2());
    packed_capacity_column();

    // The footprint table is analytic; what costs time at runtime is
    // moving sketches around (the coordinator ships partials on merge).
    for p in [14u8, 16] {
        for h in [HashKind::H32, HashKind::H64] {
            let cfg = HllConfig::new(p, h).unwrap();
            let mut s = HllSketch::new(cfg);
            for v in 0..200_000u32 {
                s.insert_u32(v.wrapping_mul(2_654_435_761));
            }
            let bytes = s.to_bytes();
            let m = b.run_bytes(
                &format!("serialize+parse sketch p={p} H={}", h.bits()),
                bytes.len() as u64,
                || {
                    let b2 = s.to_bytes();
                    HllSketch::from_bytes(&b2).unwrap()
                },
            );
            println!("{}", m.report_line());
        }
    }
}
