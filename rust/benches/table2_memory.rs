//! Bench/regeneration target for Table II: memory footprint, plus the
//! serialization cost of shipping sketches at each configuration.

use hll_fpga::bench_harness::bench_main;
use hll_fpga::hll::{HashKind, HllConfig, HllSketch};

fn main() {
    let b = bench_main("Table II — HyperLogLog memory footprint");
    println!("{}", hll_fpga::repro::tables::table2());

    // The footprint table is analytic; what costs time at runtime is
    // moving sketches around (the coordinator ships partials on merge).
    for p in [14u8, 16] {
        for h in [HashKind::H32, HashKind::H64] {
            let cfg = HllConfig::new(p, h).unwrap();
            let mut s = HllSketch::new(cfg);
            for v in 0..200_000u32 {
                s.insert_u32(v.wrapping_mul(2_654_435_761));
            }
            let bytes = s.to_bytes();
            let m = b.run_bytes(
                &format!("serialize+parse sketch p={p} H={}", h.bits()),
                bytes.len() as u64,
                || {
                    let b2 = s.to_bytes();
                    HllSketch::from_bytes(&b2).unwrap()
                },
            );
            println!("{}", m.report_line());
        }
    }
}
