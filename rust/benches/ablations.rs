//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * BRAM hazard forwarding on/off (Section V-A-4's update merging);
//! * DMA chunk size vs effective PCIe bandwidth;
//! * coordinator batch size vs throughput;
//! * 4-lane vs scalar 64-bit hashing (the paper's "not beneficial"
//!   observation for AVX2);
//! * sparse vs dense sketch memory at small cardinalities.

use hll_fpga::bench_harness::{bench_main, quick_mode};
use hll_fpga::coordinator::{run_stream, CoordinatorConfig};
use hll_fpga::fpga::BucketMemory;
use hll_fpga::hll::murmur3::murmur3_x64_64_u32;
use hll_fpga::hll::{AdaptiveSketch, HllConfig, HllSketch};
use hll_fpga::pcie::PcieLink;
use hll_fpga::util::Xoshiro256StarStar;

fn main() {
    let b = bench_main("ablations");
    let n: usize = if quick_mode() { 100_000 } else { 1_000_000 };
    let mut rng = Xoshiro256StarStar::seed_from_u64(1);
    let words: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();

    // --- Ablation 1: BRAM hazard forwarding ---
    // Correctness effect: without the merge network, colliding in-flight
    // updates clobber registers. Measure how far the final estimate
    // drifts on a collision-heavy stream (few buckets).
    let cfg_small = HllConfig::new(4, hll_fpga::hll::HashKind::H64).unwrap();
    let probe = HllSketch::new(cfg_small);
    let updates: Vec<(usize, u8)> = words
        .iter()
        .take(50_000)
        .map(|&w| {
            let h = probe.hash_u32(w);
            let (i, r) = probe.index_and_rank(h);
            (i, r)
        })
        .collect();
    let mut with = BucketMemory::new(cfg_small.m());
    with.run(updates.iter().copied());
    let mut without = BucketMemory::without_forwarding(cfg_small.m());
    without.run(updates.iter().copied());
    let est_with = hll_fpga::hll::estimate(&cfg_small, with.registers()).estimate;
    let est_without = hll_fpga::hll::estimate(&cfg_small, without.registers()).estimate;
    println!(
        "BRAM hazard merge (p=4, 50k updates): with={est_with:.0} without={est_without:.0} \
         (drift {:+.1}%) — merging is required for correctness",
        (est_without - est_with) / est_with * 100.0
    );
    let m = b.run_items("bram clock() with forwarding", 50_000, || {
        let mut bm = BucketMemory::new(cfg_small.m());
        bm.run(updates.iter().copied());
        bm
    });
    println!("{}", m.report_line());

    // --- Ablation 2: DMA chunk size (PCIe batching) ---
    println!("\nPCIe effective bandwidth vs DMA chunk size (12.48 GB/s envelope):");
    let link = PcieLink::paper();
    for chunk in [4u64 << 10, 64 << 10, 1 << 20, 8 << 20, 64 << 20] {
        println!(
            "  chunk {:>8} KiB: {}",
            chunk >> 10,
            hll_fpga::util::fmt::gbytes_per_s(link.effective_bandwidth(chunk))
        );
    }

    // --- Ablation 3: coordinator batch size ---
    println!("\ncoordinator throughput vs batch size (4 pipelines, native engine):");
    for batch in [256usize, 1024, 8192, 65536] {
        let cfg = CoordinatorConfig {
            pipelines: 4,
            batch_size: batch,
            ..CoordinatorConfig::default()
        };
        let m = b.run_bytes(&format!("coordinator batch={batch}"), (n * 4) as u64, || {
            run_stream(cfg, None, &words).unwrap()
        });
        println!("{}", m.report_line());
    }

    // --- Ablation 4: 4-lane vs scalar 64-bit hash ---
    // The paper: 4-fold AVX2 vectorization of the 64-bit hash "did not
    // prove beneficial" — check the same on this machine.
    let m_scalar = b.run_bytes("hash64 scalar", (n * 4) as u64, || {
        let mut acc = 0u64;
        for &w in &words {
            acc ^= murmur3_x64_64_u32(w, 0);
        }
        acc
    });
    let m_lane = b.run_bytes("hash64 4-lane", (n * 4) as u64, || {
        let mut acc = 0u64;
        for chunk in words.chunks_exact(4) {
            let keys: &[u32; 4] = chunk.try_into().unwrap();
            for h in hll_fpga::cpu_baseline::hash64_x4(keys, 0) {
                acc ^= h;
            }
        }
        acc
    });
    println!("{}", m_scalar.report_line());
    println!("{}", m_lane.report_line());
    let gain = m_scalar.median() / m_lane.median();
    println!(
        "4-lane speedup: {gain:.2}x (paper observed ~1.0x on AVX2 — no native 64x64 vector mul)"
    );

    // --- Ablation 5: sparse vs dense memory ---
    println!("\nsparse vs dense sketch memory at small cardinality:");
    for n_small in [100usize, 1000, 10_000] {
        let mut sparse = AdaptiveSketch::new(HllConfig::PAPER);
        for &w in &words[..n_small] {
            sparse.insert_u32(w);
        }
        let dense_bytes = HllConfig::PAPER.m();
        println!(
            "  n={n_small:>6}: sparse={} dense={} bytes ({})",
            if sparse.is_sparse() { "yes" } else { "upgraded" },
            dense_bytes,
            if sparse.is_sparse() { "saves memory" } else { "dense wins" }
        );
    }
}
