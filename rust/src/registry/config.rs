//! Registry configuration and accounting types.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::hll::{EstimatorKind, HllConfig};

/// Coarse wall-time source for [`super::SketchRegistry`]'s
/// wall-clock TTL ([`super::SketchRegistry::evict_idle_wall`]).
///
/// The registry reads it once per mutating call (not per word), so the
/// default [`WallClock::System`] costs one `SystemTime::now()` per batch.
/// Tests inject [`WallClock::manual`] and advance the shared cell to age
/// keys deterministically without sleeping.
#[derive(Debug, Clone)]
pub enum WallClock {
    /// Seconds since `UNIX_EPOCH` via `SystemTime::now()`.
    System,
    /// A shared counter of seconds, advanced by the test (or embedder).
    Manual(Arc<AtomicU64>),
}

impl WallClock {
    /// A manual clock starting at `start_secs`, plus the cell that
    /// advances it (store a larger value to move time forward).
    pub fn manual(start_secs: u64) -> (WallClock, Arc<AtomicU64>) {
        let cell = Arc::new(AtomicU64::new(start_secs));
        (WallClock::Manual(cell.clone()), cell)
    }

    /// Current time in whole seconds.
    pub fn now_secs(&self) -> u64 {
        match self {
            WallClock::System => SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
            WallClock::Manual(cell) => cell.load(Ordering::Relaxed),
        }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::System
    }
}

/// Static parameters of a [`super::SketchRegistry`].
#[derive(Debug, Clone, Copy)]
pub struct RegistryConfig {
    /// Per-key sketch parameters (all keys share one config; mixed-config
    /// registries would make cross-key merges unsound).
    pub hll: HllConfig,
    /// Number of mutex stripes; must be a power of two so the shard
    /// selector is a mask. More shards = less ingest contention, more
    /// fixed overhead; 64 is a good default for up to ~16 threads.
    pub shards: usize,
    /// Maintain a lock-free all-keys union sketch updated on every
    /// ingested word (answers global distinct counts in O(m)).
    pub track_global: bool,
    /// Soft cap on total sketch heap bytes (the sum
    /// [`RegistryStats::memory_bytes`] reports). When set,
    /// [`super::SketchRegistry::enforce_budget`] evicts
    /// least-recently-touched keys until back under; `None` disables the
    /// budget. The cap is a target, not a hard limit — ingest never
    /// blocks on it.
    pub max_memory_bytes: Option<usize>,
    /// Which estimator answers `estimate`/`for_each_estimate` queries.
    /// Storage is estimator-agnostic; this only selects the computation
    /// phase ([`EstimatorKind::Ertl`] by default).
    pub estimator: EstimatorKind,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        Self {
            hll: HllConfig::PAPER,
            shards: 64,
            track_global: true,
            max_memory_bytes: None,
            estimator: EstimatorKind::default(),
        }
    }
}

impl RegistryConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.shards == 0 {
            return Err("shards must be >= 1".into());
        }
        if !self.shards.is_power_of_two() {
            return Err(format!("shards must be a power of two, got {}", self.shards));
        }
        Ok(())
    }
}

/// Point-in-time accounting for one shard.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Live keys in this shard.
    pub keys: usize,
    /// Keys still in the sparse representation.
    pub sparse_keys: usize,
    /// Keys compressed into the packed (base + 3-bit delta) tier.
    pub packed_keys: usize,
    /// Keys upgraded to the dense register file.
    pub dense_keys: usize,
    /// Approximate heap bytes held by this shard's sketches.
    pub memory_bytes: usize,
    /// Words ingested through this shard since creation.
    pub words: u64,
}

/// Registry-wide accounting: per-shard stats plus totals and the
/// estimator answering this registry's queries.
#[derive(Debug, Clone, Default)]
pub struct RegistryStats {
    pub shards: Vec<ShardStats>,
    /// The configured [`RegistryConfig::estimator`].
    pub estimator: EstimatorKind,
}

impl RegistryStats {
    pub fn keys(&self) -> usize {
        self.shards.iter().map(|s| s.keys).sum()
    }

    pub fn sparse_keys(&self) -> usize {
        self.shards.iter().map(|s| s.sparse_keys).sum()
    }

    pub fn packed_keys(&self) -> usize {
        self.shards.iter().map(|s| s.packed_keys).sum()
    }

    pub fn dense_keys(&self) -> usize {
        self.shards.iter().map(|s| s.dense_keys).sum()
    }

    /// Which estimator computed/answers this registry's estimates.
    pub fn estimator(&self) -> EstimatorKind {
        self.estimator
    }

    pub fn memory_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.memory_bytes).sum()
    }

    pub fn words(&self) -> u64 {
        self.shards.iter().map(|s| s.words).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        assert!(RegistryConfig::default().validate().is_ok());
    }

    #[test]
    fn non_power_of_two_shards_rejected() {
        let mut c = RegistryConfig::default();
        c.shards = 0;
        assert!(c.validate().is_err());
        c.shards = 48;
        assert!(c.validate().is_err());
        c.shards = 1;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn manual_wall_clock_advances() {
        let (wall, cell) = WallClock::manual(100);
        assert_eq!(wall.now_secs(), 100);
        cell.store(250, Ordering::Relaxed);
        assert_eq!(wall.now_secs(), 250);
        // The system clock reads as a plausible epoch time.
        assert!(WallClock::System.now_secs() > 1_500_000_000);
    }

    #[test]
    fn stats_totals_sum_shards() {
        let stats = RegistryStats {
            shards: vec![
                ShardStats {
                    keys: 3,
                    sparse_keys: 1,
                    packed_keys: 1,
                    dense_keys: 1,
                    memory_bytes: 100,
                    words: 7,
                },
                ShardStats {
                    keys: 3,
                    sparse_keys: 3,
                    packed_keys: 0,
                    dense_keys: 0,
                    memory_bytes: 50,
                    words: 5,
                },
            ],
            estimator: EstimatorKind::default(),
        };
        assert_eq!(stats.keys(), 6);
        assert_eq!(stats.sparse_keys(), 4);
        assert_eq!(stats.packed_keys(), 1);
        assert_eq!(stats.dense_keys(), 1);
        assert_eq!(stats.memory_bytes(), 150);
        assert_eq!(stats.words(), 12);
        assert_eq!(stats.estimator(), EstimatorKind::Ertl);
    }
}
