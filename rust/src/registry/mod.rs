//! Multi-tenant sketch store: millions of concurrent, keyed HLL sketches
//! behind a shard-striped registry.
//!
//! The paper accelerates *one* stream's sketch; a production deployment
//! ("how many distinct items per user / per flow / per tenant?") needs
//! one sketch per key, alive simultaneously for millions of keys. This
//! module provides that layer, following the architecture production HLL
//! stores use (HLL++-style adaptive sketches behind a striped map):
//!
//! * each key owns an [`crate::hll::AdaptiveSketch`] — sparse
//!   (index,rank) pairs while small, upgraded to a dense register file
//!   at the HLL++ threshold, so a million mostly-small keys cost MBs,
//!   not `1M × 64 KiB`;
//! * keys are striped over `shards` (power of two) mutexes, so ingest
//!   threads working different shards never contend — the locking
//!   analogue of the paper's "inputs are processed where they arrive"
//!   slicing (Section V-B);
//! * an optional registry-global [`crate::hll::ConcurrentHllSketch`] is
//!   raised lock-free on every ingested word, answering "distinct items
//!   across *all* keys" in O(m) without walking a single shard — this is
//!   Fig 3's merge fold running continuously instead of at stream end.
//!
//! Keyed batch ingest, bulk estimate/merge/evict, and per-shard memory
//! accounting are on [`SketchRegistry`]; [`crate::coordinator::keyed`]
//! drives it with pipeline workers and
//! [`crate::runtime::RegistryService`] exposes it to query clients.

pub mod config;
pub mod registry;
pub mod shard;

pub use config::{RegistryConfig, RegistryStats, ShardStats};
pub use registry::SketchRegistry;
