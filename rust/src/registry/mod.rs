//! Multi-tenant sketch store: millions of concurrent, keyed HLL sketches
//! behind a shard-striped registry.
//!
//! The paper accelerates *one* stream's sketch; a production deployment
//! ("how many distinct items per user / per flow / per tenant?") needs
//! one sketch per key, alive simultaneously for millions of keys. This
//! module provides that layer, following the architecture production HLL
//! stores use (HLL++-style adaptive sketches behind a striped map):
//!
//! * each key owns an [`crate::hll::AdaptiveSketch`] — sparse
//!   (index,rank) pairs while small, upgraded to a dense register file
//!   at the HLL++ threshold, so a million mostly-small keys cost MBs,
//!   not `1M × 64 KiB`;
//! * keys are striped over `shards` (power of two) mutexes, so ingest
//!   threads working different shards never contend — the locking
//!   analogue of the paper's "inputs are processed where they arrive"
//!   slicing (Section V-B);
//! * an optional registry-global [`crate::hll::ConcurrentHllSketch`] is
//!   raised lock-free on every ingested word, answering "distinct items
//!   across *all* keys" in O(m) without walking a single shard — this is
//!   Fig 3's merge fold running continuously instead of at stream end.
//!
//! Keyed batch ingest, bulk estimate/merge/evict, and per-shard memory
//! accounting are on [`SketchRegistry`]; [`crate::coordinator::keyed`]
//! drives it with pipeline workers,
//! [`crate::runtime::RegistryService`] exposes it to in-process query
//! clients, and [`crate::server`] puts a real TCP protocol (plus
//! snapshot/restore) in front of it for remote producers and queries.
//!
//! Lifecycle management beyond explicit eviction: every key records the
//! logical tick of its last touch *and* a coarse wall-clock second,
//! feeding two TTL sweeps ([`SketchRegistry::evict_idle`] in ingest
//! ticks, [`SketchRegistry::evict_idle_wall`] in real time via an
//! injectable [`WallClock`]) and LRU size-budget enforcement
//! ([`SketchRegistry::enforce_budget`] against
//! [`RegistryConfig::max_memory_bytes`]). Registry contents round-trip
//! through [`SketchRegistry::export_sketches`] /
//! [`SketchRegistry::restore`] in the seed-carrying sketch wire format
//! v2, which is what the snapshot file format and the `MergeSketch` RPC
//! are built on.
//!
//! Replication support: with [`SketchRegistry::enable_dirty_tracking`]
//! on, every mutating touch records *what changed* in a per-shard dirty
//! map — the exact dense registers an ingest raised, a full-resend
//! marker for sparse keys and merges, and an eviction tombstone when
//! any eviction path (explicit, TTL, budget, clear) removes a key.
//! [`SketchRegistry::drain_dirty_deltas`] swaps those maps out and
//! resolves each key into a typed [`SketchDelta`] (tombstone / register
//! diff / full sketch) — the feed of
//! [`crate::replica::ReplicationLog`]'s delta batches. The global
//! union tracks its own raised registers in a lock-free bitmap,
//! drained by [`SketchRegistry::drain_dirty_global`] into a
//! [`SketchDelta::GlobalDiff`], so words whose key is evicted before a
//! capture still replicate into followers' global estimates.

pub mod config;
pub mod registry;
pub mod shard;

pub use config::{RegistryConfig, RegistryStats, ShardStats, WallClock};
pub use registry::{SketchDelta, SketchRegistry};
