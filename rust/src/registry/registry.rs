//! The shard-striped, concurrent, keyed sketch store.

use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use super::config::{RegistryConfig, RegistryStats, WallClock};
use super::shard::Shard;
use crate::hll::{AdaptiveSketch, ConcurrentHllSketch, HllConfig, HllSketch, SketchError};

/// Reusable buffers for one batch-ingest call: every ingest entry point
/// hashes, routes and gathers through these instead of allocating fresh
/// vectors per call (the old `ingest_pairs` allocated a `Vec<Vec<_>>`
/// per batch; `ingest` a `Vec<u64>` of hashes). Checked out of the
/// registry's [`ScratchPool`] for the duration of one call.
#[derive(Debug, Default)]
struct IngestScratch {
    /// The batch's words, copied contiguous so [`HllConfig::hash_words`]
    /// sees one flat slice (pair/triple inputs interleave words with
    /// keys).
    words: Vec<u32>,
    /// Hash of each batch word, in input order.
    hashes: Vec<u64>,
    /// `(shard, route mix, input index)` per pair; sorting groups the
    /// batch by shard and, within a shard, brings equal keys together
    /// (equal keys share a mix) while preserving input order per key
    /// (the index tiebreak).
    route: Vec<(u32, u64, u32)>,
    /// Hashes regathered contiguous per key run, one shard at a time.
    gathered: Vec<u64>,
    /// Key runs of the shard currently being ingested:
    /// `(input index of the key, start, len)` into `gathered`.
    runs: Vec<(u32, u32, u32)>,
}

impl IngestScratch {
    fn clear(&mut self) {
        self.words.clear();
        self.hashes.clear();
        self.route.clear();
        self.gathered.clear();
        self.runs.clear();
    }
}

/// A small pool of [`IngestScratch`] buffers shared by all ingest
/// threads. Bounded: steady-state concurrency determines how many
/// buffers exist, and surplus returns are dropped rather than hoarding
/// the high-water batch size forever.
#[derive(Debug, Default)]
struct ScratchPool {
    bufs: Mutex<Vec<IngestScratch>>,
}

/// Pooled scratch buffers kept at rest. More concurrent ingest callers
/// than this just allocate a fresh scratch and drop it on return.
const SCRATCH_POOL_CAP: usize = 16;

impl ScratchPool {
    /// Check a scratch out (fresh if the pool is empty). Recovers from
    /// poison like the shard locks: the pool holds plain buffers that
    /// cannot be left logically torn.
    fn take(&self) -> IngestScratch {
        self.bufs.lock().unwrap_or_else(PoisonError::into_inner).pop().unwrap_or_default()
    }

    fn put(&self, mut scratch: IngestScratch) {
        scratch.clear();
        let mut bufs = self.bufs.lock().unwrap_or_else(PoisonError::into_inner);
        if bufs.len() < SCRATCH_POOL_CAP {
            bufs.push(scratch);
        }
    }
}

/// One replication delta for one key — what a dirty-tracking drain
/// ([`SketchRegistry::drain_dirty_deltas`]) resolved that key's changes
/// into, and the typed entry a `DELTA_BATCH` v3 frame carries on the
/// wire (see [`crate::server::protocol`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SketchDelta {
    /// The key was evicted; followers must remove it.
    Tombstone,
    /// Only these registers moved since the last drain: a sparse
    /// register diff in the [`crate::hll::encode_register_diff`] wire
    /// format. Applying is a per-register max-merge.
    RegisterDiff(Vec<u8>),
    /// The key's full sketch in wire format v2 — the fallback for
    /// sparse-mode keys, merges, re-created keys and diffs past the
    /// density threshold.
    Full(Vec<u8>),
    /// Registers of the registry's *global union* sketch raised since
    /// the last capture ([`SketchRegistry::drain_dirty_global`]), in
    /// the same register-diff wire format. The entry's key field is
    /// meaningless (encoded as 0). This is what closes the
    /// evicted-before-capture gap: words whose key died before the
    /// capture tick still reach followers' `GlobalEstimate`.
    GlobalDiff(Vec<u8>),
}

impl SketchDelta {
    /// Serialized payload length of this delta's body (0 for a
    /// tombstone) — the per-entry size input of the replication log's
    /// batch-size caps.
    pub fn body_len(&self) -> usize {
        match self {
            SketchDelta::Tombstone => 0,
            SketchDelta::RegisterDiff(b) | SketchDelta::Full(b) | SketchDelta::GlobalDiff(b) => {
                b.len()
            }
        }
    }
}

/// A concurrent registry of per-key adaptive HLL sketches.
///
/// All methods take `&self`; the registry is `Send + Sync` and is
/// normally shared as an `Arc` between ingest workers (see
/// [`crate::coordinator::keyed`]), query servers (see
/// [`crate::runtime::RegistryService`]) and the network serving layer
/// (see [`crate::server`]).
#[derive(Debug)]
pub struct SketchRegistry<K> {
    cfg: RegistryConfig,
    shards: Vec<Shard<K>>,
    shard_mask: usize,
    /// Lock-free union of every ingested word, if configured.
    global: Option<ConcurrentHllSketch>,
    /// Monotone logical clock: one tick per mutating call. Keys record
    /// the tick of their last touch, which drives [`Self::evict_idle`]
    /// (TTL) and the LRU order of [`Self::evict_to_budget`].
    clock: AtomicU64,
    /// Coarse wall-time source, read once per mutating call; feeds the
    /// Duration-based TTL sweep [`Self::evict_idle_wall`]. Injectable
    /// via [`Self::with_wall_clock`], `SystemTime`-backed by default.
    wall: WallClock,
    /// When set (see [`Self::enable_dirty_tracking`]), every mutating
    /// touch records *what changed* (raised registers, full-resend
    /// markers, eviction tombstones) in a per-shard dirty map, drained
    /// by [`Self::drain_dirty_deltas`] — the feed of the replication
    /// log ([`crate::replica`]). Off by default: a registry nobody
    /// drains must not accumulate dirty state forever.
    dirty_enabled: Arc<AtomicBool>,
    /// Reusable batch-ingest buffers (hash, route, gather) checked out
    /// per call — see [`IngestScratch`].
    scratch: ScratchPool,
}

impl<K: Eq + Hash + Clone> SketchRegistry<K> {
    pub fn new(cfg: RegistryConfig) -> Result<Self, String> {
        Self::with_wall_clock(cfg, WallClock::System)
    }

    /// As [`Self::new`], with an explicit wall-time source (tests inject
    /// [`WallClock::manual`] to age keys without sleeping).
    pub fn with_wall_clock(cfg: RegistryConfig, wall: WallClock) -> Result<Self, String> {
        cfg.validate()?;
        let dirty_enabled = Arc::new(AtomicBool::new(false));
        let shards = (0..cfg.shards).map(|_| Shard::new(dirty_enabled.clone())).collect();
        let global = cfg.track_global.then(|| ConcurrentHllSketch::new(cfg.hll));
        Ok(Self {
            cfg,
            shards,
            shard_mask: cfg.shards - 1,
            global,
            clock: AtomicU64::new(0),
            wall,
            dirty_enabled,
            scratch: ScratchPool::default(),
        })
    }

    /// Convenience: default registry config, shared-ready.
    pub fn shared(cfg: RegistryConfig) -> Result<Arc<Self>, String> {
        Ok(Arc::new(Self::new(cfg)?))
    }

    pub fn config(&self) -> &RegistryConfig {
        &self.cfg
    }

    /// Current value of the logical ingest clock (ticks, not wall time).
    pub fn now(&self) -> u64 {
        self.clock.load(Ordering::Relaxed)
    }

    /// Current wall-clock reading in whole seconds (from the configured
    /// [`WallClock`] source).
    pub fn wall_now_secs(&self) -> u64 {
        self.wall.now_secs()
    }

    /// Advance the clock by one mutating call and return the new tick.
    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Turn on per-shard dirty tracking (idempotent). A replication
    /// primary enables this before accepting subscribers; keys touched
    /// while tracking was off reach followers through their bootstrap
    /// full sync, not the delta log. With tracking on, evictions are
    /// recorded as tombstones so TTL/budget sweeps propagate too, and
    /// the global union (if tracked) starts recording its raised
    /// registers for [`Self::drain_dirty_global`] — off, neither costs
    /// a byte or an extra atomic.
    pub fn enable_dirty_tracking(&self) {
        if let Some(global) = &self.global {
            global.enable_dirty_tracking();
        }
        self.dirty_enabled.store(true, Ordering::SeqCst);
    }

    pub fn dirty_tracking_enabled(&self) -> bool {
        self.dirty_enabled.load(Ordering::SeqCst)
    }

    /// Route a key: `(stripe, mix)` where `mix` is the full finalized
    /// key hash the stripe is masked from. Batch ingest sorts on the
    /// mix to bring equal keys together within a shard group (equal
    /// keys share a mix; colliding unequal keys just split into more
    /// runs, harmlessly).
    fn route_of(&self, key: &K) -> (usize, u64) {
        let mut hasher = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut hasher);
        // Finalize with a splitmix-style mix so low-entropy key hashes
        // (sequential integers) still spread across stripes.
        let mut x = hasher.finish();
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
        ((x as usize) & self.shard_mask, x)
    }

    /// Which stripe a key lives on. Stable across the registry's
    /// lifetime; the keyed coordinator also uses it to route whole
    /// shards to dedicated workers so shard locks never see contention.
    pub fn shard_of(&self, key: &K) -> usize {
        self.route_of(key).0
    }

    /// Ingest a batch of words for one key: hash in one tight loop
    /// (into pooled scratch — no per-call allocation), raise the global
    /// union in one pass, then fold the whole run into the key's sketch
    /// under one lock acquisition.
    pub fn ingest(&self, key: K, words: &[u32]) {
        if words.is_empty() {
            return;
        }
        let now = self.tick();
        let wall = self.wall.now_secs();
        let mut scratch = self.scratch.take();
        scratch.hashes.resize(words.len(), 0);
        self.cfg.hll.hash_words(words, &mut scratch.hashes);
        if let Some(global) = &self.global {
            global.insert_hashes(&scratch.hashes);
        }
        self.shards[self.shard_of(&key)].ingest_hashes(self.cfg.hll, &key, &scratch.hashes, now, wall);
        self.scratch.put(scratch);
    }

    /// Keyed batch ingest: hash every word in one tight loop, route and
    /// sort the batch so each shard's pairs group into per-key runs,
    /// then fold each shard's runs under a single lock acquisition —
    /// one map lookup, one touch and one dirty-state resolution per
    /// *key per batch* (the old path paid each per word, plus a
    /// `Vec<Vec<_>>` allocation per call; all buffers are pooled now).
    pub fn ingest_pairs(&self, pairs: &[(K, u32)]) {
        if pairs.is_empty() {
            return;
        }
        let now = self.tick();
        let wall = self.wall.now_secs();
        let mut scratch = self.scratch.take();
        scratch.words.extend(pairs.iter().map(|(_, w)| *w));
        scratch.hashes.resize(pairs.len(), 0);
        self.cfg.hll.hash_words(&scratch.words, &mut scratch.hashes);
        if let Some(global) = &self.global {
            global.insert_hashes(&scratch.hashes);
        }
        scratch.route.extend(pairs.iter().enumerate().map(|(i, (key, _))| {
            let (shard, mix) = self.route_of(key);
            (shard as u32, mix, i as u32)
        }));
        // (shard, mix, input index): shards group, equal keys within a
        // shard group (same mix), and each key's words stay in input
        // order (index tiebreak) so per-key insert order — and with it
        // tier-promotion timing — matches the word-at-a-time path.
        scratch.route.sort_unstable();
        let mut seg_start = 0;
        while seg_start < scratch.route.len() {
            let shard = scratch.route[seg_start].0;
            let mut seg_end = seg_start;
            while seg_end < scratch.route.len() && scratch.route[seg_end].0 == shard {
                seg_end += 1;
            }
            // Gather this shard's hashes contiguous, one slice per
            // maximal equal-key run. Mix equality is the cheap first
            // test; key equality decides (collisions split runs).
            scratch.gathered.clear();
            scratch.runs.clear();
            let seg = &scratch.route[seg_start..seg_end];
            let mut run_start = 0;
            while run_start < seg.len() {
                let (_, mix, key_idx) = seg[run_start];
                let key = &pairs[key_idx as usize].0;
                let start = scratch.gathered.len() as u32;
                let mut run_end = run_start;
                while run_end < seg.len()
                    && seg[run_end].1 == mix
                    && pairs[seg[run_end].2 as usize].0 == *key
                {
                    scratch.gathered.push(scratch.hashes[seg[run_end].2 as usize]);
                    run_end += 1;
                }
                scratch.runs.push((key_idx, start, scratch.gathered.len() as u32 - start));
                run_start = run_end;
            }
            self.shards[shard as usize].ingest_runs(
                self.cfg.hll,
                scratch.runs.iter().map(|&(key_idx, start, len)| {
                    (
                        &pairs[key_idx as usize].0,
                        &scratch.gathered[start as usize..(start + len) as usize],
                    )
                }),
                now,
                wall,
            );
            seg_start = seg_end;
        }
        self.scratch.put(scratch);
    }

    /// Keyed ingest for pairs already routed to one shard: callers that
    /// computed [`SketchRegistry::shard_of`] once on the feeder side
    /// (the keyed coordinator) pass it in instead of paying the key
    /// hash a second time per pair. Hashing runs up front in one tight
    /// loop (pooled scratch), and *consecutive* equal-key pairs fold as
    /// one run — feeders that sort by key (the keyed workers do) get
    /// one map lookup and one dirty resolution per key per batch.
    pub fn ingest_sharded(&self, shard: usize, pairs: &[(K, u32)]) {
        if pairs.is_empty() {
            return;
        }
        debug_assert!(
            pairs.iter().all(|(k, _)| self.shard_of(k) == shard),
            "pair routed to the wrong shard"
        );
        let now = self.tick();
        let wall = self.wall.now_secs();
        let mut scratch = self.scratch.take();
        scratch.words.extend(pairs.iter().map(|(_, w)| *w));
        scratch.hashes.resize(pairs.len(), 0);
        self.cfg.hll.hash_words(&scratch.words, &mut scratch.hashes);
        if let Some(global) = &self.global {
            global.insert_hashes(&scratch.hashes);
        }
        let hashes = &scratch.hashes;
        let mut pos = 0;
        self.shards[shard].ingest_runs(
            self.cfg.hll,
            std::iter::from_fn(move || {
                if pos >= pairs.len() {
                    return None;
                }
                let start = pos;
                let key = &pairs[start].0;
                let mut end = start + 1;
                while end < pairs.len() && pairs[end].0 == *key {
                    end += 1;
                }
                pos = end;
                Some((key, &hashes[start..end]))
            }),
            now,
            wall,
        );
        self.scratch.put(scratch);
    }

    /// As [`SketchRegistry::ingest_sharded`], but over a run of routed
    /// `(shard, key, word)` triples sharing one shard — read in place,
    /// so the keyed worker needs no reshaping buffer. Consecutive equal
    /// keys fold as one run, like [`SketchRegistry::ingest_sharded`].
    pub fn ingest_routed_run(&self, run: &[(usize, K, u32)]) {
        let Some(&(shard, _, _)) = run.first() else {
            return;
        };
        debug_assert!(
            run.iter().all(|(s, k, _)| *s == shard && self.shard_of(k) == shard),
            "triple routed to the wrong shard"
        );
        let now = self.tick();
        let wall = self.wall.now_secs();
        let mut scratch = self.scratch.take();
        scratch.words.extend(run.iter().map(|(_, _, w)| *w));
        scratch.hashes.resize(run.len(), 0);
        self.cfg.hll.hash_words(&scratch.words, &mut scratch.hashes);
        if let Some(global) = &self.global {
            global.insert_hashes(&scratch.hashes);
        }
        let hashes = &scratch.hashes;
        let mut pos = 0;
        self.shards[shard].ingest_runs(
            self.cfg.hll,
            std::iter::from_fn(move || {
                if pos >= run.len() {
                    return None;
                }
                let start = pos;
                let key = &run[start].1;
                let mut end = start + 1;
                while end < run.len() && run[end].1 == *key {
                    end += 1;
                }
                pos = end;
                Some((key, &hashes[start..end]))
            }),
            now,
            wall,
        );
        self.scratch.put(scratch);
    }

    /// Cardinality estimate for one key (`None` if the key is unknown),
    /// computed by the configured [`RegistryConfig::estimator`].
    pub fn estimate(&self, key: &K) -> Option<f64> {
        self.shards[self.shard_of(key)].estimate(key, self.cfg.estimator)
    }

    /// Bulk estimate: every live (key, estimate) pair, shard by shard.
    pub fn estimates(&self) -> Vec<(K, f64)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            shard.for_each_estimate(self.cfg.estimator, |k, e| out.push((k.clone(), e)));
        }
        out
    }

    /// Distinct count across *all* keys from the lock-free global
    /// sketch; `None` when `track_global` is off.
    pub fn global_estimate(&self) -> Option<f64> {
        self.global.as_ref().map(|g| g.estimate())
    }

    /// Union of every key's sketch, folded bucket-wise (Fig 3's merge at
    /// registry scale). Equals the global sketch when tracking is on.
    pub fn merge_all(&self) -> HllSketch {
        let mut acc = HllSketch::new(self.cfg.hll);
        for shard in &self.shards {
            shard.fold_into(&mut acc);
        }
        acc
    }

    /// Merge key `src`'s sketch into `dst` (removing `src`). Locks are
    /// taken one shard at a time, never nested.
    pub fn merge_keys(&self, dst: K, src: &K) -> Result<bool, SketchError> {
        let Some(sketch) = self.shards[self.shard_of(src)].take(src) else {
            return Ok(false);
        };
        self.shards[self.shard_of(&dst)].merge_in(
            self.cfg.hll,
            dst,
            sketch,
            self.tick(),
            self.wall.now_secs(),
        )?;
        Ok(true)
    }

    /// Merge a dense sketch (typically wire-decoded) into `key`, creating
    /// the key if absent — the serving layer's `MergeSketch` RPC and the
    /// snapshot restore path. The global union, if tracked, is raised
    /// too, so remotely merged registers are counted by
    /// [`Self::global_estimate`] exactly like locally ingested words.
    /// Config (including hash seed) must match the registry's; mismatches
    /// fail with [`SketchError::ConfigMismatch`] before any state changes.
    pub fn merge_sketch(&self, key: K, sketch: HllSketch) -> Result<(), SketchError> {
        if *sketch.config() != self.cfg.hll {
            return Err(SketchError::ConfigMismatch(*sketch.config(), self.cfg.hll));
        }
        if let Some(global) = &self.global {
            global.merge_sketch(&sketch)?;
        }
        let now = self.tick();
        let wall = self.wall.now_secs();
        // Re-compress into the most compact tier that holds the
        // registers losslessly: a restore of a million mostly-small keys
        // must not resident them all as m-byte dense files.
        self.shards[self.shard_of(&key)].merge_in(
            self.cfg.hll,
            key,
            AdaptiveSketch::from_dense(sketch),
            now,
            wall,
        )
    }

    /// Batched [`Self::merge_sketch`]: every sketch's config is
    /// validated up front (the whole batch is rejected before any state
    /// changes), the global union is raised, then the entries are
    /// grouped by shard so each shard's run applies under a single lock
    /// acquisition — the follower's apply path for runs of consecutive
    /// full-sketch delta entries ([`crate::replica`]), where per-entry
    /// [`Self::merge_sketch`] paid one lock round trip per key. Merges
    /// are bucket-wise max (commutative, idempotent), so the grouping's
    /// reordering across keys cannot change any register.
    pub fn merge_sketch_batch(&self, entries: Vec<(K, HllSketch)>) -> Result<(), SketchError> {
        for (_, sketch) in &entries {
            if *sketch.config() != self.cfg.hll {
                return Err(SketchError::ConfigMismatch(*sketch.config(), self.cfg.hll));
            }
        }
        if let Some(global) = &self.global {
            for (_, sketch) in &entries {
                global.merge_sketch(sketch)?;
            }
        }
        let now = self.tick();
        let wall = self.wall.now_secs();
        let mut routed: Vec<(usize, K, AdaptiveSketch)> = entries
            .into_iter()
            .map(|(key, sketch)| {
                let shard = self.shard_of(&key);
                (shard, key, AdaptiveSketch::from_dense(sketch))
            })
            .collect();
        // Stable sort: equal-shard entries keep their batch order (the
        // documented apply-order contract, though max-merge makes any
        // order equivalent).
        routed.sort_by_key(|&(shard, _, _)| shard);
        while !routed.is_empty() {
            let shard = routed[0].0;
            let run = routed.iter().take_while(|&&(s, _, _)| s == shard).count();
            self.shards[shard].merge_in_batch(
                self.cfg.hll,
                routed.drain(..run).map(|(_, key, sketch)| (key, sketch)),
                now,
                wall,
            )?;
        }
        Ok(())
    }

    /// Visit every live key's sketch serialized in wire format v2
    /// (seed-carrying header; see [`crate::hll::sketch`]), shard by
    /// shard. Only one shard's records are materialized at a time, so a
    /// million-key snapshot walk peaks at one shard's serialization —
    /// not the whole registry's dense image. Sparse keys are densified
    /// into a temporary for encoding; live state is unchanged.
    pub fn for_each_sketch_bytes<F: FnMut(&K, Vec<u8>)>(&self, mut f: F) {
        for shard in &self.shards {
            let mut batch = Vec::new();
            shard.export_bytes(&mut batch);
            for (key, bytes) in batch {
                f(&key, bytes);
            }
        }
    }

    /// Every live key's sketch in wire format v2, collected into one
    /// vector. Convenient for tests and small registries; at scale this
    /// holds the full dense serialization in memory at once — the
    /// snapshot writer streams via [`Self::for_each_sketch_bytes`]
    /// instead.
    pub fn export_sketches(&self) -> Vec<(K, Vec<u8>)> {
        let mut out = Vec::new();
        self.for_each_sketch_bytes(|key, bytes| out.push((key.clone(), bytes)));
        out
    }

    /// Rebuild registry contents from `(key, sketch)` pairs (the inverse
    /// of [`Self::export_sketches`] after decoding) by merging each
    /// sketch into its key. Because sketch merge is a bucket-wise max,
    /// restoring over existing keys is lossless and idempotent: a
    /// restarted server that restores the latest snapshot serves
    /// identical estimates. Returns the number of entries applied; the
    /// first config/seed mismatch aborts with its error.
    pub fn restore<I: IntoIterator<Item = (K, HllSketch)>>(
        &self,
        entries: I,
    ) -> Result<usize, SketchError> {
        let mut applied = 0;
        for (key, sketch) in entries {
            self.merge_sketch(key, sketch)?;
            applied += 1;
        }
        Ok(applied)
    }

    /// Remove one key; returns its final dense sketch if it existed.
    pub fn evict(&self, key: &K) -> Option<HllSketch> {
        self.shards[self.shard_of(key)].evict(key)
    }

    /// Bulk evict: drop every key the predicate rejects; returns the
    /// number evicted. The predicate sees the key and its live sketch
    /// (mutable, so it can estimate).
    pub fn evict_where<F: FnMut(&K, &mut AdaptiveSketch) -> bool>(&self, mut evict: F) -> usize {
        self.shards.iter().map(|s| s.retain(|k, sk| !evict(k, sk))).sum()
    }

    /// TTL sweep: drop every key whose last touch is more than `max_age`
    /// ticks behind the current logical clock (see [`Self::now`]); idle
    /// tenants age out without explicit eviction calls. Returns the
    /// number evicted.
    pub fn evict_idle(&self, max_age: u64) -> usize {
        let cutoff = self.now().saturating_sub(max_age);
        self.shards.iter().map(|s| s.evict_idle(cutoff)).sum()
    }

    /// Wall-clock TTL sweep: drop every key whose last touch is more
    /// than `max_age` of real time behind the registry's wall clock
    /// (coarse, whole seconds — see [`WallClock`]). The logical-tick
    /// sweep [`Self::evict_idle`] ages keys by ingest activity; this one
    /// ages them by elapsed time, which is what "expire tenants idle for
    /// an hour" actually means on a quiet server. Returns the number
    /// evicted.
    pub fn evict_idle_wall(&self, max_age: Duration) -> usize {
        let cutoff = self.wall.now_secs().saturating_sub(max_age.as_secs());
        self.shards.iter().map(|s| s.evict_idle_wall(cutoff)).sum()
    }

    /// Drain every shard's dirty map, resolving each key's recorded
    /// changes into a typed [`SketchDelta`] — the feed the replication
    /// log seals into delta batches ([`crate::replica`]): register
    /// diffs for packed/dense keys whose changed registers were tracked,
    /// full wire-v2 sketches for sparse keys / merges / spilled diffs, and
    /// tombstones for evicted keys (an evict-then-recreate emits the
    /// tombstone *before* the new full sketch, in entry order). Empty
    /// unless [`Self::enable_dirty_tracking`] was called. The swap
    /// happens under each shard lock, so a concurrent mutation lands
    /// either in this drain or the next — never in neither; diff values
    /// are the registers' current maxima and merges are bucket-wise
    /// max, so draining a key twice is harmless.
    pub fn drain_dirty_deltas(&self) -> Vec<(K, SketchDelta)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            shard.drain_dirty(&mut out);
        }
        out
    }

    /// Max-merge a decoded register diff into `key` (created if absent)
    /// — the follower's apply path for [`SketchDelta::RegisterDiff`]
    /// entries. The diff's config (including hash seed) must match the
    /// registry's; mismatches fail before any state changes. The global
    /// union, if tracked, is raised with the same registers: a register
    /// that sets a new per-key max is exactly a register that may set a
    /// new global max (per-key registers never exceed the global's), so
    /// replicated diffs keep [`Self::global_estimate`] convergent the
    /// same way full-sketch merges do.
    pub fn apply_register_diff(
        &self,
        key: K,
        cfg: HllConfig,
        entries: &[(u32, u8)],
    ) -> Result<(), SketchError> {
        // Validates and raises the global union; the shard apply below
        // only runs once the whole diff is known good.
        self.merge_global_diff(cfg, entries)?;
        let now = self.tick();
        let wall = self.wall.now_secs();
        self.shards[self.shard_of(&key)].apply_register_diff(cfg, key, entries, now, wall);
        Ok(())
    }

    /// Full range validation before any register moves: these are pub
    /// APIs, and only the follower's apply path arrives pre-validated
    /// by `decode_register_diff` — a stray index must be a typed error,
    /// not an out-of-bounds panic halfway through raising the global
    /// union.
    fn validate_diff(&self, cfg: HllConfig, entries: &[(u32, u8)]) -> Result<(), SketchError> {
        if cfg != self.cfg.hll {
            return Err(SketchError::ConfigMismatch(cfg, self.cfg.hll));
        }
        for &(idx, val) in entries {
            if (idx as usize) >= cfg.m() {
                return Err(SketchError::Malformed(format!(
                    "diff index {idx} out of range for m={}",
                    cfg.m()
                )));
            }
            if val == 0 || val > cfg.max_rank() {
                return Err(SketchError::Malformed(format!(
                    "diff value {val} outside 1..={}",
                    cfg.max_rank()
                )));
            }
        }
        Ok(())
    }

    /// Max-merge a decoded register diff into the *global union* sketch
    /// only, touching no key — the follower's apply path for
    /// [`SketchDelta::GlobalDiff`] entries (words whose key was evicted
    /// on the primary before the capture tick). No-op `Ok` when
    /// `track_global` is off; config/seed mismatches and out-of-range
    /// entries fail before any register moves.
    pub fn merge_global_diff(
        &self,
        cfg: HllConfig,
        entries: &[(u32, u8)],
    ) -> Result<(), SketchError> {
        self.validate_diff(cfg, entries)?;
        if let Some(global) = &self.global {
            for &(idx, val) in entries {
                global.update_register(idx as usize, val);
            }
        }
        Ok(())
    }

    /// Drain the global union's raised-register set into one encoded
    /// register diff ([`crate::hll::encode_register_diff`] format), or
    /// `None` when nothing moved, `track_global` is off, or
    /// [`Self::enable_dirty_tracking`] was never called. Values are
    /// the registers' *current* maxima, so draining twice or racing an
    /// ingest is harmless under max-merge. This is the replication
    /// capture's global feed — per-key deltas die with an evicted key,
    /// this does not.
    pub fn drain_dirty_global(&self) -> Option<Vec<u8>> {
        let global = self.global.as_ref()?;
        let entries = global.drain_dirty_registers();
        if entries.is_empty() {
            return None;
        }
        Some(crate::hll::encode_register_diff(&self.cfg.hll, &entries))
    }

    /// Number of global-union registers raised since the last
    /// [`Self::drain_dirty_global`] (0 when `track_global` is off).
    pub fn dirty_global_registers(&self) -> usize {
        self.global.as_ref().map_or(0, |g| g.dirty_registers())
    }

    /// Number of keys currently awaiting a dirty drain (0 when tracking
    /// is disabled or everything has been captured).
    pub fn dirty_keys(&self) -> usize {
        self.shards.iter().map(|s| s.dirty_len()).sum()
    }

    /// Point-in-time copy of the lock-free global union sketch (`None`
    /// when `track_global` is off). Unlike [`Self::merge_all`], this
    /// includes words whose keys were since evicted — which is exactly
    /// why snapshot format v2 persists it as its own record.
    pub fn global_sketch(&self) -> Option<HllSketch> {
        self.global.as_ref().map(|g| g.snapshot())
    }

    /// Raise the global union by `sketch` without touching any key — the
    /// restore path for snapshot v2's global record. No-op `Ok` when
    /// `track_global` is off; a config/seed mismatch fails before any
    /// register changes.
    pub fn merge_global(&self, sketch: &HllSketch) -> Result<(), SketchError> {
        match &self.global {
            Some(global) => global.merge_sketch(sketch),
            None => Ok(()),
        }
    }

    /// Size-budget eviction: while total sketch heap exceeds `max_bytes`,
    /// drop least-recently-touched keys (global LRU order over the
    /// per-shard last-touch ticks). Returns the number evicted. Accounting
    /// is the same per-sketch heap estimate [`Self::stats`] reports;
    /// concurrent ingest during the sweep makes the budget best-effort,
    /// not a hard cap.
    pub fn evict_to_budget(&self, max_bytes: usize) -> usize {
        // Cheap early-out for the common under-budget case: stats sums
        // bytes under the shard locks without cloning a single key,
        // where the meta walk below clones every live key.
        if self.stats().memory_bytes() <= max_bytes {
            return 0;
        }
        let mut meta: Vec<(K, u64, usize)> = Vec::new();
        for shard in &self.shards {
            shard.collect_meta(&mut meta);
        }
        let mut total: usize = meta.iter().map(|&(_, _, bytes)| bytes).sum();
        if total <= max_bytes {
            return 0;
        }
        meta.sort_by_key(|&(_, touch, _)| touch);
        let mut victims: std::collections::HashSet<K> = std::collections::HashSet::new();
        for (key, _, bytes) in meta {
            if total <= max_bytes {
                break;
            }
            total -= bytes;
            victims.insert(key);
        }
        self.evict_where(|k, _| victims.contains(k))
    }

    /// Enforce the configured [`RegistryConfig::max_memory_bytes`] budget
    /// (no-op returning 0 when unset). The serving layer runs this
    /// periodically during ingest on budgeted registries; embedders can
    /// call it on a timer. (The budget `Evict` RPC is separate — it
    /// enforces a caller-supplied cap via [`Self::evict_to_budget`].)
    pub fn enforce_budget(&self) -> usize {
        match self.cfg.max_memory_bytes {
            Some(max) => self.evict_to_budget(max),
            None => 0,
        }
    }

    /// Live key count.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Per-shard memory and population accounting, plus the configured
    /// estimator kind.
    pub fn stats(&self) -> RegistryStats {
        RegistryStats {
            shards: self.shards.iter().map(|s| s.stats()).collect(),
            estimator: self.cfg.estimator,
        }
    }

    /// Drop every key (the global sketch, if any, is reset too).
    pub fn clear(&self) {
        for shard in &self.shards {
            shard.clear();
        }
        if let Some(global) = &self.global {
            global.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::{HashKind, HllConfig};
    use crate::util::Xoshiro256StarStar;

    fn registry(shards: usize) -> SketchRegistry<u64> {
        SketchRegistry::new(RegistryConfig {
            hll: HllConfig::PAPER,
            shards,
            track_global: true,
            ..RegistryConfig::default()
        })
        .unwrap()
    }

    /// One key's current dense register file, read non-destructively.
    fn dense_of(reg: &SketchRegistry<u64>, key: u64) -> HllSketch {
        let (_, bytes) =
            reg.export_sketches().into_iter().find(|(k, _)| *k == key).expect("key live");
        HllSketch::from_bytes(&bytes).unwrap()
    }

    #[test]
    fn per_key_estimates_match_reference_sketches() {
        let reg = registry(16);
        let mut rng = Xoshiro256StarStar::seed_from_u64(1);
        for key in 0u64..50 {
            let n = 10 + (key as usize * 37) % 400;
            let words: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            reg.ingest(key, &words);
            let mut reference = AdaptiveSketch::new(HllConfig::PAPER);
            for &w in &words {
                reference.insert_u32(w);
            }
            let got = reg.estimate(&key).unwrap();
            assert_eq!(got, reference.estimate(), "key {key}");
        }
        assert_eq!(reg.len(), 50);
        assert!(reg.estimate(&999).is_none());
    }

    #[test]
    fn ingest_pairs_equals_per_key_ingest() {
        let a = registry(8);
        let b = registry(8);
        let mut rng = Xoshiro256StarStar::seed_from_u64(2);
        let pairs: Vec<(u64, u32)> =
            (0..20_000).map(|_| (rng.next_u64_below(500), rng.next_u32())).collect();
        a.ingest_pairs(&pairs);
        for (k, w) in &pairs {
            b.ingest(*k, &[*w]);
        }
        assert_eq!(a.len(), b.len());
        for (key, est) in a.estimates() {
            assert_eq!(Some(est), b.estimate(&key), "key {key}");
        }
    }

    #[test]
    fn global_estimate_equals_merge_all() {
        let reg = registry(8);
        let mut rng = Xoshiro256StarStar::seed_from_u64(3);
        let pairs: Vec<(u64, u32)> =
            (0..30_000).map(|_| (rng.next_u64_below(100), rng.next_u32())).collect();
        reg.ingest_pairs(&pairs);
        let merged = reg.merge_all();
        let global = reg.global_estimate().unwrap();
        assert_eq!(global, merged.estimate());
        // And both equal a serial sketch over every word.
        let mut serial = HllSketch::new(HllConfig::PAPER);
        for (_, w) in &pairs {
            serial.insert_u32(*w);
        }
        assert_eq!(merged, serial);
    }

    #[test]
    fn sparse_keys_upgrade_to_packed_under_volume() {
        let reg = registry(4);
        let mut rng = Xoshiro256StarStar::seed_from_u64(4);
        // Key 0 gets a heavy stream, keys 1..20 stay tiny. 60k distinct
        // words blow past the sparse budget but pack cleanly (random
        // ranks concentrate in a 7-value window), so the heavy key lands
        // in the packed tier, not dense.
        let heavy: Vec<u32> = (0..60_000).map(|_| rng.next_u32()).collect();
        reg.ingest(0, &heavy);
        for key in 1u64..20 {
            reg.ingest(key, &[rng.next_u32()]);
        }
        let stats = reg.stats();
        assert_eq!(stats.keys(), 20);
        assert_eq!(stats.packed_keys(), 1, "heavy key must have upgraded to packed");
        assert_eq!(stats.dense_keys(), 0);
        assert_eq!(stats.sparse_keys(), 19);
        // Packed holds the register file in ~3 bits per register: well
        // above the sparse floor, well under the m-byte dense file.
        assert!(stats.memory_bytes() >= 3 * HllConfig::PAPER.m() / 8);
        assert!(stats.memory_bytes() < HllConfig::PAPER.m());
        assert_eq!(stats.words(), 60_000 + 19);
    }

    #[test]
    fn evict_and_merge_keys() {
        let reg = registry(8);
        let mut rng = Xoshiro256StarStar::seed_from_u64(5);
        let wa: Vec<u32> = (0..5_000).map(|_| rng.next_u32()).collect();
        let wb: Vec<u32> = (0..5_000).map(|_| rng.next_u32()).collect();
        reg.ingest(1, &wa);
        reg.ingest(2, &wb);

        // Merge 2 into 1: the union estimate must match a joint sketch.
        assert!(reg.merge_keys(1, &2).unwrap());
        assert_eq!(reg.len(), 1);
        let mut joint = HllSketch::new(HllConfig::PAPER);
        joint.insert_batch(&wa);
        joint.insert_batch(&wb);
        let evicted = reg.evict(&1).expect("key 1 present");
        assert_eq!(evicted, joint);
        assert!(reg.is_empty());
        // Merging a missing key is a no-op.
        assert!(!reg.merge_keys(1, &2).unwrap());
    }

    #[test]
    fn evict_where_drops_small_keys() {
        let reg = registry(8);
        let mut rng = Xoshiro256StarStar::seed_from_u64(6);
        for key in 0u64..30 {
            let n = if key < 10 { 5 } else { 2_000 };
            let words: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            reg.ingest(key, &words);
        }
        let evicted = reg.evict_where(|_, sketch| sketch.estimate() < 100.0);
        assert_eq!(evicted, 10);
        assert_eq!(reg.len(), 20);
    }

    #[test]
    fn concurrent_ingest_from_many_threads() {
        let reg = std::sync::Arc::new(registry(16));
        let mut rng = Xoshiro256StarStar::seed_from_u64(7);
        let pairs: Vec<(u64, u32)> =
            (0..40_000).map(|_| (rng.next_u64_below(1_000), rng.next_u32())).collect();
        std::thread::scope(|scope| {
            for slice in pairs.chunks(pairs.len() / 4) {
                let reg = reg.clone();
                scope.spawn(move || reg.ingest_pairs(slice));
            }
        });
        let mut serial = HllSketch::new(HllConfig::PAPER);
        for (_, w) in &pairs {
            serial.insert_u32(*w);
        }
        // The global union is order-independent: bit-identical to serial.
        assert_eq!(reg.merge_all(), serial);
        assert_eq!(reg.stats().words(), 40_000);
    }

    #[test]
    fn h32_config_registry_works() {
        let reg: SketchRegistry<u64> = SketchRegistry::new(RegistryConfig {
            hll: HllConfig::new(12, HashKind::H32).unwrap(),
            shards: 4,
            track_global: false,
            ..RegistryConfig::default()
        })
        .unwrap();
        reg.ingest(9, &[1, 2, 3, 2, 1]);
        assert!(reg.global_estimate().is_none());
        let est = reg.estimate(&9).unwrap();
        assert!((est - 3.0).abs() < 0.5, "{est}");
    }

    #[test]
    fn evict_idle_ages_out_untouched_keys() {
        let reg = registry(8);
        // Keys 0..10 touched at ticks 1..=10.
        for key in 0u64..10 {
            reg.ingest(key, &[key as u32]);
        }
        // Advance the clock to tick 100 hammering one hot key.
        for i in 0u32..90 {
            reg.ingest(999, &[i]);
        }
        assert_eq!(reg.now(), 100);
        // max_age 50: cutoff is tick 50, so only the hot key survives.
        assert_eq!(reg.evict_idle(50), 10);
        assert_eq!(reg.len(), 1);
        assert!(reg.estimate(&999).is_some());
        // A huge max_age evicts nothing.
        assert_eq!(reg.evict_idle(u64::MAX), 0);
    }

    #[test]
    fn budget_eviction_is_lru_ordered() {
        let reg = registry(8);
        let mut rng = Xoshiro256StarStar::seed_from_u64(11);
        // Four keys touched in order 1, 2, 3, 4 — then key 1 again, making
        // key 2 the least recently used.
        for key in 1u64..=4 {
            let words: Vec<u32> = (0..2_000).map(|_| rng.next_u32()).collect();
            reg.ingest(key, &words);
        }
        reg.ingest(1, &[rng.next_u32()]);
        let total = reg.stats().memory_bytes();
        // A budget one byte under the total must evict exactly the LRU key.
        let evicted = reg.evict_to_budget(total - 1);
        assert_eq!(evicted, 1);
        assert!(reg.estimate(&2).is_none(), "key 2 was least recently touched");
        for key in [1u64, 3, 4] {
            assert!(reg.estimate(&key).is_some(), "key {key} must survive");
        }
        // Already under budget: nothing to do.
        assert_eq!(reg.evict_to_budget(usize::MAX), 0);
    }

    #[test]
    fn enforce_budget_uses_configured_cap() {
        let reg: SketchRegistry<u64> = SketchRegistry::new(RegistryConfig {
            shards: 8,
            max_memory_bytes: Some(20 * 1024),
            ..RegistryConfig::default()
        })
        .unwrap();
        let mut rng = Xoshiro256StarStar::seed_from_u64(12);
        for key in 0u64..40 {
            let words: Vec<u32> = (0..1_500).map(|_| rng.next_u32()).collect();
            reg.ingest(key, &words);
        }
        assert!(reg.stats().memory_bytes() > 20 * 1024);
        let evicted = reg.enforce_budget();
        assert!(evicted > 0);
        assert!(reg.stats().memory_bytes() <= 20 * 1024);
        // Unbudgeted registries never evict.
        let unbounded = registry(8);
        unbounded.ingest(1, &[1, 2, 3]);
        assert_eq!(unbounded.enforce_budget(), 0);
    }

    #[test]
    fn merge_sketch_and_restore_roundtrip() {
        let reg = registry(8);
        let mut rng = Xoshiro256StarStar::seed_from_u64(13);
        for key in 0u64..25 {
            let n = 10 + (key as usize * 61) % 3_000;
            let words: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            reg.ingest(key, &words);
        }
        let exported = reg.export_sketches();
        assert_eq!(exported.len(), 25);

        // Decode and restore into a fresh registry: every estimate (and
        // the global union) must match exactly.
        let fresh = registry(8);
        let decoded: Vec<(u64, HllSketch)> = exported
            .iter()
            .map(|(k, bytes)| (*k, HllSketch::from_bytes(bytes).unwrap()))
            .collect();
        assert_eq!(fresh.restore(decoded).unwrap(), 25);
        assert_eq!(fresh.len(), reg.len());
        for (key, est) in reg.estimates() {
            assert_eq!(fresh.estimate(&key), Some(est), "key {key}");
        }
        assert_eq!(fresh.merge_all(), reg.merge_all());
        assert_eq!(fresh.global_estimate(), reg.global_estimate());

        // Restoring on top of live state is idempotent (max-merge).
        let decoded_again: Vec<(u64, HllSketch)> = exported
            .iter()
            .map(|(k, bytes)| (*k, HllSketch::from_bytes(bytes).unwrap()))
            .collect();
        fresh.restore(decoded_again).unwrap();
        assert_eq!(fresh.merge_all(), reg.merge_all());
    }

    #[test]
    fn merge_sketch_batch_matches_per_key_merge() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(53);
        // A batch of dense sketches across many keys, some keys twice
        // (replication batches may carry two Full entries for one key).
        let mut entries: Vec<(u64, HllSketch)> = Vec::new();
        for key in 0u64..60 {
            let n = 10 + (key as usize * 97) % 2_000;
            let mut s = HllSketch::new(HllConfig::PAPER);
            for _ in 0..n {
                s.insert_u32(rng.next_u32());
            }
            entries.push((key, s));
            if key % 7 == 0 {
                let mut extra = HllSketch::new(HllConfig::PAPER);
                extra.insert_u32(rng.next_u32());
                entries.push((key, extra));
            }
        }

        let batched = registry(8);
        batched.enable_dirty_tracking();
        let per_key = registry(8);
        batched.merge_sketch_batch(entries.clone()).unwrap();
        for (key, sketch) in entries.clone() {
            per_key.merge_sketch(key, sketch).unwrap();
        }
        assert_eq!(batched.len(), per_key.len());
        for (key, est) in per_key.estimates() {
            assert_eq!(batched.estimate(&key), Some(est), "key {key}");
        }
        assert_eq!(batched.merge_all(), per_key.merge_all());
        assert_eq!(batched.global_estimate(), per_key.global_estimate());
        // Every merged key is dirty as a full resend, same as the
        // per-key path would leave it.
        let drained = batched.drain_dirty_deltas();
        assert_eq!(drained.len(), 60);
        assert!(drained.iter().all(|(_, d)| matches!(d, SketchDelta::Full(_))));

        // One mismatched sketch rejects the whole batch before any
        // state changes — no key created, no global register raised.
        let fresh = registry(8);
        let mut bad = entries;
        bad.push((999, HllSketch::new(HllConfig::PAPER.with_seed(7))));
        assert!(matches!(
            fresh.merge_sketch_batch(bad),
            Err(SketchError::ConfigMismatch(..))
        ));
        assert!(fresh.is_empty());
        assert_eq!(fresh.global_sketch().unwrap(), HllSketch::new(HllConfig::PAPER));
        // An empty batch is a no-op Ok.
        fresh.merge_sketch_batch(Vec::new()).unwrap();
    }

    #[test]
    fn merge_sketch_rejects_config_and_seed_mismatch() {
        let reg = registry(4);
        let other_p = HllSketch::new(HllConfig::new(12, HashKind::H64).unwrap());
        assert!(matches!(
            reg.merge_sketch(1, other_p),
            Err(SketchError::ConfigMismatch(..))
        ));
        let seeded = HllSketch::new(HllConfig::PAPER.with_seed(7));
        assert!(matches!(
            reg.merge_sketch(1, seeded),
            Err(SketchError::ConfigMismatch(..))
        ));
        assert!(reg.is_empty(), "failed merges must not create keys");
    }

    #[test]
    fn wall_clock_ttl_evicts_by_duration() {
        use super::super::config::WallClock;
        use std::time::Duration;

        let (wall, clock) = WallClock::manual(1_000);
        let reg: SketchRegistry<u64> = SketchRegistry::with_wall_clock(
            RegistryConfig { shards: 8, ..RegistryConfig::default() },
            wall,
        )
        .unwrap();
        // Keys 0..5 touched at wall second 1000.
        for key in 0u64..5 {
            reg.ingest(key, &[key as u32]);
        }
        assert_eq!(reg.wall_now_secs(), 1_000);
        // An hour passes; one key stays hot.
        clock.store(1_000 + 3_600, std::sync::atomic::Ordering::Relaxed);
        reg.ingest(99, &[7]);
        // TTL of 2h evicts nothing; TTL of 30min evicts the 5 idle keys.
        assert_eq!(reg.evict_idle_wall(Duration::from_secs(2 * 3_600)), 0);
        assert_eq!(reg.evict_idle_wall(Duration::from_secs(30 * 60)), 5);
        assert_eq!(reg.len(), 1);
        assert!(reg.estimate(&99).is_some());
    }

    #[test]
    fn dirty_tracking_drains_exactly_once() {
        let reg = registry(8);
        // Off by default: mutations leave no dirty debt behind.
        reg.ingest(1, &[1, 2, 3]);
        assert!(!reg.dirty_tracking_enabled());
        assert_eq!(reg.dirty_keys(), 0);
        assert!(reg.drain_dirty_deltas().is_empty());

        reg.enable_dirty_tracking();
        let mut rng = Xoshiro256StarStar::seed_from_u64(31);
        for key in 0u64..20 {
            let words: Vec<u32> = (0..50).map(|_| rng.next_u32()).collect();
            reg.ingest(key, &words);
        }
        assert_eq!(reg.dirty_keys(), 20);
        let drained = reg.drain_dirty_deltas();
        assert_eq!(drained.len(), 20);
        assert_eq!(reg.dirty_keys(), 0);
        // Small fresh keys are sparse → full-resend frames carrying the
        // key's current sketch.
        for (key, delta) in &drained {
            match delta {
                SketchDelta::Full(bytes) => {
                    let sketch = HllSketch::from_bytes(bytes).unwrap();
                    assert_eq!(Some(sketch.estimate()), reg.estimate(key), "key {key}");
                }
                other => panic!("fresh sparse key {key} must drain Full, got {other:?}"),
            }
        }
        // Nothing new: the next drain is empty.
        assert!(reg.drain_dirty_deltas().is_empty());
        // One more touch re-dirties exactly that key.
        reg.ingest(7, &[rng.next_u32()]);
        let again = reg.drain_dirty_deltas();
        assert_eq!(again.len(), 1);
        assert_eq!(again[0].0, 7);
        // A dirtied-then-evicted key drains as a tombstone.
        reg.ingest(8, &[rng.next_u32()]);
        reg.evict(&8);
        assert_eq!(reg.drain_dirty_deltas(), vec![(8, SketchDelta::Tombstone)]);
        assert!(reg.drain_dirty_deltas().is_empty());
    }

    #[test]
    fn register_keys_drain_register_diffs_that_reconstruct_state() {
        use crate::hll::decode_register_diff;

        let reg = registry(8);
        reg.enable_dirty_tracking();
        let mut rng = Xoshiro256StarStar::seed_from_u64(41);
        // Promote one key out of sparse (paper config upgrades past
        // ~24 KiB of sparse entries — 60k distinct words is comfortably
        // beyond); it lands packed, which tracks changed registers just
        // like dense.
        let heavy: Vec<u32> = (0..60_000).map(|_| rng.next_u32()).collect();
        reg.ingest(9, &heavy);
        assert_eq!(reg.stats().packed_keys(), 1);
        // First drain after the promotion: the upgrade ran through the
        // sparse path, so this drain is a Full resend.
        let first = reg.drain_dirty_deltas();
        assert_eq!(first.len(), 1);
        assert!(matches!(first[0].1, SketchDelta::Full(_)));

        // Mirror the shipped state, then keep ingesting: every later
        // drain must be a register diff that, max-merged into the
        // mirror, reproduces the primary's registers bit-exactly.
        let mut mirror = match &first[0].1 {
            SketchDelta::Full(bytes) => HllSketch::from_bytes(bytes).unwrap(),
            other => panic!("expected Full, got {other:?}"),
        };
        for round in 0..3 {
            let words: Vec<u32> = (0..5_000).map(|_| rng.next_u32()).collect();
            reg.ingest(9, &words);
            let drained = reg.drain_dirty_deltas();
            assert_eq!(drained.len(), 1, "round {round}");
            match &drained[0].1 {
                SketchDelta::RegisterDiff(bytes) => {
                    let (cfg, entries) = decode_register_diff(bytes).unwrap();
                    assert_eq!(cfg, *mirror.config());
                    assert!(!entries.is_empty());
                    // Far fewer entries than registers: the point of
                    // the diff encoding.
                    assert!(entries.len() < cfg.m() / 4, "round {round}");
                    mirror.apply_register_diff(&entries);
                }
                other => panic!("round {round}: expected RegisterDiff, got {other:?}"),
            }
            assert_eq!(mirror, dense_of(&reg, 9), "round {round}");
        }

        // A touch that changes nothing (same words again) drains empty.
        let replay: Vec<u32> = heavy[..100].to_vec();
        reg.ingest(9, &replay);
        assert!(reg.drain_dirty_deltas().is_empty(), "no-op touches must not ship");
    }

    #[test]
    fn evict_then_recreate_drains_tombstone_before_full() {
        let reg = registry(8);
        reg.enable_dirty_tracking();
        reg.ingest(5, &[1, 2, 3]);
        let _ = reg.drain_dirty_deltas();
        // Evict and re-create under the same name between drains.
        reg.evict(&5);
        reg.ingest(5, &[9, 10]);
        let drained = reg.drain_dirty_deltas();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].0, 5);
        assert_eq!(drained[0].1, SketchDelta::Tombstone, "tombstone must come first");
        match &drained[1].1 {
            SketchDelta::Full(bytes) => {
                let sketch = HllSketch::from_bytes(bytes).unwrap();
                assert_eq!(Some(sketch.estimate()), reg.estimate(&5));
            }
            other => panic!("re-created key must resend Full after the tombstone: {other:?}"),
        }

        // TTL sweeps tombstone too. Key 7 is the newest touch, so an
        // age-0 sweep (cutoff = current clock) reaps keys 5 and 6.
        reg.ingest(6, &[1]);
        reg.ingest(7, &[2]);
        let _ = reg.drain_dirty_deltas();
        assert_eq!(reg.evict_idle(0), 2);
        let mut tombs: Vec<u64> = reg
            .drain_dirty_deltas()
            .into_iter()
            .map(|(k, d)| {
                assert_eq!(d, SketchDelta::Tombstone);
                k
            })
            .collect();
        tombs.sort_unstable();
        assert_eq!(tombs, vec![5, 6]);
    }

    #[test]
    fn apply_register_diff_creates_raises_and_rejects_mismatch() {
        let reg = registry(8);
        let cfg = HllConfig::PAPER;
        // Creates the key if absent and raises the global union.
        reg.apply_register_diff(3, cfg, &[(0, 5), (100, 2)]).unwrap();
        assert!(reg.estimate(&3).is_some());
        let global = reg.global_sketch().unwrap();
        assert_eq!(global.registers()[0], 5);
        assert_eq!(global.registers()[100], 2);
        // Idempotent max-merge: replaying and lower values change nothing.
        reg.apply_register_diff(3, cfg, &[(0, 4)]).unwrap();
        assert_eq!(dense_of(&reg, 3).registers()[0], 5);
        // Config/seed mismatches fail before any state changes.
        let seeded = HllConfig::PAPER.with_seed(7);
        assert!(matches!(
            reg.apply_register_diff(4, seeded, &[(0, 1)]),
            Err(SketchError::ConfigMismatch(..))
        ));
        assert!(reg.estimate(&4).is_none());
        // Out-of-range entries are typed errors, not panics — and they
        // fail before any register (key or global) moves.
        let before = reg.global_sketch().unwrap();
        assert!(matches!(
            reg.apply_register_diff(4, cfg, &[(0, 3), (cfg.m() as u32, 5)]),
            Err(SketchError::Malformed(_))
        ));
        assert!(matches!(
            reg.apply_register_diff(4, cfg, &[(1, 0)]),
            Err(SketchError::Malformed(_))
        ));
        assert!(matches!(
            reg.apply_register_diff(4, cfg, &[(1, cfg.max_rank() + 1)]),
            Err(SketchError::Malformed(_))
        ));
        assert!(reg.estimate(&4).is_none());
        assert_eq!(reg.global_sketch().unwrap(), before, "rejected diffs must not move global");
    }

    #[test]
    fn drain_dirty_global_ships_evicted_words_and_merge_global_diff_applies() {
        use crate::hll::decode_register_diff;

        let reg = registry(8);
        reg.enable_dirty_tracking();
        // Words into a key that dies before the drain: the key's delta
        // is a tombstone, but the global diff still carries the words.
        reg.ingest(1, &[100, 200, 300]);
        reg.evict(&1);
        assert!(reg.dirty_global_registers() > 0);
        let bytes = reg.drain_dirty_global().expect("raised registers must drain");
        assert_eq!(reg.dirty_global_registers(), 0);
        assert!(reg.drain_dirty_global().is_none(), "second drain is empty");

        // A fresh registry that applies the diff reports the same
        // global estimate — without ever holding the key.
        let follower = registry(8);
        let (cfg, entries) = decode_register_diff(&bytes).unwrap();
        follower.merge_global_diff(cfg, &entries).unwrap();
        assert_eq!(follower.global_estimate(), reg.global_estimate());
        assert!(follower.is_empty(), "global diffs must not create keys");

        // Validation mirrors apply_register_diff: mismatched configs
        // and out-of-range entries fail before any register moves.
        let before = follower.global_sketch().unwrap();
        let seeded = HllConfig::PAPER.with_seed(7);
        assert!(matches!(
            follower.merge_global_diff(seeded, &[(0, 1)]),
            Err(SketchError::ConfigMismatch(..))
        ));
        assert!(matches!(
            follower.merge_global_diff(HllConfig::PAPER, &[(HllConfig::PAPER.m() as u32, 1)]),
            Err(SketchError::Malformed(_))
        ));
        assert_eq!(follower.global_sketch().unwrap(), before);

        // A registry without a global union drains nothing and applies
        // diffs as a no-op Ok.
        let untracked: SketchRegistry<u64> = SketchRegistry::new(RegistryConfig {
            shards: 4,
            track_global: false,
            ..RegistryConfig::default()
        })
        .unwrap();
        untracked.enable_dirty_tracking();
        untracked.ingest(9, &[1, 2, 3]);
        assert_eq!(untracked.dirty_global_registers(), 0);
        assert!(untracked.drain_dirty_global().is_none());
        assert!(untracked.merge_global_diff(HllConfig::PAPER, &entries).is_ok());
    }

    #[test]
    fn global_sketch_and_merge_global_preserve_evicted_words() {
        let reg = registry(8);
        let mut rng = Xoshiro256StarStar::seed_from_u64(37);
        let wa: Vec<u32> = (0..3_000).map(|_| rng.next_u32()).collect();
        let wb: Vec<u32> = (0..3_000).map(|_| rng.next_u32()).collect();
        reg.ingest(1, &wa);
        reg.ingest(2, &wb);
        let full_global = reg.global_sketch().unwrap();
        assert_eq!(full_global.estimate(), reg.global_estimate().unwrap());

        // Evict key 1: the live union shrinks, the global sketch does not.
        reg.evict(&1);
        assert!(reg.merge_all().estimate() < full_global.estimate());
        assert_eq!(reg.global_estimate(), Some(full_global.estimate()));

        // merge_global carries those words into a fresh registry's union.
        let fresh = registry(8);
        fresh.merge_global(&full_global).unwrap();
        assert_eq!(fresh.global_estimate(), Some(full_global.estimate()));
        // Mismatched config is rejected; global-less registries no-op.
        let seeded = HllSketch::new(HllConfig::PAPER.with_seed(7));
        assert!(matches!(fresh.merge_global(&seeded), Err(SketchError::ConfigMismatch(..))));
        let untracked: SketchRegistry<u64> = SketchRegistry::new(RegistryConfig {
            shards: 4,
            track_global: false,
            ..RegistryConfig::default()
        })
        .unwrap();
        assert!(untracked.merge_global(&full_global).is_ok());
        assert!(untracked.global_estimate().is_none());
    }
}
