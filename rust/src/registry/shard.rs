//! One stripe of the registry: a mutex-guarded key → sketch map.
//!
//! Everything here runs under the shard lock; the registry guarantees a
//! caller never holds two shard locks at once (cross-shard operations
//! release the first lock before taking the second), so there is no lock
//! ordering to get wrong.
//!
//! Each key carries a logical last-touch tick alongside its sketch (the
//! registry's monotone ingest clock), which is what the TTL sweep
//! ([`Shard::evict_idle`]) and the LRU size-budget eviction
//! ([`Shard::collect_meta`] + retain) key off. A coarse wall-clock
//! stamp (seconds) rides along for the Duration-based TTL sweep
//! ([`Shard::evict_idle_wall`]).
//!
//! When dirty tracking is enabled (replication primaries — see
//! [`crate::replica`]), every mutating touch also records *what
//! changed* in a per-shard `key → DirtyState` map: the exact dense
//! registers an ingest raised (spilling to a full-resend marker past a
//! density threshold), a full-resend marker for sparse keys and merges,
//! and an eviction tombstone when any eviction path removes a key.
//! [`Shard::drain_dirty`] swaps the map out under the same lock the
//! mutation held, so a write either lands in the current drain or the
//! next one — never in neither — and resolves each state into a typed
//! [`SketchDelta`] the replication log seals.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use super::config::ShardStats;
use super::registry::SketchDelta;
use crate::hll::{
    encode_register_diff, AdaptiveSketch, BatchOutcome, EstimatorKind, HllConfig, HllSketch,
    InsertOutcome,
};

/// Per-key dirty state on a replication primary: what the next capture
/// must ship for this key (resolved by [`Shard::drain_dirty`]).
#[derive(Debug)]
pub(crate) enum DirtyState {
    /// Register indices raised since the last drain (append-only, may
    /// repeat across re-raises; sorted and deduplicated at drain time).
    /// Tracked for the register-addressable tiers (packed and dense).
    /// Spills to [`DirtyState::Full`] past [`spill_threshold`].
    Registers(Vec<u32>),
    /// Resend the key's full sketch: sparse-mode keys (changed
    /// registers untracked), merges, or a register list that grew past
    /// the density threshold.
    Full,
    /// The key was removed; the capture ships a tombstone so followers
    /// drop it too.
    Evicted,
    /// Removed and then re-created before the drain: the capture ships
    /// a tombstone followed by the new full sketch, *in that order*, so
    /// a follower cannot max-merge the dead incarnation's registers
    /// into the new one.
    EvictedThenFull,
}

/// Changed-register indices tracked per key before the state spills to
/// a full resend. A diff entry costs 5 wire bytes against 1 byte per
/// register in a full resend, so diffs stay cheaper up to ~m/5 changed
/// registers; m/8 leaves headroom for the tracking vec itself.
fn spill_threshold(m: usize) -> usize {
    m / 8
}

impl DirtyState {
    /// A tracked (packed or dense) register was raised.
    fn note_register(&mut self, idx: u32, spill: usize) {
        match self {
            DirtyState::Registers(v) => {
                v.push(idx);
                if v.len() > spill {
                    // Re-raises of one hot register are one diff entry,
                    // not many: dedup before concluding the diff is
                    // dense enough to spill. Cheap in amortized terms —
                    // each sort is triggered by real register raises,
                    // and a register can only be raised max_rank times.
                    v.sort_unstable();
                    v.dedup();
                    if v.len() > spill {
                        *self = DirtyState::Full;
                    }
                }
            }
            DirtyState::Full | DirtyState::EvictedThenFull => {}
            DirtyState::Evicted => *self = DirtyState::EvictedThenFull,
        }
    }

    /// The key changed in a way register tracking cannot describe
    /// (sparse insert, sparse→packed promotion, merge): full resend.
    fn note_full(&mut self) {
        match self {
            DirtyState::Registers(_) | DirtyState::Full => *self = DirtyState::Full,
            DirtyState::Evicted | DirtyState::EvictedThenFull => {
                *self = DirtyState::EvictedThenFull
            }
        }
    }
}

/// Fold one traced insert outcome into the key's dirty state.
fn note_outcome(state: &mut DirtyState, outcome: InsertOutcome, spill: usize) {
    match outcome {
        InsertOutcome::RegisterChanged(idx) => state.note_register(idx, spill),
        InsertOutcome::Unchanged => {}
        InsertOutcome::Untracked => state.note_full(),
    }
}

/// Fold one key's whole hash run into its sketch and dirty state — the
/// batch counterpart of a loop of `note_outcome` over traced single
/// inserts, resolving the dirty state once per run instead of once per
/// word. Register-tracking runs append raised indices straight into the
/// `Registers` capture vec (the sketch's batch insert pushes into it
/// directly) and run the spill check once at run end; since the set of
/// raised registers only grows, spilling at run end iff the deduplicated
/// set exceeds the threshold reaches exactly the state the per-word
/// checks would have.
fn ingest_run_traced(
    state: &mut DirtyState,
    sketch: &mut AdaptiveSketch,
    hashes: &[u64],
    spill: usize,
) {
    if hashes.is_empty() {
        // A zero-hash touch still created (or kept live) the key.
        // Without this promotion the state could stay `Evicted` — a
        // false tombstone for a live key — or a fresh key could sit at
        // `Registers([])` and never ship.
        state.note_full();
        return;
    }
    match state {
        DirtyState::Registers(v) => match sketch.insert_hashes_traced(hashes, v) {
            BatchOutcome::Tracked => {
                if v.len() > spill {
                    v.sort_unstable();
                    v.dedup();
                    if v.len() > spill {
                        *state = DirtyState::Full;
                    }
                }
            }
            BatchOutcome::Untracked => *state = DirtyState::Full,
        },
        DirtyState::Full | DirtyState::EvictedThenFull => {
            // Already committed to a full resend: no capture needed,
            // just the plain batch insert.
            sketch.insert_hashes(hashes);
        }
        DirtyState::Evicted => {
            // Rare: the key was evicted earlier in this capture window
            // and is being re-created by this run. Replay the per-word
            // traced path so the Evicted → EvictedThenFull transition
            // follows the exact scalar rules (an all-Unchanged run must
            // leave the tombstone alone — impossible here since the key
            // was just re-created sparse, but cheap to keep airtight).
            for &h in hashes {
                note_outcome(state, sketch.insert_hash_traced(h), spill);
            }
        }
    }
}

#[derive(Debug)]
pub(crate) struct Shard<K> {
    state: Mutex<ShardState<K>>,
    /// Registry-wide dirty-tracking switch, shared by every shard. Read
    /// under the shard lock on each mutation; off (the default) it costs
    /// one relaxed load and no dirty-map traffic.
    track_dirty: Arc<AtomicBool>,
}

#[derive(Debug)]
struct ShardState<K> {
    map: HashMap<K, KeyEntry>,
    words: u64,
    /// What changed per key since the last [`Shard::drain_dirty`]. Only
    /// populated while the shared `track_dirty` flag is set.
    dirty: HashMap<K, DirtyState>,
}

impl<K: Eq + Hash> ShardState<K> {
    /// Fold one key's run of pre-computed hashes into its sketch
    /// (created on first touch), recording what changed in the dirty
    /// map when `dirty` is set — the one implementation behind every
    /// ingest entry point. The whole run pays exactly one map lookup,
    /// one touch and one dirty-state resolution; the key is cloned only
    /// when the run creates a map or dirty-map entry.
    fn ingest_key_run(
        &mut self,
        cfg: HllConfig,
        key: &K,
        hashes: &[u64],
        dirty: bool,
        spill: usize,
        now: u64,
        wall: u64,
    ) where
        K: Clone,
    {
        // Borrow the key map and the dirty map disjointly: the entry
        // borrow below must coexist with the dirty-state borrow.
        let ShardState { map, dirty: dirty_map, .. } = self;
        if !map.contains_key(key) {
            map.insert(key.clone(), KeyEntry::new(cfg, now, wall));
        }
        let entry = map.get_mut(key).expect("present or just inserted");
        entry.touch(now, wall);
        if !dirty {
            entry.sketch.insert_hashes(hashes);
            return;
        }
        if !dirty_map.contains_key(key) {
            dirty_map.insert(key.clone(), DirtyState::Registers(Vec::new()));
        }
        let state = dirty_map.get_mut(key).expect("present or just inserted");
        ingest_run_traced(state, &mut entry.sketch, hashes, spill);
    }
}

/// One key's live state: the sketch plus the registry clock tick and
/// coarse wall-clock second of the last write that touched it.
#[derive(Debug)]
struct KeyEntry {
    sketch: AdaptiveSketch,
    last_touch: u64,
    last_touch_wall: u64,
}

impl KeyEntry {
    fn new(cfg: HllConfig, now: u64, wall: u64) -> Self {
        Self { sketch: AdaptiveSketch::new(cfg), last_touch: now, last_touch_wall: wall }
    }

    /// Monotone touch: ticks are taken from the registry clock *before*
    /// the shard lock, so two concurrent ingests of one key can apply
    /// their ticks in either order — a plain assignment could move the
    /// key's last touch backwards and get a just-touched key TTL-evicted.
    /// The wall stamp gets the same treatment.
    fn touch(&mut self, now: u64, wall: u64) {
        self.last_touch = self.last_touch.max(now);
        self.last_touch_wall = self.last_touch_wall.max(wall);
    }
}

impl<K: Eq + Hash> Shard<K> {
    pub(crate) fn new(track_dirty: Arc<AtomicBool>) -> Self {
        Self {
            state: Mutex::new(ShardState {
                map: HashMap::new(),
                words: 0,
                dirty: HashMap::new(),
            }),
            track_dirty,
        }
    }

    /// Whether mutations must record their key in the dirty set. Read
    /// while the caller holds (or is about to take) the shard lock.
    fn dirty_on(&self) -> bool {
        self.track_dirty.load(Ordering::Relaxed)
    }

    /// Take the shard lock, recovering from poison: a panic in a
    /// caller-supplied predicate (e.g. inside `retain`) must not turn
    /// every later query into a second panic — the map holds monotone
    /// max-register sketches that cannot be left logically torn, so the
    /// state is safe to keep serving. This is the panic-free shutdown
    /// path the service layer relies on.
    fn lock(&self) -> MutexGuard<'_, ShardState<K>> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Fold pre-hashed words into one key's sketch (created on first
    /// touch).
    pub(crate) fn ingest_hashes(&self, cfg: HllConfig, key: &K, hashes: &[u64], now: u64, wall: u64)
    where
        K: Clone,
    {
        let dirty = self.dirty_on();
        let spill = spill_threshold(cfg.m());
        let mut st = self.lock();
        st.ingest_key_run(cfg, key, hashes, dirty, spill, now, wall);
        st.words += hashes.len() as u64;
    }

    /// Fold a batch of per-key hash runs under one lock acquisition —
    /// the registry's batch ingest back end. Each `(key, hashes)` run is
    /// one [`ShardState::ingest_key_run`]: one map lookup, one touch and
    /// one dirty-state resolution per key per batch, and the register
    /// stores run as plain (CAS-free) max-stores because this shard's
    /// lock is already held. Callers hash up front (tight loops, see
    /// [`HllConfig::hash_words`]) and group equal keys into runs; the
    /// optional global union is raised by the caller too, outside the
    /// lock, since it is lock-free and shared across shards.
    pub(crate) fn ingest_runs<'a, I>(&self, cfg: HllConfig, runs: I, now: u64, wall: u64)
    where
        I: Iterator<Item = (&'a K, &'a [u64])>,
        K: Clone + 'a,
    {
        let dirty = self.dirty_on();
        let spill = spill_threshold(cfg.m());
        let mut st = self.lock();
        let mut n = 0u64;
        for (key, hashes) in runs {
            st.ingest_key_run(cfg, key, hashes, dirty, spill, now, wall);
            n += hashes.len() as u64;
        }
        st.words += n;
    }

    pub(crate) fn estimate(&self, key: &K, kind: EstimatorKind) -> Option<f64> {
        let mut st = self.lock();
        st.map.get_mut(key).map(|e| e.sketch.estimate_with(kind))
    }

    pub(crate) fn len(&self) -> usize {
        self.lock().map.len()
    }

    /// Remove one key; returns its final dense register file, if present.
    /// On a dirty-tracking shard the removal is recorded as an eviction
    /// tombstone so the next capture propagates it to followers.
    pub(crate) fn evict(&self, key: &K) -> Option<HllSketch>
    where
        K: Clone,
    {
        let dirty = self.dirty_on();
        let mut st = self.lock();
        let removed = st.map.remove(key);
        if removed.is_some() && dirty {
            st.dirty.insert(key.clone(), DirtyState::Evicted);
        }
        removed.map(|e| e.sketch.into_dense())
    }

    /// Keep only keys the predicate approves; returns how many were
    /// evicted. The predicate may mutate the sketch (e.g. to estimate).
    /// Removals are tombstoned like [`Shard::evict`].
    pub(crate) fn retain<F: FnMut(&K, &mut AdaptiveSketch) -> bool>(&self, mut keep: F) -> usize
    where
        K: Clone,
    {
        self.retain_entries(|k, e| keep(k, &mut e.sketch))
    }

    /// Drop every key whose last touch predates `cutoff`; returns how
    /// many aged out. Removals are tombstoned like [`Shard::evict`].
    pub(crate) fn evict_idle(&self, cutoff: u64) -> usize
    where
        K: Clone,
    {
        self.retain_entries(|_, e| e.last_touch >= cutoff)
    }

    /// Wall-clock twin of [`Shard::evict_idle`]: drop every key whose
    /// last wall-clock touch (seconds) predates `cutoff_secs`.
    pub(crate) fn evict_idle_wall(&self, cutoff_secs: u64) -> usize
    where
        K: Clone,
    {
        self.retain_entries(|_, e| e.last_touch_wall >= cutoff_secs)
    }

    /// The one retain-with-tombstones implementation behind [`Shard::retain`]
    /// and both TTL sweeps: every removal on a dirty-tracking shard is
    /// recorded as an eviction tombstone.
    fn retain_entries<F: FnMut(&K, &mut KeyEntry) -> bool>(&self, mut keep: F) -> usize
    where
        K: Clone,
    {
        let dirty = self.dirty_on();
        let mut st = self.lock();
        let st = &mut *st;
        let before = st.map.len();
        let tombs = &mut st.dirty;
        st.map.retain(|k, e| {
            let kept = keep(k, e);
            if !kept && dirty {
                tombs.insert(k.clone(), DirtyState::Evicted);
            }
            kept
        });
        before - st.map.len()
    }

    /// Swap out the dirty map and resolve each key's [`DirtyState`]
    /// into a typed [`SketchDelta`]:
    ///
    /// * `Registers` → a [`SketchDelta::RegisterDiff`] carrying the
    ///   current values of exactly the registers that moved (read under
    ///   the lock at drain time, so they are the key's latest maxima);
    /// * `Full` → a [`SketchDelta::Full`] wire-v2 sketch;
    /// * `Evicted` → a [`SketchDelta::Tombstone`];
    /// * `EvictedThenFull` → a tombstone immediately followed by the
    ///   re-created key's full sketch (ordering a follower must apply).
    ///
    /// Like [`Shard::export_bytes`], the lock is held only to take the
    /// map, resolve diff values and clone the full-resend sketches;
    /// densification and serialization happen after release.
    pub(crate) fn drain_dirty(&self, out: &mut Vec<(K, SketchDelta)>)
    where
        K: Clone,
    {
        enum Pending<K> {
            Tomb(K),
            Diff(K, HllConfig, Vec<(u32, u8)>),
            Full(K, AdaptiveSketch),
            TombThenFull(K, AdaptiveSketch),
        }
        let pending: Vec<Pending<K>> = {
            let mut st = self.lock();
            if st.dirty.is_empty() {
                return;
            }
            let st = &mut *st;
            let dirty = std::mem::take(&mut st.dirty);
            let mut v = Vec::with_capacity(dirty.len());
            for (key, state) in dirty {
                match state {
                    DirtyState::Registers(mut idxs) => {
                        if idxs.is_empty() {
                            // Touched, but no register moved — sound to
                            // skip: only an already-dense key can end
                            // here (anything else notes Full), and a
                            // dense key's earlier state reached
                            // followers when it was built (its builders
                            // dirtied it), so they are already current.
                            continue;
                        }
                        match st.map.get(&key) {
                            // Register changes are only recorded for the
                            // register-addressable tiers (packed/dense),
                            // and those never revert to sparse; resend
                            // defensively if one somehow did.
                            Some(entry) if entry.sketch.is_sparse() => {
                                v.push(Pending::Full(key, entry.sketch.clone()))
                            }
                            Some(entry) => {
                                idxs.sort_unstable();
                                idxs.dedup();
                                let entries: Vec<(u32, u8)> = idxs
                                    .iter()
                                    .map(|&i| {
                                        let val = entry
                                            .sketch
                                            .register_value(i as usize)
                                            .expect("packed/dense registers are addressable");
                                        (i, val)
                                    })
                                    .filter(|&(_, val)| val > 0)
                                    .collect();
                                v.push(Pending::Diff(key, *entry.sketch.config(), entries));
                            }
                            // Every eviction path rewrites the state to
                            // Evicted, so a register-tracked key should
                            // still be live; if it is not, the
                            // convergent answer is a tombstone.
                            None => v.push(Pending::Tomb(key)),
                        }
                    }
                    DirtyState::Full => match st.map.get(&key) {
                        Some(entry) => v.push(Pending::Full(key, entry.sketch.clone())),
                        None => v.push(Pending::Tomb(key)),
                    },
                    DirtyState::Evicted => v.push(Pending::Tomb(key)),
                    DirtyState::EvictedThenFull => match st.map.get(&key) {
                        Some(entry) => {
                            v.push(Pending::TombThenFull(key, entry.sketch.clone()))
                        }
                        None => v.push(Pending::Tomb(key)),
                    },
                }
            }
            v
        };
        for p in pending {
            match p {
                Pending::Tomb(key) => out.push((key, SketchDelta::Tombstone)),
                Pending::Diff(key, cfg, entries) => {
                    out.push((key, SketchDelta::RegisterDiff(encode_register_diff(&cfg, &entries))))
                }
                Pending::Full(key, sketch) => {
                    out.push((key, SketchDelta::Full(sketch.into_dense().to_bytes())))
                }
                Pending::TombThenFull(key, sketch) => {
                    out.push((key.clone(), SketchDelta::Tombstone));
                    out.push((key, SketchDelta::Full(sketch.into_dense().to_bytes())));
                }
            }
        }
    }

    /// Max-merge a decoded register diff into `key`'s sketch (created
    /// if absent) — the follower's apply path for
    /// [`SketchDelta::RegisterDiff`] entries. The registry has already
    /// checked the diff's config against its own, and the decode path
    /// validated every index and value.
    pub(crate) fn apply_register_diff(
        &self,
        cfg: HllConfig,
        key: K,
        entries: &[(u32, u8)],
        now: u64,
        wall: u64,
    ) where
        K: Clone,
    {
        let dirty = self.dirty_on();
        let mut st = self.lock();
        let st = &mut *st;
        if dirty {
            // Which of the diff's registers beat the local ones is not
            // tracked; a re-replicating holder resends the key whole.
            st.dirty
                .entry(key.clone())
                .or_insert_with(|| DirtyState::Registers(Vec::new()))
                .note_full();
        }
        let entry = st.map.entry(key).or_insert_with(|| KeyEntry::new(cfg, now, wall));
        entry.touch(now, wall);
        entry.sketch.apply_register_diff(entries);
    }

    /// Number of keys currently awaiting a dirty drain.
    pub(crate) fn dirty_len(&self) -> usize {
        self.lock().dirty.len()
    }

    /// Append `(key, last_touch, memory_bytes)` for every live key — the
    /// input the registry's LRU budget eviction sorts globally.
    pub(crate) fn collect_meta(&self, out: &mut Vec<(K, u64, usize)>)
    where
        K: Clone,
    {
        let st = self.lock();
        for (k, e) in st.map.iter() {
            out.push((k.clone(), e.last_touch, e.sketch.memory_bytes()));
        }
    }

    /// Append every key's sketch in wire-format-v2 bytes. The lock is
    /// held only while *cloning* the live sketches (proportional to
    /// their in-memory size — cheap for sparse keys); densification and
    /// serialization happen after release, so a snapshot walk does not
    /// stall ingest on this shard for the whole encode.
    pub(crate) fn export_bytes(&self, out: &mut Vec<(K, Vec<u8>)>)
    where
        K: Clone,
    {
        let cloned: Vec<(K, AdaptiveSketch)> = {
            let st = self.lock();
            st.map.iter().map(|(k, e)| (k.clone(), e.sketch.clone())).collect()
        };
        for (k, sketch) in cloned {
            out.push((k, sketch.into_dense().to_bytes()));
        }
    }

    /// Remove one key's sketch without densifying (for cross-shard
    /// moves). From this shard's point of view the key is gone, so a
    /// dirty-tracking shard records a tombstone — the destination
    /// shard's merge records its own full-resend entry.
    pub(crate) fn take(&self, key: &K) -> Option<AdaptiveSketch>
    where
        K: Clone,
    {
        let dirty = self.dirty_on();
        let mut st = self.lock();
        let taken = st.map.remove(key).map(|e| e.sketch);
        if taken.is_some() && dirty {
            st.dirty.insert(key.clone(), DirtyState::Evicted);
        }
        taken
    }

    /// Merge a sketch into `key`'s sketch (created if absent).
    pub(crate) fn merge_in(
        &self,
        cfg: HllConfig,
        key: K,
        other: AdaptiveSketch,
        now: u64,
        wall: u64,
    ) -> Result<(), crate::hll::SketchError>
    where
        K: Clone,
    {
        self.merge_in_batch(cfg, std::iter::once((key, other)), now, wall)
    }

    /// Merge a run of `(key, sketch)` entries under a single lock
    /// acquisition — the batched back end of [`Shard::merge_in`] and
    /// the follower's apply path for runs of consecutive `Full` delta
    /// entries ([`SketchRegistry::merge_sketch_batch`]). Per-entry
    /// semantics are exactly [`Shard::merge_in`]'s; the first rejected
    /// entry aborts the run (entries before it stay applied — callers
    /// that need all-or-nothing validate configs up front, which is the
    /// only failure a pre-validated batch can hit).
    ///
    /// [`SketchRegistry::merge_sketch_batch`]: super::SketchRegistry::merge_sketch_batch
    pub(crate) fn merge_in_batch<I>(
        &self,
        cfg: HllConfig,
        entries: I,
        now: u64,
        wall: u64,
    ) -> Result<(), crate::hll::SketchError>
    where
        I: Iterator<Item = (K, AdaptiveSketch)>,
        K: Clone,
    {
        let dirty = self.dirty_on();
        let mut st = self.lock();
        let st = &mut *st;
        for (key, other) in entries {
            // Only mark dirty once the merge is known to apply; a failed
            // config check must not enqueue a key that was never created.
            match st.map.entry(key.clone()) {
                std::collections::hash_map::Entry::Occupied(mut e) => {
                    let entry = e.get_mut();
                    entry.sketch.merge_into(other)?;
                    entry.touch(now, wall);
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    if *other.config() != cfg {
                        return Err(crate::hll::SketchError::ConfigMismatch(*other.config(), cfg));
                    }
                    e.insert(KeyEntry { sketch: other, last_touch: now, last_touch_wall: wall });
                }
            }
            if dirty {
                // A merge can raise arbitrary registers; full resend.
                st.dirty
                    .entry(key)
                    .or_insert_with(|| DirtyState::Registers(Vec::new()))
                    .note_full();
            }
        }
        Ok(())
    }

    /// Fold every sketch in this shard into `acc` (bucket-wise max).
    /// Dense keys merge register files directly (no clone); packed keys
    /// replay their (mostly in-window) registers; sparse keys apply only
    /// their live entries — O(live entries), not O(m), so a million
    /// mostly-small keys fold in millions of updates rather than
    /// billions of register merges.
    pub(crate) fn fold_into(&self, acc: &mut HllSketch) {
        let mut st = self.lock();
        for entry in st.map.values_mut() {
            debug_assert_eq!(entry.sketch.config(), acc.config());
            match &mut entry.sketch {
                AdaptiveSketch::Dense(d) => {
                    acc.merge(d).expect("registry sketches share one config");
                }
                AdaptiveSketch::Packed(p) => {
                    for idx in 0..p.config().m() {
                        let val = p.read_register(idx);
                        if val > 0 {
                            acc.update_register(idx, val);
                        }
                    }
                }
                AdaptiveSketch::Sparse(s) => {
                    s.for_each_entry(|idx, rank| acc.update_register(idx, rank));
                }
            }
        }
    }

    /// Run `f` over every (key, estimate) pair (bulk estimate API).
    pub(crate) fn for_each_estimate<F: FnMut(&K, f64)>(&self, kind: EstimatorKind, mut f: F) {
        let mut st = self.lock();
        for (k, e) in st.map.iter_mut() {
            let est = e.sketch.estimate_with(kind);
            f(k, est);
        }
    }

    pub(crate) fn stats(&self) -> ShardStats {
        let st = self.lock();
        let mut out = ShardStats { words: st.words, keys: st.map.len(), ..ShardStats::default() };
        for entry in st.map.values() {
            if entry.sketch.is_sparse() {
                out.sparse_keys += 1;
            } else if entry.sketch.is_packed() {
                out.packed_keys += 1;
            } else {
                out.dense_keys += 1;
            }
            out.memory_bytes += entry.sketch.memory_bytes();
        }
        out
    }

    pub(crate) fn clear(&self)
    where
        K: Clone,
    {
        let dirty = self.dirty_on();
        let mut st = self.lock();
        let st = &mut *st;
        if dirty {
            // A cleared primary must tombstone everything it held, or
            // followers keep serving the dropped keys forever.
            for key in st.map.keys() {
                st.dirty.insert(key.clone(), DirtyState::Evicted);
            }
        } else {
            st.dirty.clear();
        }
        st.map.clear();
        st.words = 0;
    }
}
