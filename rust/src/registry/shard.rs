//! One stripe of the registry: a mutex-guarded key → sketch map.
//!
//! Everything here runs under the shard lock; the registry guarantees a
//! caller never holds two shard locks at once (cross-shard operations
//! release the first lock before taking the second), so there is no lock
//! ordering to get wrong.
//!
//! Each key carries a logical last-touch tick alongside its sketch (the
//! registry's monotone ingest clock), which is what the TTL sweep
//! ([`Shard::evict_idle`]) and the LRU size-budget eviction
//! ([`Shard::collect_meta`] + retain) key off.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Mutex, MutexGuard, PoisonError};

use super::config::ShardStats;
use crate::hll::{AdaptiveSketch, HllConfig, HllSketch};

#[derive(Debug)]
pub(crate) struct Shard<K> {
    state: Mutex<ShardState<K>>,
}

#[derive(Debug)]
struct ShardState<K> {
    map: HashMap<K, KeyEntry>,
    words: u64,
}

/// One key's live state: the sketch plus the registry clock tick of the
/// last write that touched it.
#[derive(Debug)]
struct KeyEntry {
    sketch: AdaptiveSketch,
    last_touch: u64,
}

impl KeyEntry {
    fn new(cfg: HllConfig, now: u64) -> Self {
        Self { sketch: AdaptiveSketch::new(cfg), last_touch: now }
    }

    /// Monotone touch: ticks are taken from the registry clock *before*
    /// the shard lock, so two concurrent ingests of one key can apply
    /// their ticks in either order — a plain assignment could move the
    /// key's last touch backwards and get a just-touched key TTL-evicted.
    fn touch(&mut self, now: u64) {
        self.last_touch = self.last_touch.max(now);
    }
}

impl<K: Eq + Hash> Shard<K> {
    pub(crate) fn new() -> Self {
        Self { state: Mutex::new(ShardState { map: HashMap::new(), words: 0 }) }
    }

    /// Take the shard lock, recovering from poison: a panic in a
    /// caller-supplied predicate (e.g. inside `retain`) must not turn
    /// every later query into a second panic — the map holds monotone
    /// max-register sketches that cannot be left logically torn, so the
    /// state is safe to keep serving. This is the panic-free shutdown
    /// path the service layer relies on.
    fn lock(&self) -> MutexGuard<'_, ShardState<K>> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Fold pre-hashed words into one key's sketch (created on first
    /// touch).
    pub(crate) fn ingest_hashes(&self, cfg: HllConfig, key: K, hashes: &[u64], now: u64) {
        let mut st = self.lock();
        let entry = st.map.entry(key).or_insert_with(|| KeyEntry::new(cfg, now));
        entry.touch(now);
        for &h in hashes {
            entry.sketch.insert_hash(h);
        }
        st.words += hashes.len() as u64;
    }

    /// Fold a run of (key, hash) pairs under one lock acquisition.
    pub(crate) fn ingest_pairs(&self, cfg: HllConfig, pairs: &[(K, u64)], now: u64)
    where
        K: Clone,
    {
        let mut st = self.lock();
        for (key, h) in pairs {
            let entry =
                st.map.entry(key.clone()).or_insert_with(|| KeyEntry::new(cfg, now));
            entry.touch(now);
            entry.sketch.insert_hash(*h);
        }
        st.words += pairs.len() as u64;
    }

    /// Fold raw (key, word) pairs under one lock acquisition, hashing
    /// in-loop — the keyed coordinator's hot path (no intermediate
    /// buffer; callers feed whatever shape they hold through an
    /// iterator). The optional global union sketch is lock-free, so
    /// raising it from inside the shard lock is safe and keeps the
    /// word hashed exactly once.
    pub(crate) fn ingest_words_iter<'a>(
        &self,
        cfg: HllConfig,
        pairs: impl Iterator<Item = (&'a K, u32)>,
        global: Option<&crate::hll::ConcurrentHllSketch>,
        now: u64,
    ) where
        K: Clone + 'a,
    {
        let mut st = self.lock();
        let mut n = 0u64;
        for (key, word) in pairs {
            let h = cfg.hash_word(word);
            if let Some(g) = global {
                g.insert_hash(h);
            }
            let entry =
                st.map.entry(key.clone()).or_insert_with(|| KeyEntry::new(cfg, now));
            entry.touch(now);
            entry.sketch.insert_hash(h);
            n += 1;
        }
        st.words += n;
    }

    pub(crate) fn estimate(&self, key: &K) -> Option<f64> {
        let mut st = self.lock();
        st.map.get_mut(key).map(|e| e.sketch.estimate())
    }

    pub(crate) fn len(&self) -> usize {
        self.lock().map.len()
    }

    /// Remove one key; returns its final dense register file, if present.
    pub(crate) fn evict(&self, key: &K) -> Option<HllSketch> {
        let mut st = self.lock();
        st.map.remove(key).map(|e| e.sketch.into_dense())
    }

    /// Keep only keys the predicate approves; returns how many were
    /// evicted. The predicate may mutate the sketch (e.g. to estimate).
    pub(crate) fn retain<F: FnMut(&K, &mut AdaptiveSketch) -> bool>(&self, mut keep: F) -> usize {
        let mut st = self.lock();
        let before = st.map.len();
        st.map.retain(|k, e| keep(k, &mut e.sketch));
        before - st.map.len()
    }

    /// Drop every key whose last touch predates `cutoff`; returns how
    /// many aged out.
    pub(crate) fn evict_idle(&self, cutoff: u64) -> usize {
        let mut st = self.lock();
        let before = st.map.len();
        st.map.retain(|_, e| e.last_touch >= cutoff);
        before - st.map.len()
    }

    /// Append `(key, last_touch, memory_bytes)` for every live key — the
    /// input the registry's LRU budget eviction sorts globally.
    pub(crate) fn collect_meta(&self, out: &mut Vec<(K, u64, usize)>)
    where
        K: Clone,
    {
        let st = self.lock();
        for (k, e) in st.map.iter() {
            out.push((k.clone(), e.last_touch, e.sketch.memory_bytes()));
        }
    }

    /// Append every key's sketch in wire-format-v2 bytes. The lock is
    /// held only while *cloning* the live sketches (proportional to
    /// their in-memory size — cheap for sparse keys); densification and
    /// serialization happen after release, so a snapshot walk does not
    /// stall ingest on this shard for the whole encode.
    pub(crate) fn export_bytes(&self, out: &mut Vec<(K, Vec<u8>)>)
    where
        K: Clone,
    {
        let cloned: Vec<(K, AdaptiveSketch)> = {
            let st = self.lock();
            st.map.iter().map(|(k, e)| (k.clone(), e.sketch.clone())).collect()
        };
        for (k, sketch) in cloned {
            out.push((k, sketch.into_dense().to_bytes()));
        }
    }

    /// Remove one key's sketch without densifying (for cross-shard moves).
    pub(crate) fn take(&self, key: &K) -> Option<AdaptiveSketch> {
        self.lock().map.remove(key).map(|e| e.sketch)
    }

    /// Merge a sketch into `key`'s sketch (created if absent).
    pub(crate) fn merge_in(
        &self,
        cfg: HllConfig,
        key: K,
        other: AdaptiveSketch,
        now: u64,
    ) -> Result<(), crate::hll::SketchError> {
        let mut st = self.lock();
        match st.map.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                let entry = e.get_mut();
                entry.sketch.merge_into(other)?;
                entry.touch(now);
                Ok(())
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                if *other.config() != cfg {
                    return Err(crate::hll::SketchError::ConfigMismatch(*other.config(), cfg));
                }
                e.insert(KeyEntry { sketch: other, last_touch: now });
                Ok(())
            }
        }
    }

    /// Fold every sketch in this shard into `acc` (bucket-wise max).
    /// Dense keys merge register files directly (no clone); sparse keys
    /// apply only their live entries — O(live entries), not O(m), so a
    /// million mostly-small keys fold in millions of updates rather
    /// than billions of register merges.
    pub(crate) fn fold_into(&self, acc: &mut HllSketch) {
        let mut st = self.lock();
        for entry in st.map.values_mut() {
            debug_assert_eq!(entry.sketch.config(), acc.config());
            match &mut entry.sketch {
                AdaptiveSketch::Dense(d) => {
                    acc.merge(d).expect("registry sketches share one config");
                }
                AdaptiveSketch::Sparse(s) => {
                    s.for_each_entry(|idx, rank| acc.update_register(idx, rank));
                }
            }
        }
    }

    /// Run `f` over every (key, estimate) pair (bulk estimate API).
    pub(crate) fn for_each_estimate<F: FnMut(&K, f64)>(&self, mut f: F) {
        let mut st = self.lock();
        for (k, e) in st.map.iter_mut() {
            let est = e.sketch.estimate();
            f(k, est);
        }
    }

    pub(crate) fn stats(&self) -> ShardStats {
        let st = self.lock();
        let mut out = ShardStats { words: st.words, keys: st.map.len(), ..ShardStats::default() };
        for entry in st.map.values() {
            if entry.sketch.is_sparse() {
                out.sparse_keys += 1;
            } else {
                out.dense_keys += 1;
            }
            out.memory_bytes += entry.sketch.memory_bytes();
        }
        out
    }

    pub(crate) fn clear(&self) {
        let mut st = self.lock();
        st.map.clear();
        st.words = 0;
    }
}
