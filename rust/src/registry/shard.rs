//! One stripe of the registry: a mutex-guarded key → sketch map.
//!
//! Everything here runs under the shard lock; the registry guarantees a
//! caller never holds two shard locks at once (cross-shard operations
//! release the first lock before taking the second), so there is no lock
//! ordering to get wrong.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Mutex;

use super::config::ShardStats;
use crate::hll::{AdaptiveSketch, HllConfig, HllSketch};

#[derive(Debug)]
pub(crate) struct Shard<K> {
    state: Mutex<ShardState<K>>,
}

#[derive(Debug)]
struct ShardState<K> {
    map: HashMap<K, AdaptiveSketch>,
    words: u64,
}

impl<K: Eq + Hash> Shard<K> {
    pub(crate) fn new() -> Self {
        Self { state: Mutex::new(ShardState { map: HashMap::new(), words: 0 }) }
    }

    /// Fold pre-hashed words into one key's sketch (created on first
    /// touch).
    pub(crate) fn ingest_hashes(&self, cfg: HllConfig, key: K, hashes: &[u64]) {
        let mut st = self.state.lock().unwrap();
        let sketch = st.map.entry(key).or_insert_with(|| AdaptiveSketch::new(cfg));
        for &h in hashes {
            sketch.insert_hash(h);
        }
        st.words += hashes.len() as u64;
    }

    /// Fold a run of (key, hash) pairs under one lock acquisition.
    pub(crate) fn ingest_pairs(&self, cfg: HllConfig, pairs: &[(K, u64)])
    where
        K: Clone,
    {
        let mut st = self.state.lock().unwrap();
        for (key, h) in pairs {
            st.map
                .entry(key.clone())
                .or_insert_with(|| AdaptiveSketch::new(cfg))
                .insert_hash(*h);
        }
        st.words += pairs.len() as u64;
    }

    /// Fold raw (key, word) pairs under one lock acquisition, hashing
    /// in-loop — the keyed coordinator's hot path (no intermediate
    /// buffer; callers feed whatever shape they hold through an
    /// iterator). The optional global union sketch is lock-free, so
    /// raising it from inside the shard lock is safe and keeps the
    /// word hashed exactly once.
    pub(crate) fn ingest_words_iter<'a>(
        &self,
        cfg: HllConfig,
        pairs: impl Iterator<Item = (&'a K, u32)>,
        global: Option<&crate::hll::ConcurrentHllSketch>,
    ) where
        K: Clone + 'a,
    {
        let mut st = self.state.lock().unwrap();
        let mut n = 0u64;
        for (key, word) in pairs {
            let h = cfg.hash_word(word);
            if let Some(g) = global {
                g.insert_hash(h);
            }
            st.map
                .entry(key.clone())
                .or_insert_with(|| AdaptiveSketch::new(cfg))
                .insert_hash(h);
            n += 1;
        }
        st.words += n;
    }

    pub(crate) fn estimate(&self, key: &K) -> Option<f64> {
        let mut st = self.state.lock().unwrap();
        st.map.get_mut(key).map(|s| s.estimate())
    }

    pub(crate) fn len(&self) -> usize {
        self.state.lock().unwrap().map.len()
    }

    /// Remove one key; returns its final dense register file, if present.
    pub(crate) fn evict(&self, key: &K) -> Option<HllSketch> {
        let mut st = self.state.lock().unwrap();
        st.map.remove(key).map(|s| s.into_dense())
    }

    /// Keep only keys the predicate approves; returns how many were
    /// evicted. The predicate may mutate the sketch (e.g. to estimate).
    pub(crate) fn retain<F: FnMut(&K, &mut AdaptiveSketch) -> bool>(&self, mut keep: F) -> usize {
        let mut st = self.state.lock().unwrap();
        let before = st.map.len();
        st.map.retain(|k, s| keep(k, s));
        before - st.map.len()
    }

    /// Remove one key's sketch without densifying (for cross-shard moves).
    pub(crate) fn take(&self, key: &K) -> Option<AdaptiveSketch> {
        self.state.lock().unwrap().map.remove(key)
    }

    /// Merge a sketch into `key`'s sketch (created if absent).
    pub(crate) fn merge_in(
        &self,
        cfg: HllConfig,
        key: K,
        other: AdaptiveSketch,
    ) -> Result<(), crate::hll::SketchError> {
        let mut st = self.state.lock().unwrap();
        match st.map.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => e.get_mut().merge_into(other),
            std::collections::hash_map::Entry::Vacant(e) => {
                if *other.config() != cfg {
                    return Err(crate::hll::SketchError::ConfigMismatch(*other.config(), cfg));
                }
                e.insert(other);
                Ok(())
            }
        }
    }

    /// Fold every sketch in this shard into `acc` (bucket-wise max).
    /// Dense keys merge register files directly (no clone); sparse keys
    /// apply only their live entries — O(live entries), not O(m), so a
    /// million mostly-small keys fold in millions of updates rather
    /// than billions of register merges.
    pub(crate) fn fold_into(&self, acc: &mut HllSketch) {
        let mut st = self.state.lock().unwrap();
        for sketch in st.map.values_mut() {
            debug_assert_eq!(sketch.config(), acc.config());
            match sketch {
                AdaptiveSketch::Dense(d) => {
                    acc.merge(d).expect("registry sketches share one config");
                }
                AdaptiveSketch::Sparse(s) => {
                    s.for_each_entry(|idx, rank| acc.update_register(idx, rank));
                }
            }
        }
    }

    /// Run `f` over every (key, estimate) pair (bulk estimate API).
    pub(crate) fn for_each_estimate<F: FnMut(&K, f64)>(&self, mut f: F) {
        let mut st = self.state.lock().unwrap();
        for (k, s) in st.map.iter_mut() {
            let e = s.estimate();
            f(k, e);
        }
    }

    pub(crate) fn stats(&self) -> ShardStats {
        let st = self.state.lock().unwrap();
        let mut out = ShardStats { words: st.words, keys: st.map.len(), ..ShardStats::default() };
        for sketch in st.map.values() {
            if sketch.is_sparse() {
                out.sparse_keys += 1;
            } else {
                out.dense_keys += 1;
            }
            out.memory_bytes += sketch.memory_bytes();
        }
        out
    }

    pub(crate) fn clear(&self) {
        let mut st = self.state.lock().unwrap();
        st.map.clear();
        st.words = 0;
    }
}
