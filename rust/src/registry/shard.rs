//! One stripe of the registry: a mutex-guarded key → sketch map.
//!
//! Everything here runs under the shard lock; the registry guarantees a
//! caller never holds two shard locks at once (cross-shard operations
//! release the first lock before taking the second), so there is no lock
//! ordering to get wrong.
//!
//! Each key carries a logical last-touch tick alongside its sketch (the
//! registry's monotone ingest clock), which is what the TTL sweep
//! ([`Shard::evict_idle`]) and the LRU size-budget eviction
//! ([`Shard::collect_meta`] + retain) key off. A coarse wall-clock
//! stamp (seconds) rides along for the Duration-based TTL sweep
//! ([`Shard::evict_idle_wall`]).
//!
//! When dirty tracking is enabled (replication primaries — see
//! [`crate::replica`]), every mutating touch also records the key in a
//! per-shard dirty set; [`Shard::drain_dirty`] swaps the set out under
//! the same lock the mutation held, so a write either lands in the
//! current drain or the next one — never in neither.

use std::collections::{HashMap, HashSet};
use std::hash::Hash;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use super::config::ShardStats;
use crate::hll::{AdaptiveSketch, HllConfig, HllSketch};

#[derive(Debug)]
pub(crate) struct Shard<K> {
    state: Mutex<ShardState<K>>,
    /// Registry-wide dirty-tracking switch, shared by every shard. Read
    /// under the shard lock on each mutation; off (the default) it costs
    /// one relaxed load and no dirty-set traffic.
    track_dirty: Arc<AtomicBool>,
}

#[derive(Debug)]
struct ShardState<K> {
    map: HashMap<K, KeyEntry>,
    words: u64,
    /// Keys mutated since the last [`Shard::drain_dirty`]. Only
    /// populated while the shared `track_dirty` flag is set.
    dirty: HashSet<K>,
}

/// One key's live state: the sketch plus the registry clock tick and
/// coarse wall-clock second of the last write that touched it.
#[derive(Debug)]
struct KeyEntry {
    sketch: AdaptiveSketch,
    last_touch: u64,
    last_touch_wall: u64,
}

impl KeyEntry {
    fn new(cfg: HllConfig, now: u64, wall: u64) -> Self {
        Self { sketch: AdaptiveSketch::new(cfg), last_touch: now, last_touch_wall: wall }
    }

    /// Monotone touch: ticks are taken from the registry clock *before*
    /// the shard lock, so two concurrent ingests of one key can apply
    /// their ticks in either order — a plain assignment could move the
    /// key's last touch backwards and get a just-touched key TTL-evicted.
    /// The wall stamp gets the same treatment.
    fn touch(&mut self, now: u64, wall: u64) {
        self.last_touch = self.last_touch.max(now);
        self.last_touch_wall = self.last_touch_wall.max(wall);
    }
}

impl<K: Eq + Hash> Shard<K> {
    pub(crate) fn new(track_dirty: Arc<AtomicBool>) -> Self {
        Self {
            state: Mutex::new(ShardState {
                map: HashMap::new(),
                words: 0,
                dirty: HashSet::new(),
            }),
            track_dirty,
        }
    }

    /// Whether mutations must record their key in the dirty set. Read
    /// while the caller holds (or is about to take) the shard lock.
    fn dirty_on(&self) -> bool {
        self.track_dirty.load(Ordering::Relaxed)
    }

    /// Take the shard lock, recovering from poison: a panic in a
    /// caller-supplied predicate (e.g. inside `retain`) must not turn
    /// every later query into a second panic — the map holds monotone
    /// max-register sketches that cannot be left logically torn, so the
    /// state is safe to keep serving. This is the panic-free shutdown
    /// path the service layer relies on.
    fn lock(&self) -> MutexGuard<'_, ShardState<K>> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Fold pre-hashed words into one key's sketch (created on first
    /// touch).
    pub(crate) fn ingest_hashes(&self, cfg: HllConfig, key: K, hashes: &[u64], now: u64, wall: u64)
    where
        K: Clone,
    {
        let dirty = self.dirty_on();
        let mut st = self.lock();
        if dirty {
            st.dirty.insert(key.clone());
        }
        let entry = st.map.entry(key).or_insert_with(|| KeyEntry::new(cfg, now, wall));
        entry.touch(now, wall);
        for &h in hashes {
            entry.sketch.insert_hash(h);
        }
        st.words += hashes.len() as u64;
    }

    /// Fold a run of (key, hash) pairs under one lock acquisition.
    pub(crate) fn ingest_pairs(&self, cfg: HllConfig, pairs: &[(K, u64)], now: u64, wall: u64)
    where
        K: Clone,
    {
        let dirty = self.dirty_on();
        let mut st = self.lock();
        for (key, h) in pairs {
            if dirty {
                st.dirty.insert(key.clone());
            }
            let entry =
                st.map.entry(key.clone()).or_insert_with(|| KeyEntry::new(cfg, now, wall));
            entry.touch(now, wall);
            entry.sketch.insert_hash(*h);
        }
        st.words += pairs.len() as u64;
    }

    /// Fold raw (key, word) pairs under one lock acquisition, hashing
    /// in-loop — the keyed coordinator's hot path (no intermediate
    /// buffer; callers feed whatever shape they hold through an
    /// iterator). The optional global union sketch is lock-free, so
    /// raising it from inside the shard lock is safe and keeps the
    /// word hashed exactly once.
    pub(crate) fn ingest_words_iter<'a>(
        &self,
        cfg: HllConfig,
        pairs: impl Iterator<Item = (&'a K, u32)>,
        global: Option<&crate::hll::ConcurrentHllSketch>,
        now: u64,
        wall: u64,
    ) where
        K: Clone + 'a,
    {
        let dirty = self.dirty_on();
        let mut st = self.lock();
        let mut n = 0u64;
        for (key, word) in pairs {
            let h = cfg.hash_word(word);
            if let Some(g) = global {
                g.insert_hash(h);
            }
            if dirty {
                st.dirty.insert(key.clone());
            }
            let entry =
                st.map.entry(key.clone()).or_insert_with(|| KeyEntry::new(cfg, now, wall));
            entry.touch(now, wall);
            entry.sketch.insert_hash(h);
            n += 1;
        }
        st.words += n;
    }

    pub(crate) fn estimate(&self, key: &K) -> Option<f64> {
        let mut st = self.lock();
        st.map.get_mut(key).map(|e| e.sketch.estimate())
    }

    pub(crate) fn len(&self) -> usize {
        self.lock().map.len()
    }

    /// Remove one key; returns its final dense register file, if present.
    pub(crate) fn evict(&self, key: &K) -> Option<HllSketch> {
        let mut st = self.lock();
        st.map.remove(key).map(|e| e.sketch.into_dense())
    }

    /// Keep only keys the predicate approves; returns how many were
    /// evicted. The predicate may mutate the sketch (e.g. to estimate).
    pub(crate) fn retain<F: FnMut(&K, &mut AdaptiveSketch) -> bool>(&self, mut keep: F) -> usize {
        let mut st = self.lock();
        let before = st.map.len();
        st.map.retain(|k, e| keep(k, &mut e.sketch));
        before - st.map.len()
    }

    /// Drop every key whose last touch predates `cutoff`; returns how
    /// many aged out.
    pub(crate) fn evict_idle(&self, cutoff: u64) -> usize {
        let mut st = self.lock();
        let before = st.map.len();
        st.map.retain(|_, e| e.last_touch >= cutoff);
        before - st.map.len()
    }

    /// Wall-clock twin of [`Shard::evict_idle`]: drop every key whose
    /// last wall-clock touch (seconds) predates `cutoff_secs`.
    pub(crate) fn evict_idle_wall(&self, cutoff_secs: u64) -> usize {
        let mut st = self.lock();
        let before = st.map.len();
        st.map.retain(|_, e| e.last_touch_wall >= cutoff_secs);
        before - st.map.len()
    }

    /// Swap out the dirty set and append each still-live dirty key's
    /// sketch in wire-format-v2 bytes. Like [`Shard::export_bytes`], the
    /// lock is held only to take the set and clone the live sketches;
    /// densification and serialization happen after release. Keys that
    /// were dirtied and then evicted before the drain are skipped —
    /// eviction does not replicate (see [`crate::replica`]).
    pub(crate) fn drain_dirty(&self, out: &mut Vec<(K, Vec<u8>)>)
    where
        K: Clone,
    {
        let cloned: Vec<(K, AdaptiveSketch)> = {
            let mut st = self.lock();
            if st.dirty.is_empty() {
                return;
            }
            let dirty = std::mem::take(&mut st.dirty);
            let mut v = Vec::with_capacity(dirty.len());
            for key in dirty {
                if let Some(entry) = st.map.get(&key) {
                    v.push((key, entry.sketch.clone()));
                }
            }
            v
        };
        for (key, sketch) in cloned {
            out.push((key, sketch.into_dense().to_bytes()));
        }
    }

    /// Number of keys currently awaiting a dirty drain.
    pub(crate) fn dirty_len(&self) -> usize {
        self.lock().dirty.len()
    }

    /// Append `(key, last_touch, memory_bytes)` for every live key — the
    /// input the registry's LRU budget eviction sorts globally.
    pub(crate) fn collect_meta(&self, out: &mut Vec<(K, u64, usize)>)
    where
        K: Clone,
    {
        let st = self.lock();
        for (k, e) in st.map.iter() {
            out.push((k.clone(), e.last_touch, e.sketch.memory_bytes()));
        }
    }

    /// Append every key's sketch in wire-format-v2 bytes. The lock is
    /// held only while *cloning* the live sketches (proportional to
    /// their in-memory size — cheap for sparse keys); densification and
    /// serialization happen after release, so a snapshot walk does not
    /// stall ingest on this shard for the whole encode.
    pub(crate) fn export_bytes(&self, out: &mut Vec<(K, Vec<u8>)>)
    where
        K: Clone,
    {
        let cloned: Vec<(K, AdaptiveSketch)> = {
            let st = self.lock();
            st.map.iter().map(|(k, e)| (k.clone(), e.sketch.clone())).collect()
        };
        for (k, sketch) in cloned {
            out.push((k, sketch.into_dense().to_bytes()));
        }
    }

    /// Remove one key's sketch without densifying (for cross-shard moves).
    pub(crate) fn take(&self, key: &K) -> Option<AdaptiveSketch> {
        self.lock().map.remove(key).map(|e| e.sketch)
    }

    /// Merge a sketch into `key`'s sketch (created if absent).
    pub(crate) fn merge_in(
        &self,
        cfg: HllConfig,
        key: K,
        other: AdaptiveSketch,
        now: u64,
        wall: u64,
    ) -> Result<(), crate::hll::SketchError>
    where
        K: Clone,
    {
        let dirty = self.dirty_on();
        let mut st = self.lock();
        // Only mark dirty once the merge is known to apply; a failed
        // config check must not enqueue a key that was never created.
        match st.map.entry(key.clone()) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                let entry = e.get_mut();
                entry.sketch.merge_into(other)?;
                entry.touch(now, wall);
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                if *other.config() != cfg {
                    return Err(crate::hll::SketchError::ConfigMismatch(*other.config(), cfg));
                }
                e.insert(KeyEntry { sketch: other, last_touch: now, last_touch_wall: wall });
            }
        }
        if dirty {
            st.dirty.insert(key);
        }
        Ok(())
    }

    /// Fold every sketch in this shard into `acc` (bucket-wise max).
    /// Dense keys merge register files directly (no clone); sparse keys
    /// apply only their live entries — O(live entries), not O(m), so a
    /// million mostly-small keys fold in millions of updates rather
    /// than billions of register merges.
    pub(crate) fn fold_into(&self, acc: &mut HllSketch) {
        let mut st = self.lock();
        for entry in st.map.values_mut() {
            debug_assert_eq!(entry.sketch.config(), acc.config());
            match &mut entry.sketch {
                AdaptiveSketch::Dense(d) => {
                    acc.merge(d).expect("registry sketches share one config");
                }
                AdaptiveSketch::Sparse(s) => {
                    s.for_each_entry(|idx, rank| acc.update_register(idx, rank));
                }
            }
        }
    }

    /// Run `f` over every (key, estimate) pair (bulk estimate API).
    pub(crate) fn for_each_estimate<F: FnMut(&K, f64)>(&self, mut f: F) {
        let mut st = self.lock();
        for (k, e) in st.map.iter_mut() {
            let est = e.sketch.estimate();
            f(k, est);
        }
    }

    pub(crate) fn stats(&self) -> ShardStats {
        let st = self.lock();
        let mut out = ShardStats { words: st.words, keys: st.map.len(), ..ShardStats::default() };
        for entry in st.map.values() {
            if entry.sketch.is_sparse() {
                out.sparse_keys += 1;
            } else {
                out.dense_keys += 1;
            }
            out.memory_bytes += entry.sketch.memory_bytes();
        }
        out
    }

    pub(crate) fn clear(&self) {
        let mut st = self.lock();
        st.map.clear();
        st.words = 0;
        st.dirty.clear();
    }
}
