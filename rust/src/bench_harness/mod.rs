//! Benchmark harness substrate (the offline crate set has no `criterion`).
//!
//! Provides warmup + timed iterations with basic robust statistics
//! (median, MAD, min), throughput reporting, and a consistent text output
//! format shared by every `rust/benches/*.rs` target. Respects
//! `HLL_BENCH_QUICK=1` for fast smoke runs (used by `cargo test`-adjacent
//! CI loops).

use std::time::{Duration, Instant};

/// One benchmark measurement series.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub samples: Vec<f64>,
    /// Work per iteration (for throughput), if declared.
    pub bytes_per_iter: Option<u64>,
    pub items_per_iter: Option<u64>,
}

impl Measurement {
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if s.is_empty() {
            return f64::NAN;
        }
        let mid = s.len() / 2;
        if s.len() % 2 == 0 {
            (s[mid - 1] + s[mid]) / 2.0
        } else {
            s[mid]
        }
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Median absolute deviation — robust spread estimate.
    pub fn mad(&self) -> f64 {
        let med = self.median();
        let mut devs: Vec<f64> = self.samples.iter().map(|s| (s - med).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if devs.is_empty() {
            return f64::NAN;
        }
        devs[devs.len() / 2]
    }

    pub fn throughput_bytes_per_s(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| b as f64 / self.median())
    }

    pub fn throughput_items_per_s(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n as f64 / self.median())
    }

    pub fn report_line(&self) -> String {
        let mut line = format!(
            "{:<44} median {:>12} (min {:>12}, mad {:>10}, n={})",
            self.name,
            crate::util::fmt::duration_s(self.median()),
            crate::util::fmt::duration_s(self.min()),
            crate::util::fmt::duration_s(self.mad()),
            self.samples.len()
        );
        if let Some(t) = self.throughput_bytes_per_s() {
            line.push_str(&format!("  {}", crate::util::fmt::gbytes_per_s(t)));
        }
        if let Some(t) = self.throughput_items_per_s() {
            line.push_str(&format!("  {:.1} Mitems/s", t / 1e6));
        }
        line
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    warmup: Duration,
    min_iters: usize,
    max_iters: usize,
    target_time: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        if quick_mode() {
            Self {
                warmup: Duration::from_millis(20),
                min_iters: 3,
                max_iters: 10,
                target_time: Duration::from_millis(120),
            }
        } else {
            Self {
                warmup: Duration::from_millis(300),
                min_iters: 10,
                max_iters: 200,
                target_time: Duration::from_secs(2),
            }
        }
    }
}

/// `HLL_BENCH_QUICK=1` shrinks every run for smoke testing.
pub fn quick_mode() -> bool {
    std::env::var("HLL_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    pub fn target_time(mut self, d: Duration) -> Self {
        self.target_time = d;
        self
    }

    /// Run `f` repeatedly; `f` returns an opaque value to defeat dead-code
    /// elimination (it is passed through `std::hint::black_box`).
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Measurement {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while samples.len() < self.min_iters
            || (t0.elapsed() < self.target_time && samples.len() < self.max_iters)
        {
            let s = Instant::now();
            std::hint::black_box(f());
            samples.push(s.elapsed().as_secs_f64());
        }
        Measurement { name: name.to_string(), samples, bytes_per_iter: None, items_per_iter: None }
    }

    /// As [`Bench::run`], declaring bytes of work per iteration.
    pub fn run_bytes<T, F: FnMut() -> T>(&self, name: &str, bytes: u64, f: F) -> Measurement {
        let mut m = self.run(name, f);
        m.bytes_per_iter = Some(bytes);
        m
    }

    pub fn run_items<T, F: FnMut() -> T>(&self, name: &str, items: u64, f: F) -> Measurement {
        let mut m = self.run(name, f);
        m.items_per_iter = Some(items);
        m
    }
}

/// Standard bench-binary preamble: prints a header and returns the
/// harness. All `rust/benches/*.rs` call this.
pub fn bench_main(title: &str) -> Bench {
    println!("\n=== {title} ===");
    if quick_mode() {
        println!("(quick mode: HLL_BENCH_QUICK=1 — reduced iterations)");
    }
    Bench::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_stats() {
        let m = Measurement {
            name: "t".into(),
            samples: vec![1.0, 2.0, 3.0, 4.0, 100.0],
            bytes_per_iter: Some(3_000_000_000),
            items_per_iter: None,
        };
        assert_eq!(m.median(), 3.0);
        assert_eq!(m.min(), 1.0);
        assert_eq!(m.mad(), 1.0);
        assert_eq!(m.throughput_bytes_per_s().unwrap(), 1e9);
    }

    #[test]
    fn even_sample_median() {
        let m = Measurement {
            name: "t".into(),
            samples: vec![1.0, 2.0, 3.0, 4.0],
            bytes_per_iter: None,
            items_per_iter: None,
        };
        assert_eq!(m.median(), 2.5);
    }

    #[test]
    fn run_collects_samples() {
        let b = Bench::new()
            .warmup(Duration::from_millis(1))
            .target_time(Duration::from_millis(10));
        let m = b.run("noop", || 1 + 1);
        assert!(m.samples.len() >= 3);
        assert!(m.median() >= 0.0);
    }

    #[test]
    fn report_line_contains_name_and_throughput() {
        let m = Measurement {
            name: "hash/64".into(),
            samples: vec![0.5],
            bytes_per_iter: Some(5_000_000_000),
            items_per_iter: Some(1_000_000),
        };
        let line = m.report_line();
        assert!(line.contains("hash/64"));
        assert!(line.contains("GB/s"));
        assert!(line.contains("Mitems/s"));
    }
}
