//! PJRT runtime layer: loads the AOT-lowered HLO artifacts (`make
//! artifacts`) and executes them from the Rust hot path. See
//! `/opt/xla-example/load_hlo/` for the reference wiring this follows.

pub mod artifacts;
pub mod client;
pub mod engine;
pub mod service;
pub mod xla_stub;

pub use artifacts::{ArtifactKind, ArtifactMeta, Manifest, ManifestError};
pub use client::{Result, RuntimeError, XlaRuntime};
pub use engine::{Engine, EngineKind, EstimateOut, NativeEngine, XlaEngine};
pub use service::{RegistryHandle, RegistryService, XlaHandle, XlaService};
