//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The build environment has no crates.io access, so the real `xla`
//! crate (PJRT client + HLO loader) cannot be linked. This module
//! provides the exact API surface [`super::client`] consumes: client
//! construction succeeds (so [`super::service::XlaService`] starts and
//! manifest/shape validation keeps working, as the failure-injection
//! tests require), while anything that would actually touch a PJRT
//! device — loading HLO text, compiling, allocating device buffers —
//! returns [`Error::Unavailable`]. Swapping the real crate back in is a
//! one-line change in `client.rs`.

use std::path::Path;

/// Errors surfaced by the stub (mirrors `xla::Error`'s role).
#[derive(Debug)]
pub enum Error {
    /// The PJRT runtime is not linked into this build.
    Unavailable(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "PJRT runtime unavailable in this build (xla stub): {what}"
            ),
        }
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error::Unavailable(what.to_string())
}

/// Stub PJRT client. Construction succeeds; device work fails.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Ok(Self)
    }

    pub fn platform_name(&self) -> &'static str {
        "stub-cpu"
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compile"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable("buffer_from_host_buffer"))
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execute"))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execute_b"))
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Stub host literal.
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn shape(&self) -> Result<Shape, Error> {
        Err(unavailable("shape"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("to_vec"))
    }
}

/// Stub shape handle.
pub struct Shape;

impl Shape {
    pub fn is_tuple(&self) -> bool {
        false
    }
}

/// Stub HLO module proto. Loading HLO text is the first device-path step
/// in [`super::client::XlaRuntime::executable`]; it fails here, which is
/// exactly the "lazy compile error" behaviour the failure-injection
/// suite pins down.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self, Error> {
        Err(unavailable(&format!(
            "cannot load HLO text {} without the PJRT runtime",
            path.as_ref().display()
        )))
    }
}

/// Stub computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_starts_but_device_work_fails() {
        let client = PjRtClient::cpu().expect("stub client constructs");
        assert_eq!(client.device_count(), 0);
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(client
            .buffer_from_host_buffer(&[0i32; 4], &[4], None)
            .is_err());
    }

    #[test]
    fn error_message_names_the_stub() {
        let e = HloModuleProto::from_text_file("a.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
