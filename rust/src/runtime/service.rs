//! The XLA device service: a dedicated thread owning the PJRT client.
//!
//! The `xla` crate's client/executable types are thread-confined (`Rc` +
//! raw pointers), while the coordinator runs one worker thread per
//! pipeline. The service thread is the software analogue of the paper's
//! single shared FPGA device: workers submit aggregation/estimation jobs
//! through a channel-backed [`XlaHandle`] (Clone + Send) and block on the
//! reply, exactly like DMA requests queueing toward one PCIe endpoint.

use std::sync::mpsc;
use std::thread::JoinHandle;

use super::artifacts::Manifest;
use super::client::{Result, RuntimeError, XlaRuntime};
use crate::hll::HashKind;

enum Request {
    /// Chunked aggregate execution: every chunk already padded to the
    /// artifact's batch shape; registers stay device-resident across
    /// chunks.
    Aggregate {
        p: u8,
        h: HashKind,
        chunks: Vec<Vec<i32>>,
        regs_i32: Vec<i32>,
        reply: mpsc::Sender<Result<Vec<i32>>>,
    },
    Estimate {
        p: u8,
        h: HashKind,
        regs_i32: Vec<i32>,
        reply: mpsc::Sender<Result<(f64, f64, f64)>>,
    },
    Merge {
        p: u8,
        a_i32: Vec<i32>,
        b_i32: Vec<i32>,
        reply: mpsc::Sender<Result<Vec<i32>>>,
    },
    /// Batch shape lookup so callers can chunk correctly.
    AggregateBatchShape {
        p: u8,
        h: HashKind,
        want: usize,
        reply: mpsc::Sender<Result<usize>>,
    },
    Shutdown,
}

/// Cloneable, Send handle to the device service.
#[derive(Clone)]
pub struct XlaHandle {
    tx: mpsc::Sender<Request>,
}

/// The service itself; dropping it shuts the device thread down.
pub struct XlaService {
    tx: mpsc::Sender<Request>,
    join: Option<JoinHandle<()>>,
}

impl XlaService {
    /// Spawn the device thread over the default artifacts directory.
    pub fn start() -> Result<Self> {
        Self::start_with(Manifest::load_default()?)
    }

    pub fn start_with(manifest: Manifest) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        // Bring the runtime up on the service thread; report readiness
        // through a one-shot so `start` fails fast on broken artifacts.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("xla-device".into())
            .spawn(move || {
                let rt = match XlaRuntime::with_manifest(manifest) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                Self::serve(rt, rx);
            })
            .expect("spawn xla-device thread");
        ready_rx
            .recv()
            .unwrap_or_else(|_| Err(RuntimeError::Shape("device thread died".into())))?;
        Ok(Self { tx, join: Some(join) })
    }

    fn serve(rt: XlaRuntime, rx: mpsc::Receiver<Request>) {
        while let Ok(req) = rx.recv() {
            match req {
                Request::Aggregate { p, h, chunks, regs_i32, reply } => {
                    let want = chunks.first().map(|c| c.len()).unwrap_or(0);
                    let res = rt
                        .manifest()
                        .find_aggregate(p, h, want)
                        .cloned()
                        .ok_or_else(|| RuntimeError::ArtifactNotFound(format!(
                            "aggregate p={p} H={}",
                            h.bits()
                        )))
                        .and_then(|meta| rt.run_aggregate_chunks(&meta, &chunks, &regs_i32));
                    let _ = reply.send(res);
                }
                Request::Estimate { p, h, regs_i32, reply } => {
                    let res = rt
                        .manifest()
                        .find_estimate(p, h)
                        .cloned()
                        .ok_or_else(|| RuntimeError::ArtifactNotFound(format!(
                            "estimate p={p} H={}",
                            h.bits()
                        )))
                        .and_then(|meta| rt.run_estimate(&meta, &regs_i32));
                    let _ = reply.send(res);
                }
                Request::Merge { p, a_i32, b_i32, reply } => {
                    let res = rt
                        .manifest()
                        .find_merge(p)
                        .cloned()
                        .ok_or_else(|| {
                            RuntimeError::ArtifactNotFound(format!("merge p={p}"))
                        })
                        .and_then(|meta| rt.run_merge(&meta, &a_i32, &b_i32));
                    let _ = reply.send(res);
                }
                Request::AggregateBatchShape { p, h, want, reply } => {
                    let res = rt
                        .manifest()
                        .find_aggregate(p, h, want)
                        .map(|m| m.batch)
                        .ok_or_else(|| RuntimeError::ArtifactNotFound(format!(
                            "aggregate p={p} H={}",
                            h.bits()
                        )));
                    let _ = reply.send(res);
                }
                Request::Shutdown => break,
            }
        }
    }

    pub fn handle(&self) -> XlaHandle {
        XlaHandle { tx: self.tx.clone() }
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl XlaHandle {
    fn call<T>(
        &self,
        make: impl FnOnce(mpsc::Sender<Result<T>>) -> Request,
    ) -> Result<T> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(make(reply_tx))
            .map_err(|_| RuntimeError::Shape("xla device thread gone".into()))?;
        reply_rx
            .recv()
            .map_err(|_| RuntimeError::Shape("xla device thread dropped reply".into()))?
    }

    /// The static batch shape the device will use for a `want`-sized
    /// aggregate call.
    pub fn aggregate_batch_shape(&self, p: u8, h: HashKind, want: usize) -> Result<usize> {
        self.call(|reply| Request::AggregateBatchShape { p, h, want, reply })
    }

    /// Chunked aggregate: all chunks must share one artifact batch shape
    /// (pad tails — idempotent re-insertion is free).
    pub fn aggregate(
        &self,
        p: u8,
        h: HashKind,
        chunks: Vec<Vec<i32>>,
        regs_i32: Vec<i32>,
    ) -> Result<Vec<i32>> {
        self.call(|reply| Request::Aggregate { p, h, chunks, regs_i32, reply })
    }

    pub fn estimate(&self, p: u8, h: HashKind, regs_i32: Vec<i32>) -> Result<(f64, f64, f64)> {
        self.call(|reply| Request::Estimate { p, h, regs_i32, reply })
    }

    pub fn merge(&self, p: u8, a_i32: Vec<i32>, b_i32: Vec<i32>) -> Result<Vec<i32>> {
        self.call(|reply| Request::Merge { p, a_i32, b_i32, reply })
    }
}
