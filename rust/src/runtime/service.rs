//! Device-style services: dedicated threads answering requests over
//! channel-backed handles.
//!
//! Two services live here:
//!
//! * [`XlaService`] — owns the PJRT client. The `xla` crate's
//!   client/executable types are thread-confined (`Rc` + raw pointers),
//!   while the coordinator runs one worker thread per pipeline. The
//!   service thread is the software analogue of the paper's single
//!   shared FPGA device: workers submit aggregation/estimation jobs
//!   through a channel-backed [`XlaHandle`] (Clone + Send) and block on
//!   the reply, exactly like DMA requests queueing toward one PCIe
//!   endpoint.
//! * [`RegistryService`] — the query front-end of the multi-tenant
//!   [`crate::registry::SketchRegistry`]: per-key / global estimates,
//!   accounting and eviction served off the ingest hot path, through the
//!   same cloneable-handle pattern (the seam a future network serving
//!   layer plugs into).

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use super::artifacts::Manifest;
use super::client::{Result, RuntimeError, XlaRuntime};
use crate::hll::HashKind;
use crate::obs::{Counter, MetricsRegistry};
use crate::registry::{RegistryStats, SketchRegistry};

enum Request {
    /// Chunked aggregate execution: every chunk already padded to the
    /// artifact's batch shape; registers stay device-resident across
    /// chunks.
    Aggregate {
        p: u8,
        h: HashKind,
        chunks: Vec<Vec<i32>>,
        regs_i32: Vec<i32>,
        reply: mpsc::Sender<Result<Vec<i32>>>,
    },
    Estimate {
        p: u8,
        h: HashKind,
        regs_i32: Vec<i32>,
        reply: mpsc::Sender<Result<(f64, f64, f64)>>,
    },
    Merge {
        p: u8,
        a_i32: Vec<i32>,
        b_i32: Vec<i32>,
        reply: mpsc::Sender<Result<Vec<i32>>>,
    },
    /// Batch shape lookup so callers can chunk correctly.
    AggregateBatchShape {
        p: u8,
        h: HashKind,
        want: usize,
        reply: mpsc::Sender<Result<usize>>,
    },
    Shutdown,
}

/// Cloneable, Send handle to the device service.
#[derive(Clone)]
pub struct XlaHandle {
    tx: mpsc::Sender<Request>,
}

/// The service itself; dropping it shuts the device thread down.
pub struct XlaService {
    tx: mpsc::Sender<Request>,
    join: Option<JoinHandle<()>>,
}

impl XlaService {
    /// Spawn the device thread over the default artifacts directory.
    pub fn start() -> Result<Self> {
        Self::start_with(Manifest::load_default()?)
    }

    pub fn start_with(manifest: Manifest) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        // Bring the runtime up on the service thread; report readiness
        // through a one-shot so `start` fails fast on broken artifacts.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("xla-device".into())
            .spawn(move || {
                let rt = match XlaRuntime::with_manifest(manifest) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                Self::serve(rt, rx);
            })
            .expect("spawn xla-device thread");
        ready_rx
            .recv()
            .unwrap_or_else(|_| Err(RuntimeError::ServiceGone("device thread died".into())))?;
        Ok(Self { tx, join: Some(join) })
    }

    fn serve(rt: XlaRuntime, rx: mpsc::Receiver<Request>) {
        while let Ok(req) = rx.recv() {
            match req {
                Request::Aggregate { p, h, chunks, regs_i32, reply } => {
                    let want = chunks.first().map(|c| c.len()).unwrap_or(0);
                    let res = rt
                        .manifest()
                        .find_aggregate(p, h, want)
                        .cloned()
                        .ok_or_else(|| RuntimeError::ArtifactNotFound(format!(
                            "aggregate p={p} H={}",
                            h.bits()
                        )))
                        .and_then(|meta| rt.run_aggregate_chunks(&meta, &chunks, &regs_i32));
                    let _ = reply.send(res);
                }
                Request::Estimate { p, h, regs_i32, reply } => {
                    let res = rt
                        .manifest()
                        .find_estimate(p, h)
                        .cloned()
                        .ok_or_else(|| RuntimeError::ArtifactNotFound(format!(
                            "estimate p={p} H={}",
                            h.bits()
                        )))
                        .and_then(|meta| rt.run_estimate(&meta, &regs_i32));
                    let _ = reply.send(res);
                }
                Request::Merge { p, a_i32, b_i32, reply } => {
                    let res = rt
                        .manifest()
                        .find_merge(p)
                        .cloned()
                        .ok_or_else(|| {
                            RuntimeError::ArtifactNotFound(format!("merge p={p}"))
                        })
                        .and_then(|meta| rt.run_merge(&meta, &a_i32, &b_i32));
                    let _ = reply.send(res);
                }
                Request::AggregateBatchShape { p, h, want, reply } => {
                    let res = rt
                        .manifest()
                        .find_aggregate(p, h, want)
                        .map(|m| m.batch)
                        .ok_or_else(|| RuntimeError::ArtifactNotFound(format!(
                            "aggregate p={p} H={}",
                            h.bits()
                        )));
                    let _ = reply.send(res);
                }
                Request::Shutdown => break,
            }
        }
    }

    pub fn handle(&self) -> XlaHandle {
        XlaHandle { tx: self.tx.clone() }
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl XlaHandle {
    fn call<T>(
        &self,
        make: impl FnOnce(mpsc::Sender<Result<T>>) -> Request,
    ) -> Result<T> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(make(reply_tx))
            .map_err(|_| RuntimeError::ServiceGone("xla device thread gone".into()))?;
        reply_rx
            .recv()
            .map_err(|_| RuntimeError::ServiceGone("xla device thread dropped reply".into()))?
    }

    /// The static batch shape the device will use for a `want`-sized
    /// aggregate call.
    pub fn aggregate_batch_shape(&self, p: u8, h: HashKind, want: usize) -> Result<usize> {
        self.call(|reply| Request::AggregateBatchShape { p, h, want, reply })
    }

    /// Chunked aggregate: all chunks must share one artifact batch shape
    /// (pad tails — idempotent re-insertion is free).
    pub fn aggregate(
        &self,
        p: u8,
        h: HashKind,
        chunks: Vec<Vec<i32>>,
        regs_i32: Vec<i32>,
    ) -> Result<Vec<i32>> {
        self.call(|reply| Request::Aggregate { p, h, chunks, regs_i32, reply })
    }

    pub fn estimate(&self, p: u8, h: HashKind, regs_i32: Vec<i32>) -> Result<(f64, f64, f64)> {
        self.call(|reply| Request::Estimate { p, h, regs_i32, reply })
    }

    pub fn merge(&self, p: u8, a_i32: Vec<i32>, b_i32: Vec<i32>) -> Result<Vec<i32>> {
        self.call(|reply| Request::Merge { p, a_i32, b_i32, reply })
    }
}

// ---------------------------------------------------------------------------
// Registry query service
// ---------------------------------------------------------------------------

enum RegistryRequest {
    Estimate { key: u64, reply: mpsc::Sender<Option<f64>> },
    GlobalEstimate { reply: mpsc::Sender<Option<f64>> },
    Keys { reply: mpsc::Sender<usize> },
    Stats { reply: mpsc::Sender<RegistryStats> },
    Evict { key: u64, reply: mpsc::Sender<bool> },
    Shutdown,
}

/// Cloneable, Send handle for registry queries.
#[derive(Clone)]
pub struct RegistryHandle {
    tx: mpsc::Sender<RegistryRequest>,
}

/// Query front-end over a shared [`SketchRegistry`]; dropping it shuts
/// the query thread down (the registry itself stays alive for ingest).
pub struct RegistryService {
    tx: mpsc::Sender<RegistryRequest>,
    join: Option<JoinHandle<()>>,
}

/// Per-kind query counters for an instrumented [`RegistryService`].
struct QueryCounters {
    estimate: Counter,
    global_estimate: Counter,
    keys: Counter,
    stats: Counter,
    evict: Counter,
}

impl QueryCounters {
    fn register(m: &MetricsRegistry) -> Self {
        let kind = |k: &'static str| Some(("kind", k.to_string()));
        Self {
            estimate: m.counter("registry_service_queries_total", kind("estimate")),
            global_estimate: m.counter("registry_service_queries_total", kind("global_estimate")),
            keys: m.counter("registry_service_queries_total", kind("keys")),
            stats: m.counter("registry_service_queries_total", kind("stats")),
            evict: m.counter("registry_service_queries_total", kind("evict")),
        }
    }
}

impl RegistryService {
    pub fn start(registry: Arc<SketchRegistry<u64>>) -> Self {
        Self::spawn(registry, None)
    }

    /// Like [`RegistryService::start`], but counts served queries per
    /// kind into `metrics` (`registry_service_queries_total{kind=...}`).
    pub fn start_with_metrics(
        registry: Arc<SketchRegistry<u64>>,
        metrics: &MetricsRegistry,
    ) -> Self {
        Self::spawn(registry, Some(QueryCounters::register(metrics)))
    }

    fn spawn(registry: Arc<SketchRegistry<u64>>, counters: Option<QueryCounters>) -> Self {
        let (tx, rx) = mpsc::channel::<RegistryRequest>();
        let join = std::thread::Builder::new()
            .name("registry-query".into())
            .spawn(move || Self::serve(registry, rx, counters))
            .expect("spawn registry-query thread");
        Self { tx, join: Some(join) }
    }

    fn serve(
        registry: Arc<SketchRegistry<u64>>,
        rx: mpsc::Receiver<RegistryRequest>,
        counters: Option<QueryCounters>,
    ) {
        while let Ok(req) = rx.recv() {
            match req {
                RegistryRequest::Estimate { key, reply } => {
                    if let Some(c) = &counters {
                        c.estimate.inc();
                    }
                    let _ = reply.send(registry.estimate(&key));
                }
                RegistryRequest::GlobalEstimate { reply } => {
                    if let Some(c) = &counters {
                        c.global_estimate.inc();
                    }
                    let _ = reply.send(registry.global_estimate());
                }
                RegistryRequest::Keys { reply } => {
                    if let Some(c) = &counters {
                        c.keys.inc();
                    }
                    let _ = reply.send(registry.len());
                }
                RegistryRequest::Stats { reply } => {
                    if let Some(c) = &counters {
                        c.stats.inc();
                    }
                    let _ = reply.send(registry.stats());
                }
                RegistryRequest::Evict { key, reply } => {
                    if let Some(c) = &counters {
                        c.evict.inc();
                    }
                    let _ = reply.send(registry.evict(&key).is_some());
                }
                RegistryRequest::Shutdown => break,
            }
        }
    }

    pub fn handle(&self) -> RegistryHandle {
        RegistryHandle { tx: self.tx.clone() }
    }
}

impl Drop for RegistryService {
    fn drop(&mut self) {
        let _ = self.tx.send(RegistryRequest::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl RegistryHandle {
    /// Submit one request and block on the reply.
    ///
    /// Both failure edges of the channel pair are typed, never panics:
    /// a dropped service (receiver gone) fails the `send`, and a service
    /// that dies mid-request (sender gone before replying) fails the
    /// `recv` — either way the caller gets
    /// [`RuntimeError::ServiceGone`], so handles outliving their
    /// [`RegistryService`] degrade into errors rather than hangs or
    /// panics (asserted by `handle_is_a_typed_error_after_service_drop`).
    fn call<T>(&self, make: impl FnOnce(mpsc::Sender<T>) -> RegistryRequest) -> Result<T> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(make(reply_tx))
            .map_err(|_| RuntimeError::ServiceGone("registry query thread gone".into()))?;
        reply_rx
            .recv()
            .map_err(|_| RuntimeError::ServiceGone("registry query thread dropped reply".into()))
    }

    /// Per-key distinct estimate; `Ok(None)` for unknown keys.
    pub fn estimate(&self, key: u64) -> Result<Option<f64>> {
        self.call(|reply| RegistryRequest::Estimate { key, reply })
    }

    /// Distinct count across all keys (if the registry tracks it).
    pub fn global_estimate(&self) -> Result<Option<f64>> {
        self.call(|reply| RegistryRequest::GlobalEstimate { reply })
    }

    /// Live key count.
    pub fn keys(&self) -> Result<usize> {
        self.call(|reply| RegistryRequest::Keys { reply })
    }

    /// Per-shard accounting snapshot.
    pub fn stats(&self) -> Result<RegistryStats> {
        self.call(|reply| RegistryRequest::Stats { reply })
    }

    /// Drop one key; `Ok(true)` if it existed.
    pub fn evict(&self, key: u64) -> Result<bool> {
        self.call(|reply| RegistryRequest::Evict { key, reply })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::RegistryConfig;

    #[test]
    fn registry_service_answers_queries() {
        let registry = SketchRegistry::shared(RegistryConfig {
            shards: 8,
            ..RegistryConfig::default()
        })
        .unwrap();
        registry.ingest(7, &[1, 2, 3, 2]);
        registry.ingest(8, &[10, 11]);

        let svc = RegistryService::start(registry.clone());
        let handle = svc.handle();
        assert_eq!(handle.keys().unwrap(), 2);
        let est = handle.estimate(7).unwrap().expect("key 7 live");
        assert!((est - 3.0).abs() < 0.5, "{est}");
        assert!(handle.estimate(99).unwrap().is_none());
        assert!(handle.global_estimate().unwrap().is_some());
        let stats = handle.stats().unwrap();
        assert_eq!(stats.keys(), 2);
        assert_eq!(stats.words(), 6);
        // Tiny keys sit in the sparse tier, and the stats carry the
        // registry's configured estimator.
        assert_eq!(stats.sparse_keys(), 2);
        assert_eq!(stats.packed_keys(), 0);
        assert_eq!(stats.dense_keys(), 0);
        assert_eq!(stats.estimator(), crate::hll::EstimatorKind::Ertl);

        // Handles stay usable from other threads.
        let h2 = handle.clone();
        std::thread::spawn(move || h2.keys().unwrap()).join().unwrap();

        // Eviction goes through the service, visible to direct users.
        assert!(handle.evict(7).unwrap());
        assert!(!handle.evict(7).unwrap());
        assert_eq!(registry.len(), 1);
    }

    #[test]
    fn instrumented_service_counts_queries_per_kind() {
        let registry = SketchRegistry::shared(RegistryConfig {
            shards: 4,
            ..RegistryConfig::default()
        })
        .unwrap();
        registry.ingest(1, &[1, 2, 3]);
        let metrics = MetricsRegistry::shared();
        let svc = RegistryService::start_with_metrics(registry, &metrics);
        let handle = svc.handle();
        handle.estimate(1).unwrap();
        handle.estimate(2).unwrap();
        handle.keys().unwrap();
        // Drop joins the query thread, so every count is flushed.
        drop(svc);
        let text = metrics.render();
        assert!(text.contains("registry_service_queries_total{kind=\"estimate\"} 2\n"), "{text}");
        assert!(text.contains("registry_service_queries_total{kind=\"keys\"} 1\n"), "{text}");
        assert!(text.contains("registry_service_queries_total{kind=\"evict\"} 0\n"), "{text}");
    }

    #[test]
    fn handle_is_a_typed_error_after_service_drop() {
        let registry = SketchRegistry::shared(RegistryConfig {
            shards: 4,
            ..RegistryConfig::default()
        })
        .unwrap();
        registry.ingest(1, &[1, 2, 3]);
        let svc = RegistryService::start(registry.clone());
        let handle = svc.handle();
        assert!(handle.estimate(1).unwrap().is_some());

        // Dropping the service joins the query thread; every later call
        // on a surviving handle must be Err(ServiceGone) — not a panic,
        // not a hang.
        drop(svc);
        assert!(matches!(handle.estimate(1), Err(RuntimeError::ServiceGone(_))));
        assert!(matches!(handle.global_estimate(), Err(RuntimeError::ServiceGone(_))));
        assert!(matches!(handle.keys(), Err(RuntimeError::ServiceGone(_))));
        assert!(matches!(handle.stats(), Err(RuntimeError::ServiceGone(_))));
        assert!(matches!(handle.evict(1), Err(RuntimeError::ServiceGone(_))));
        // Clones of a dead handle behave the same.
        let clone = handle.clone();
        assert!(clone.keys().is_err());
        // The registry itself is untouched by service shutdown.
        assert_eq!(registry.len(), 1);
    }
}

