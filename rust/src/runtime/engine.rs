//! The `Engine` abstraction: how a pipeline worker executes the HLL
//! aggregation/computation phases.
//!
//! Two implementations:
//!
//! * [`NativeEngine`] — the pure-Rust hot path (the CPU-baseline code of
//!   the paper's Fig 4(b), also used for odd-sized batch tails);
//! * [`XlaEngine`] — executes the AOT-lowered JAX/Pallas artifacts via
//!   PJRT through the [`super::service::XlaService`] device thread,
//!   proving the three layers compose. Batches are chunked to the
//!   artifact's static shape; tails are padded with an already-inserted
//!   element (idempotence makes this a no-op on the sketch state).
//!
//! An integration test asserts the two produce bit-identical register
//! files on random streams.

use super::client::{Result, RuntimeError};
use super::service::XlaHandle;
use crate::hll::{EstimateBreakdown, EstimatorKind, HllConfig, HllSketch};

/// Estimate triple as produced by the computation phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EstimateOut {
    pub raw: f64,
    pub zero_registers: usize,
    pub estimate: f64,
}

impl From<EstimateBreakdown> for EstimateOut {
    fn from(b: EstimateBreakdown) -> Self {
        Self { raw: b.raw, zero_registers: b.zero_registers, estimate: b.estimate }
    }
}

/// A pipeline's compute backend.
pub trait Engine: Send {
    fn name(&self) -> &'static str;

    /// Fold a batch of 32-bit stream words into the sketch.
    fn aggregate(&self, batch: &[u32], sketch: &mut HllSketch) -> Result<()>;

    /// Computation phase over the sketch's registers.
    fn estimate(&self, sketch: &HllSketch) -> Result<EstimateOut>;

    /// Bucket-wise max of `other` into `sketch`.
    fn merge(&self, sketch: &mut HllSketch, other: &HllSketch) -> Result<()>;
}

/// Pure-Rust engine.
#[derive(Debug, Clone, Default)]
pub struct NativeEngine;

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn aggregate(&self, batch: &[u32], sketch: &mut HllSketch) -> Result<()> {
        sketch.insert_batch(batch);
        Ok(())
    }

    fn estimate(&self, sketch: &HllSketch) -> Result<EstimateOut> {
        // Pinned to the legacy range-split estimator: the XLA engine runs
        // the AOT-lowered Pallas estimate kernel, which implements exactly
        // that computation, and engine parity asserts the two backends
        // agree to ~1e-9. The registry/serving layer, not the engine
        // pipeline, is where the Ertl default applies.
        Ok(sketch.estimate_breakdown_with(EstimatorKind::Legacy).into())
    }

    fn merge(&self, sketch: &mut HllSketch, other: &HllSketch) -> Result<()> {
        sketch
            .merge(other)
            .map_err(|e| RuntimeError::Shape(e.to_string()))
    }
}

/// PJRT-backed engine executing the JAX/Pallas artifacts through the
/// device-service thread.
pub struct XlaEngine {
    handle: XlaHandle,
    cfg: HllConfig,
    /// Preferred batch shape (the artifact actually used per chunk is the
    /// largest one fitting the remaining data).
    preferred_batch: usize,
}

impl std::fmt::Debug for XlaEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaEngine")
            .field("cfg", &self.cfg)
            .field("preferred_batch", &self.preferred_batch)
            .finish()
    }
}

impl XlaEngine {
    pub fn new(handle: XlaHandle, cfg: HllConfig, preferred_batch: usize) -> Result<Self> {
        // Validate that the artifacts this engine needs exist up front.
        handle.aggregate_batch_shape(cfg.p(), cfg.hash(), preferred_batch)?;
        Ok(Self { handle, cfg, preferred_batch })
    }

    fn regs_to_i32(sketch: &HllSketch) -> Vec<i32> {
        sketch.registers().iter().map(|&r| r as i32).collect()
    }

    fn regs_from_i32(&self, regs: Vec<i32>) -> Result<HllSketch> {
        let bytes: Vec<u8> = regs.iter().map(|&r| r as u8).collect();
        HllSketch::from_registers(self.cfg, bytes)
            .map_err(|e| RuntimeError::Shape(e.to_string()))
    }
}

impl Engine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn aggregate(&self, batch: &[u32], sketch: &mut HllSketch) -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        debug_assert_eq!(*sketch.config(), self.cfg);
        let (p, h) = (self.cfg.p(), self.cfg.hash());
        // One artifact shape for the whole call; tails are padded with an
        // already-present element (idempotent re-insertion, exact no-op).
        let shape = self
            .handle
            .aggregate_batch_shape(p, h, batch.len().min(self.preferred_batch))?;
        let mut chunks: Vec<Vec<i32>> = Vec::with_capacity(batch.len().div_ceil(shape));
        for chunk in batch.chunks(shape) {
            let mut keys: Vec<i32> = Vec::with_capacity(shape);
            keys.extend(chunk.iter().map(|&k| k as i32));
            keys.resize(shape, chunk[0] as i32);
            chunks.push(keys);
        }
        // Single device-service call: registers stay device-resident
        // across all chunks (uploaded once, downloaded once).
        let regs = self
            .handle
            .aggregate(p, h, chunks, Self::regs_to_i32(sketch))?;
        *sketch = self.regs_from_i32(regs)?;
        Ok(())
    }

    fn estimate(&self, sketch: &HllSketch) -> Result<EstimateOut> {
        let regs = Self::regs_to_i32(sketch);
        let (raw, v, est) = self.handle.estimate(self.cfg.p(), self.cfg.hash(), regs)?;
        Ok(EstimateOut { raw, zero_registers: v as usize, estimate: est })
    }

    fn merge(&self, sketch: &mut HllSketch, other: &HllSketch) -> Result<()> {
        let a = Self::regs_to_i32(sketch);
        let b = Self::regs_to_i32(other);
        let merged = self.handle.merge(self.cfg.p(), a, b)?;
        *sketch = self.regs_from_i32(merged)?;
        Ok(())
    }
}

/// Which engine a worker should use — CLI-selectable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Native,
    Xla,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "native" => Some(Self::Native),
            "xla" => Some(Self::Xla),
            _ => None,
        }
    }

    /// Build an engine instance. `handle` is required for
    /// [`EngineKind::Xla`].
    pub fn build(
        self,
        cfg: HllConfig,
        handle: Option<XlaHandle>,
        preferred_batch: usize,
    ) -> Result<Box<dyn Engine>> {
        match self {
            EngineKind::Native => Ok(Box::new(NativeEngine)),
            EngineKind::Xla => {
                let handle = handle.ok_or_else(|| {
                    RuntimeError::ArtifactNotFound("XlaEngine needs a device handle".into())
                })?;
                Ok(Box::new(XlaEngine::new(handle, cfg, preferred_batch)?))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::HashKind;
    use crate::util::Xoshiro256StarStar;

    #[test]
    fn native_engine_basics() {
        let cfg = HllConfig::new(12, HashKind::H64).unwrap();
        let eng = NativeEngine;
        let mut s = HllSketch::new(cfg);
        let mut rng = Xoshiro256StarStar::seed_from_u64(1);
        let batch: Vec<u32> = (0..1000).map(|_| rng.next_u32()).collect();
        eng.aggregate(&batch, &mut s).unwrap();
        let est = eng.estimate(&s).unwrap();
        assert!(est.estimate > 0.0);
        assert_eq!(est.zero_registers, s.zero_registers());

        let mut s2 = HllSketch::new(cfg);
        eng.aggregate(&batch[..500], &mut s2).unwrap();
        let mut s3 = HllSketch::new(cfg);
        eng.aggregate(&batch[500..], &mut s3).unwrap();
        eng.merge(&mut s2, &s3).unwrap();
        assert_eq!(s2, s);
    }

    #[test]
    fn engine_kind_parse() {
        assert_eq!(EngineKind::parse("native"), Some(EngineKind::Native));
        assert_eq!(EngineKind::parse("xla"), Some(EngineKind::Xla));
        assert_eq!(EngineKind::parse("cuda"), None);
    }
}
