//! Artifact manifest: the index of AOT-lowered HLO modules produced by
//! `python/compile/aot.py` (`make artifacts`).
//!
//! The manifest is a TSV (dependency-free to parse) with one row per
//! artifact: name, file, kind, p, h_bits, batch, m, outputs.

use std::path::{Path, PathBuf};

use crate::hll::HashKind;

/// What a lowered module computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `(keys i32[batch], regs i32[m]) -> regs i32[m]`
    Aggregate,
    /// `(regs i32[m]) -> f64[3] = (raw, V, estimate)`
    Estimate,
    /// `(a i32[m], b i32[m]) -> i32[m]`
    Merge,
    /// `(keys i32[batch], regs i32[m]) -> (regs i32[m], f64[3])`
    AggregateEstimate,
}

impl ArtifactKind {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "aggregate" => Some(Self::Aggregate),
            "estimate" => Some(Self::Estimate),
            "merge" => Some(Self::Merge),
            "aggregate_estimate" => Some(Self::AggregateEstimate),
            _ => None,
        }
    }
}

/// One manifest row.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: ArtifactKind,
    pub p: u8,
    /// 0 for kind == Merge (hash-agnostic).
    pub h_bits: u32,
    /// 0 for kinds without a key input.
    pub batch: usize,
    pub m: usize,
}

#[derive(Debug)]
pub enum ManifestError {
    NotFound(PathBuf),
    Parse(usize, String),
    Io(std::io::Error),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::NotFound(p) => {
                write!(f, "artifacts manifest not found at {} — run `make artifacts`", p.display())
            }
            ManifestError::Parse(line, what) => {
                write!(f, "manifest parse error at line {line}: {what}")
            }
            ManifestError::Io(e) => write!(f, "io error reading manifest: {e}"),
        }
    }
}

impl std::error::Error for ManifestError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ManifestError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ManifestError {
    fn from(e: std::io::Error) -> Self {
        ManifestError::Io(e)
    }
}

/// Parsed manifest plus the directory it lives in.
#[derive(Debug, Clone)]
pub struct Manifest {
    dir: PathBuf,
    entries: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Default artifacts directory: `$HLL_ARTIFACTS` if set, else
    /// `<repo>/artifacts` (located via the compile-time manifest dir so
    /// tests and examples work from any cwd).
    pub fn default_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("HLL_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn load_default() -> Result<Self, ManifestError> {
        Self::load(&Self::default_dir())
    }

    pub fn load(dir: &Path) -> Result<Self, ManifestError> {
        let path = dir.join("manifest.tsv");
        if !path.exists() {
            return Err(ManifestError::NotFound(path));
        }
        let text = std::fs::read_to_string(&path)?;
        let mut lines = text.lines().enumerate();
        let (_, header) = lines
            .next()
            .ok_or_else(|| ManifestError::Parse(0, "empty manifest".into()))?;
        let cols: Vec<&str> = header.split('\t').collect();
        let idx = |name: &str| -> Result<usize, ManifestError> {
            cols.iter()
                .position(|c| *c == name)
                .ok_or_else(|| ManifestError::Parse(0, format!("missing column {name}")))
        };
        let (ci_name, ci_file, ci_kind, ci_p, ci_h, ci_b, ci_m) = (
            idx("name")?,
            idx("file")?,
            idx("kind")?,
            idx("p")?,
            idx("h_bits")?,
            idx("batch")?,
            idx("m")?,
        );
        let mut entries = Vec::new();
        for (lineno, line) in lines {
            if line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            let get = |i: usize| -> Result<&str, ManifestError> {
                f.get(i)
                    .copied()
                    .ok_or_else(|| ManifestError::Parse(lineno + 1, "short row".into()))
            };
            let parse_num = |s: &str| -> Result<u64, ManifestError> {
                s.parse()
                    .map_err(|_| ManifestError::Parse(lineno + 1, format!("bad number '{s}'")))
            };
            let kind = ArtifactKind::parse(get(ci_kind)?).ok_or_else(|| {
                ManifestError::Parse(lineno + 1, format!("unknown kind '{}'", f[ci_kind]))
            })?;
            let meta = ArtifactMeta {
                name: get(ci_name)?.to_string(),
                file: get(ci_file)?.to_string(),
                kind,
                p: parse_num(get(ci_p)?)? as u8,
                h_bits: parse_num(get(ci_h)?)? as u32,
                batch: parse_num(get(ci_b)?)? as usize,
                m: parse_num(get(ci_m)?)? as usize,
            };
            if meta.m != 1usize << meta.p {
                return Err(ManifestError::Parse(
                    lineno + 1,
                    format!("m={} inconsistent with p={}", meta.m, meta.p),
                ));
            }
            entries.push(meta);
        }
        Ok(Self { dir: dir.to_path_buf(), entries })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn entries(&self) -> &[ArtifactMeta] {
        &self.entries
    }

    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    fn hash_bits(h: HashKind) -> u32 {
        h.bits()
    }

    /// The aggregate artifact for (p, H) with the largest batch ≤ `want`,
    /// falling back to the smallest available batch.
    pub fn find_aggregate(&self, p: u8, h: HashKind, want_batch: usize) -> Option<&ArtifactMeta> {
        let h_bits = Self::hash_bits(h);
        let mut candidates: Vec<&ArtifactMeta> = self
            .entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::Aggregate && e.p == p && e.h_bits == h_bits)
            .collect();
        candidates.sort_by_key(|e| e.batch);
        candidates
            .iter()
            .rev()
            .find(|e| e.batch <= want_batch)
            .copied()
            .or_else(|| candidates.first().copied())
    }

    pub fn find_estimate(&self, p: u8, h: HashKind) -> Option<&ArtifactMeta> {
        let h_bits = Self::hash_bits(h);
        self.entries
            .iter()
            .find(|e| e.kind == ArtifactKind::Estimate && e.p == p && e.h_bits == h_bits)
    }

    pub fn find_merge(&self, p: u8) -> Option<&ArtifactMeta> {
        self.entries.iter().find(|e| e.kind == ArtifactKind::Merge && e.p == p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), body).unwrap();
    }

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hll_manifest_test_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    const HEADER: &str = "name\tfile\tkind\tp\th_bits\tbatch\tm\toutputs\n";

    #[test]
    fn parses_valid_manifest() {
        let d = tmpdir("valid");
        write_manifest(
            &d,
            &format!(
                "{HEADER}agg\ta.hlo.txt\taggregate\t16\t64\t8192\t65536\tregs\n\
                 est\te.hlo.txt\testimate\t16\t64\t0\t65536\tstats\n\
                 mrg\tm.hlo.txt\tmerge\t16\t0\t0\t65536\tregs\n"
            ),
        );
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.entries().len(), 3);
        assert!(m.find_aggregate(16, HashKind::H64, 8192).is_some());
        assert!(m.find_estimate(16, HashKind::H64).is_some());
        assert!(m.find_merge(16).is_some());
        assert!(m.find_aggregate(14, HashKind::H64, 8192).is_none());
    }

    #[test]
    fn batch_selection_prefers_largest_fitting() {
        let d = tmpdir("batch");
        write_manifest(
            &d,
            &format!(
                "{HEADER}a1\ta1.hlo.txt\taggregate\t16\t64\t1024\t65536\tregs\n\
                 a2\ta2.hlo.txt\taggregate\t16\t64\t8192\t65536\tregs\n\
                 a3\ta3.hlo.txt\taggregate\t16\t64\t65536\t65536\tregs\n"
            ),
        );
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.find_aggregate(16, HashKind::H64, 8192).unwrap().batch, 8192);
        assert_eq!(m.find_aggregate(16, HashKind::H64, 100_000).unwrap().batch, 65536);
        assert_eq!(m.find_aggregate(16, HashKind::H64, 9000).unwrap().batch, 8192);
        // Smaller than every artifact: fall back to the smallest.
        assert_eq!(m.find_aggregate(16, HashKind::H64, 10).unwrap().batch, 1024);
    }

    #[test]
    fn rejects_inconsistent_m() {
        let d = tmpdir("bad_m");
        write_manifest(
            &d,
            &format!("{HEADER}agg\ta.hlo.txt\taggregate\t16\t64\t8192\t999\tregs\n"),
        );
        assert!(matches!(Manifest::load(&d), Err(ManifestError::Parse(..))));
    }

    #[test]
    fn rejects_unknown_kind() {
        let d = tmpdir("bad_kind");
        write_manifest(
            &d,
            &format!("{HEADER}x\tx.hlo.txt\tfrobnicate\t16\t64\t0\t65536\tregs\n"),
        );
        assert!(matches!(Manifest::load(&d), Err(ManifestError::Parse(..))));
    }

    #[test]
    fn missing_dir_is_not_found() {
        let d = tmpdir("missing").join("nope");
        assert!(matches!(Manifest::load(&d), Err(ManifestError::NotFound(_))));
    }

    #[test]
    fn real_artifacts_if_built() {
        // When `make artifacts` has run, the real manifest must load and
        // contain the paper configuration.
        let dir = Manifest::default_dir();
        if !dir.join("manifest.tsv").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let agg = m.find_aggregate(16, HashKind::H64, 8192).expect("paper aggregate");
        assert!(m.path_of(agg).exists());
        assert!(m.find_estimate(16, HashKind::H64).is_some());
        assert!(m.find_merge(16).is_some());
    }
}
