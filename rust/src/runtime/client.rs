//! PJRT runtime: loads the HLO-text artifacts and executes them on the
//! CPU PJRT client via the `xla` crate.
//!
//! One [`XlaRuntime`] owns the client plus a compile-once cache of loaded
//! executables keyed by artifact name. All Layer-2 compute the Rust
//! coordinator triggers at runtime goes through here — Python is never
//! involved.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::artifacts::{ArtifactMeta, Manifest, ManifestError};
// The real `xla` crate is unavailable offline; the stub exposes the same
// API and fails cleanly at first device use. Swap this import to link
// the real bindings.
use super::xla_stub as xla;

#[derive(Debug)]
pub enum RuntimeError {
    Xla(xla::Error),
    Manifest(ManifestError),
    ArtifactNotFound(String),
    Shape(String),
    /// A service thread (XLA device or registry query) is no longer
    /// answering — a lifecycle failure, not a data-shape problem.
    ServiceGone(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Xla(e) => write!(f, "xla error: {e}"),
            RuntimeError::Manifest(e) => write!(f, "{e}"),
            RuntimeError::ArtifactNotFound(what) => write!(f, "artifact not found: {what}"),
            RuntimeError::Shape(what) => write!(f, "shape mismatch: {what}"),
            RuntimeError::ServiceGone(what) => write!(f, "service unavailable: {what}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Xla(e) => Some(e),
            RuntimeError::Manifest(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e)
    }
}

impl From<ManifestError> for RuntimeError {
    fn from(e: ManifestError) -> Self {
        RuntimeError::Manifest(e)
    }
}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// The PJRT client + executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl std::fmt::Debug for XlaRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaRuntime")
            .field("platform", &self.client.platform_name())
            .field("artifacts", &self.manifest.dir())
            .finish()
    }
}

impl XlaRuntime {
    /// Create a CPU PJRT client over the default artifacts directory.
    pub fn new() -> Result<Self> {
        Self::with_manifest(Manifest::load_default()?)
    }

    pub fn with_manifest(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        crate::log_info!(
            "runtime",
            "PJRT client up: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.dir().display()
        );
        Ok(Self { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn executable(&self, meta: &ArtifactMeta) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(&meta.name) {
            return Ok(exe.clone());
        }
        let path = self.manifest.path_of(meta);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        crate::log_info!(
            "runtime",
            "compiled {} in {:.1} ms",
            meta.name,
            t0.elapsed().as_secs_f64() * 1e3
        );
        self.cache.lock().unwrap().insert(meta.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact, returning the output literals (tuple outputs
    /// are decomposed; single-array outputs come back as one literal).
    pub fn execute(
        &self,
        meta: &ArtifactMeta,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(meta)?;
        let result = exe.execute::<xla::Literal>(args)?;
        let literal = result[0][0].to_literal_sync()?;
        if literal.shape()?.is_tuple() {
            Ok(literal.to_tuple()?)
        } else {
            Ok(vec![literal])
        }
    }

    /// Chunked aggregation with a device-resident register file: the
    /// registers are uploaded once, threaded through every chunk's
    /// execution as a `PjRtBuffer`, and downloaded once at the end —
    /// the donated-buffer analogue that removes the 512 KiB/chunk
    /// host↔device round trip (EXPERIMENTS.md §Perf).
    pub fn run_aggregate_chunks(
        &self,
        meta: &ArtifactMeta,
        chunks: &[Vec<i32>],
        regs_i32: &[i32],
    ) -> Result<Vec<i32>> {
        if regs_i32.len() != meta.m {
            return Err(RuntimeError::Shape(format!(
                "{} expects {} registers, got {}",
                meta.name,
                meta.m,
                regs_i32.len()
            )));
        }
        let exe = self.executable(meta)?;
        let mut regs_buf = self.client.buffer_from_host_buffer(regs_i32, &[meta.m], None)?;
        for keys in chunks {
            if keys.len() != meta.batch {
                return Err(RuntimeError::Shape(format!(
                    "{} expects batch {}, got {}",
                    meta.name,
                    meta.batch,
                    keys.len()
                )));
            }
            let keys_buf = self.client.buffer_from_host_buffer(keys, &[meta.batch], None)?;
            let mut out = exe.execute_b(&[&keys_buf, &regs_buf])?;
            regs_buf = out
                .get_mut(0)
                .and_then(|v| {
                    if v.is_empty() {
                        None
                    } else {
                        Some(v.remove(0))
                    }
                })
                .ok_or_else(|| RuntimeError::Shape("empty execute_b output".into()))?;
        }
        let literal = regs_buf.to_literal_sync()?;
        Ok(literal.to_vec::<i32>()?)
    }

    /// Helper: run an `aggregate` artifact over i32 keys + i32 registers.
    pub fn run_aggregate(
        &self,
        meta: &ArtifactMeta,
        keys_i32: &[i32],
        regs_i32: &[i32],
    ) -> Result<Vec<i32>> {
        if keys_i32.len() != meta.batch {
            return Err(RuntimeError::Shape(format!(
                "{} expects batch {}, got {}",
                meta.name,
                meta.batch,
                keys_i32.len()
            )));
        }
        if regs_i32.len() != meta.m {
            return Err(RuntimeError::Shape(format!(
                "{} expects {} registers, got {}",
                meta.name,
                meta.m,
                regs_i32.len()
            )));
        }
        let keys = xla::Literal::vec1(keys_i32);
        let regs = xla::Literal::vec1(regs_i32);
        let out = self.execute(meta, &[keys, regs])?;
        let regs_out = out
            .into_iter()
            .next()
            .ok_or_else(|| RuntimeError::Shape("empty output tuple".into()))?;
        Ok(regs_out.to_vec::<i32>()?)
    }

    /// Helper: run an `estimate` artifact. Returns (raw, V, estimate).
    pub fn run_estimate(&self, meta: &ArtifactMeta, regs_i32: &[i32]) -> Result<(f64, f64, f64)> {
        if regs_i32.len() != meta.m {
            return Err(RuntimeError::Shape(format!(
                "{} expects {} registers, got {}",
                meta.name,
                meta.m,
                regs_i32.len()
            )));
        }
        let regs = xla::Literal::vec1(regs_i32);
        let out = self.execute(meta, &[regs])?;
        let stats = out
            .into_iter()
            .next()
            .ok_or_else(|| RuntimeError::Shape("empty output tuple".into()))?
            .to_vec::<f64>()?;
        if stats.len() != 3 {
            return Err(RuntimeError::Shape(format!(
                "estimate returned {} values, expected 3",
                stats.len()
            )));
        }
        Ok((stats[0], stats[1], stats[2]))
    }

    /// Helper: run a `merge` artifact.
    pub fn run_merge(
        &self,
        meta: &ArtifactMeta,
        a_i32: &[i32],
        b_i32: &[i32],
    ) -> Result<Vec<i32>> {
        if a_i32.len() != meta.m || b_i32.len() != meta.m {
            return Err(RuntimeError::Shape(format!(
                "{} expects {} registers",
                meta.name, meta.m
            )));
        }
        let a = xla::Literal::vec1(a_i32);
        let b = xla::Literal::vec1(b_i32);
        let out = self.execute(meta, &[a, b])?;
        let merged = out
            .into_iter()
            .next()
            .ok_or_else(|| RuntimeError::Shape("empty output tuple".into()))?;
        Ok(merged.to_vec::<i32>()?)
    }
}
