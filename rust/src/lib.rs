//! # hll-fpga — HyperLogLog Sketch Acceleration, reproduced in software
//!
//! A reproduction of *"HyperLogLog Sketch Acceleration on FPGA"*
//! (Kulkarni et al., 2020) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (`python/compile/kernels/`) — the Murmur3 hash + rank
//!   hot-spot as Pallas kernels (interpret mode, validated vs `ref.py`).
//! * **Layer 2** (`python/compile/model.py`) — the HLL aggregation and
//!   estimation compute graph in JAX, AOT-lowered to HLO text artifacts.
//! * **Layer 3** (this crate) — the coordinator: a streaming orchestrator
//!   mirroring the paper's multi-pipelined FPGA architecture, plus every
//!   substrate the evaluation needs (FPGA dataflow simulator, PCIe/XDMA
//!   model, 100 Gbit/s TCP network simulator, optimized CPU baseline,
//!   statistical profiling harness) and a PJRT runtime that executes the
//!   Layer-2 artifacts with Python never on the data path. The
//!   multi-tenant [`registry`], its network [`server`] (binary TCP
//!   protocol, snapshot/restore, background sweeper) and the
//!   conflict-free [`replica`] subsystem (primary→follower delta
//!   streaming with cursor resume) turn the library into a serving
//!   system.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every paper table/figure to a module and bench target.

pub mod bench_harness;
pub mod coordinator;
pub mod cpu_baseline;
pub mod fpga;
pub mod hll;
pub mod net;
pub mod obs;
pub mod pcie;
pub mod proptest_lite;
pub mod registry;
pub mod replica;
pub mod repro;
pub mod runtime;
pub mod server;
pub mod stats;
pub mod util;

pub use hll::{ConcurrentHllSketch, HashKind, HllConfig, HllSketch};
pub use registry::{RegistryConfig, SketchRegistry};
pub use replica::{FollowerConfig, FollowerServer, ReplicationConfig};
pub use server::{ServerConfig, SketchClient, SketchServer};
