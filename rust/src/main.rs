//! `hll-fpga` binary: CLI entry point. Subcommand plumbing lives in
//! `cli`; experiment regeneration in `repro`.

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if let Err(e) = hll_fpga::repro::cli::run(&args[1..]) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
