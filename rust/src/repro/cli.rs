//! Hand-rolled CLI (the offline crate set has no `clap`).
//!
//! ```text
//! hll-fpga repro <fig1|table1|table2|table3|fig4a|fig4b|table4|all> [--full] [--trials N] [--mb N]
//! hll-fpga estimate [--n N | --file PATH] [--pipelines K] [--engine native|xla] [--batch B]
//! hll-fpga info
//! ```

use crate::coordinator::{run_stream, CoordinatorConfig};
use crate::cpu_baseline::ScalingModel;
use crate::runtime::{EngineKind, Manifest, XlaService};
use crate::stats::DistinctStream;

/// CLI error: a message, optionally wrapping a source error (the offline
/// crate set has no `anyhow`).
#[derive(Debug)]
pub struct CliError(String);

impl CliError {
    pub fn msg<S: Into<String>>(s: S) -> Self {
        CliError(s.into())
    }
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for CliError {}

impl From<std::io::Error> for CliError {
    fn from(e: std::io::Error) -> Self {
        CliError(e.to_string())
    }
}

impl From<crate::runtime::RuntimeError> for CliError {
    fn from(e: crate::runtime::RuntimeError) -> Self {
        CliError(e.to_string())
    }
}

pub type CliResult<T> = std::result::Result<T, CliError>;

/// Minimal flag parser: positionals + `--key value` + boolean `--key`.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    pub fn parse(raw: &[String]) -> Result<Self, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                // Boolean flag if next token is absent or another flag.
                if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), raw[i + 1].clone());
                    i += 2;
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn bool_flag(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn num_flag<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("bad value for --{name}: {v}")),
        }
    }
}

const USAGE: &str = "\
hll-fpga — HyperLogLog Sketch Acceleration (Kulkarni et al. 2020) reproduction

USAGE:
  hll-fpga repro <target> [--full] [--trials N] [--mb N]
      target: fig1 | table1 | table2 | table3 | fig4a | fig4b | table4 | all
      --full     extend fig1 to ~10^9-scale cardinalities (slow)
      --trials N trials per fig1 point (default 5)
      --mb N     data volume per simulated run (default 64 for fig4a, 8 for table4)
  hll-fpga estimate [--n N | --file PATH] [--pipelines K] [--engine native|xla] [--batch B]
      count distinct 32-bit words from a synthetic stream (--n) or a
      little-endian binary file (--file)
  hll-fpga info
  hll-fpga help
";

pub fn run(raw: &[String]) -> CliResult<()> {
    let args = Args::parse(raw).map_err(CliError::msg)?;
    match args.positional.first().map(|s| s.as_str()) {
        None | Some("help") => {
            print!("{USAGE}");
            Ok(())
        }
        Some("repro") => cmd_repro(&args),
        Some("estimate") => cmd_estimate(&args),
        Some("info") => cmd_info(),
        Some(other) => Err(CliError::msg(format!("unknown command '{other}'\n{USAGE}"))),
    }
}

fn cmd_repro(args: &Args) -> CliResult<()> {
    let target = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| CliError::msg(format!("repro needs a target\n{USAGE}")))?;
    let all = target == "all";
    let mut matched = all;

    if all || target == "table1" {
        matched = true;
        println!("{}", super::tables::table1());
    }
    if all || target == "table2" {
        matched = true;
        println!("{}", super::tables::table2());
    }
    if all || target == "table3" {
        matched = true;
        println!("{}", super::tables::table3());
    }
    if all || target == "fig1" {
        matched = true;
        let opts = super::fig1::Fig1Options {
            full: args.bool_flag("full"),
            trials: args.num_flag("trials", 5usize).map_err(CliError::msg)?,
            max_exp: None,
        };
        let curves = super::fig1::curves(&opts);
        println!("{}", super::fig1::render(&curves));
        for (claim, holds, detail) in super::fig1::check_claims(&curves) {
            println!("  [{}] {claim} ({detail})", if holds { "ok" } else { "MISS" });
        }
    }
    if all || target == "fig4a" {
        matched = true;
        let mb: u64 = args.num_flag("mb", 512u64).map_err(CliError::msg)?;
        let rows = super::fig4::fig4a_rows(mb << 20);
        println!("{}", super::fig4::render_fig4a(&rows));
    }
    if all || target == "fig4b" {
        matched = true;
        let model = ScalingModel::paper_xeon();
        let rows = super::fig4::fig4b_rows(&model);
        println!("{}", super::fig4::render_fig4b(&rows, "paper Xeon E5-2630 v3 model"));
    }
    if all || target == "table4" {
        matched = true;
        let mb: u64 = args.num_flag("mb", 8u64).map_err(CliError::msg)?;
        let rows = super::table4::rows(mb << 20);
        println!("{}", super::table4::render(&rows));
    }
    if !matched {
        return Err(CliError::msg(format!("unknown repro target '{target}'\n{USAGE}")));
    }
    Ok(())
}

fn cmd_estimate(args: &Args) -> CliResult<()> {
    let pipelines: usize = args.num_flag("pipelines", 4usize).map_err(CliError::msg)?;
    let batch: usize = args.num_flag("batch", 8192usize).map_err(CliError::msg)?;
    let engine = match args.flag("engine").unwrap_or("native") {
        "native" => EngineKind::Native,
        "xla" => EngineKind::Xla,
        other => return Err(CliError::msg(format!("unknown engine '{other}' (native|xla)"))),
    };

    let words: Vec<u32> = if let Some(path) = args.flag("file") {
        let bytes = std::fs::read(path)?;
        bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    } else {
        let n: u64 = args.num_flag("n", 1_000_000u64).map_err(CliError::msg)?;
        DistinctStream::new(n, 0xD15C0).collect()
    };

    let cfg = CoordinatorConfig {
        pipelines,
        batch_size: batch,
        engine,
        ..CoordinatorConfig::default()
    };
    let service = if engine == EngineKind::Xla { Some(XlaService::start()?) } else { None };
    let handle = service.as_ref().map(|s| s.handle());
    let summary = run_stream(cfg, handle, &words)?;
    println!("engine:          {:?}", engine);
    println!("pipelines:       {pipelines}");
    println!("words in:        {}", crate::util::fmt::count(summary.metrics.words_in));
    println!("estimate:        {:.1}", summary.estimate.estimate);
    println!("raw estimate:    {:.1}", summary.estimate.raw);
    println!("zero registers:  {}", summary.estimate.zero_registers);
    println!("elapsed:         {}", crate::util::fmt::duration_s(summary.elapsed.as_secs_f64()));
    println!(
        "throughput:      {}",
        crate::util::fmt::gbytes_per_s(summary.throughput_bytes_per_s())
    );
    println!("backpressure:    {} stalls", summary.metrics.backpressure_stalls);
    Ok(())
}

fn cmd_info() -> CliResult<()> {
    println!("hll-fpga — three-layer reproduction of 'HyperLogLog Sketch Acceleration on FPGA'");
    println!("paper config: p=16, 64-bit Murmur3, m=65536, sigma=0.41%");
    match Manifest::load_default() {
        Ok(m) => {
            println!("artifacts ({}):", m.dir().display());
            for e in m.entries() {
                println!(
                    "  {:<44} kind={:?} p={} H={} batch={}",
                    e.name, e.kind, e.p, e.h_bits, e.batch
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    let dev = crate::fpga::Device::XCVU9P;
    let model = crate::fpga::ResourceModel::paper_h64_p16();
    println!(
        "device model: {} (max {} pipelines, {}-bound)",
        dev.name,
        model.max_pipelines(&dev),
        model.binding_resource(&dev)
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_flags_and_positionals() {
        let a = Args::parse(&argv(&["repro", "fig1", "--trials", "3", "--full"])).unwrap();
        assert_eq!(a.positional, vec!["repro", "fig1"]);
        assert_eq!(a.flag("trials"), Some("3"));
        assert!(a.bool_flag("full"));
        assert_eq!(a.num_flag("trials", 5usize).unwrap(), 3);
        assert_eq!(a.num_flag("missing", 7usize).unwrap(), 7);
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&argv(&["frobnicate"])).is_err());
    }

    #[test]
    fn help_runs() {
        assert!(run(&argv(&["help"])).is_ok());
        assert!(run(&[]).is_ok());
    }

    #[test]
    fn table_targets_run() {
        assert!(run(&argv(&["repro", "table1"])).is_ok());
        assert!(run(&argv(&["repro", "table2"])).is_ok());
        assert!(run(&argv(&["repro", "table3"])).is_ok());
    }
}
