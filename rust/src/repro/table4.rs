//! Table IV — sustained NIC throughput vs #pipelines over the simulated
//! 100 Gbit/s TCP link.

use crate::net::{table4_sweep, NicRun};
use crate::util::fmt::TextTable;

pub const PAPER_ROWS: [(usize, f64); 6] =
    [(1, 0.05), (2, 0.12), (4, 4.83), (8, 6.77), (10, 8.94), (16, 9.35)];

pub fn rows(bytes_per_run: u64) -> Vec<(usize, NicRun)> {
    table4_sweep(&[1, 2, 4, 8, 10, 16], bytes_per_run)
}

pub fn render(rows: &[(usize, NicRun)]) -> String {
    let mut out = String::new();
    out.push_str("Table IV — NIC throughput [GByte/s] vs #pipelines (100 Gbit/s TCP)\n\n");
    let mut t = TextTable::new(vec![
        "Pipelines",
        "Throughput (sim)",
        "Paper",
        "drops",
        "RTOs",
        "fast-retx",
    ]);
    for (k, run) in rows {
        let paper = PAPER_ROWS
            .iter()
            .find(|(pk, _)| pk == k)
            .map(|(_, v)| format!("{v:.2}"))
            .unwrap_or_default();
        t.row(vec![
            k.to_string(),
            format!("{:.2}", run.throughput_bytes_per_s() / 1e9),
            paper,
            run.tcp.drops.to_string(),
            run.tcp.timeouts.to_string(),
            run.tcp.fast_retransmits.to_string(),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(&format!(
        "\nComputation-phase drain after stream end: {} (paper: 203 µs, constant).\n",
        crate::util::fmt::duration_s(rows[0].1.drain_seconds)
    ));
    out.push_str(
        "Shape check: collapse at k<=2 (re-transmission cycles), recovery at k=4,\n\
         window-limited plateau approaching the paper's 9.35 GB/s at k=16.\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_includes_all_k() {
        let r = rows(4 << 20);
        let s = render(&r);
        for k in ["1", "2", "4", "8", "10", "16"] {
            assert!(s.contains(k));
        }
        assert!(s.contains("203"));
    }
}
