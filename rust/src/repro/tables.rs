//! Tables I–III: didactic hash table, memory footprint, FPGA resources.

use crate::fpga::{Device, ResourceModel};
use crate::hll::{HashKind, HllConfig};
use crate::util::fmt::TextTable;

/// Table I — the didactic 4-bit hash-value table (Section III).
pub fn table1() -> String {
    let mut out = String::new();
    out.push_str("Table I — 4-bit hash values with leading-zero counts\n\n");
    let mut t = TextTable::new(vec!["hash", "leading zeros", "rank ρ (within 4 bits)"]);
    for v in 0u8..16 {
        let lz = crate::util::bits::leading_zeros_width(v as u64, 4);
        t.row(vec![format!("{v:04b}"), lz.to_string(), (lz + 1).to_string()]);
    }
    out.push_str(&t.render());
    out.push_str(
        "\nP(≥1 leading zero) = 8/16, P(≥2) = 4/16, P(≥3) = 2/16, P(4) = 1/16 —\n\
         observing k leading zeros suggests ≈ 2^k distinct elements.\n",
    );
    out
}

/// Table II — HyperLogLog memory footprint (eq. (3)).
pub fn table2() -> String {
    let mut out = String::new();
    out.push_str("Table II — HyperLogLog memory footprint\n\n");
    let mut t = TextTable::new(vec![
        "p [bits]",
        "H [bits]",
        "register size [bits]",
        "total memory [KiB]",
    ]);
    for p in [14u8, 16] {
        for h in [HashKind::H32, HashKind::H64] {
            let cfg = HllConfig::new(p, h).unwrap();
            t.row(vec![
                p.to_string(),
                h.bits().to_string(),
                cfg.register_bits().to_string(),
                format!("{:.0}", cfg.footprint_kib()),
            ]);
        }
    }
    out.push_str(&t.render());
    out.push_str("\nPaper values: (14,32)→5b/10KiB, (14,64)→6b/12KiB, (16,32)→5b/40KiB, (16,64)→6b/48KiB.\n");
    out
}

/// Table III — resource usage vs #pipelines on the XCVU9P.
pub fn table3() -> String {
    let model = ResourceModel::paper_h64_p16();
    let dev = Device::XCVU9P;
    let mut out = String::new();
    out.push_str("Table III — resource usage of HLL vs #pipelines (HLL64, p=16, XCVU9P)\n\n");
    let mut t = TextTable::new(vec!["Pipelines", "BRAM", "DSP", "LUT", "FF"]);
    for k in [1usize, 2, 4, 8, 10, 16] {
        let u = model.usage(k);
        let pct = u.utilization(&dev);
        t.row(vec![
            k.to_string(),
            format!("{} / {:.2}%", u.bram, pct.bram),
            format!("{} / {:.2}%", u.dsp, pct.dsp),
            format!("{:.1}K / {:.2}%", u.lut as f64 / 1000.0, pct.lut),
            format!("{:.1}K / {:.2}%", u.ff as f64 / 1000.0, pct.ff),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(&format!(
        "\nScaling limit on {}: {} pipelines ({}-bound).\n",
        dev.name,
        model.max_pipelines(&dev),
        model.binding_resource(&dev)
    ));
    out.push_str("Paper values (k=1): BRAM 12/0.55%, DSP 84/1.22%, LUT 4.5K/0.38%, FF 5.5K/0.23%.\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_contains_all_16_rows() {
        let t = table1();
        assert!(t.contains("0000"));
        assert!(t.contains("1111"));
        assert!(t.lines().count() > 18);
    }

    #[test]
    fn table2_matches_paper_numbers() {
        let t = table2();
        for v in ["10", "12", "40", "48"] {
            assert!(t.contains(v), "missing {v} KiB");
        }
    }

    #[test]
    fn table3_matches_paper_dsp_column() {
        let t = table3();
        for v in ["84", "152", "288", "560", "696", "1104"] {
            assert!(t.contains(v), "missing DSP count {v}");
        }
        assert!(t.contains("DSP-bound"));
    }
}
