//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §3 maps each to its module), plus the CLI that drives them.

pub mod cli;
pub mod fig1;
pub mod fig4;
pub mod table4;
pub mod tables;
