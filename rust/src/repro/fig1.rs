//! Fig 1 — HyperLogLog standard error vs cardinality, for
//! (p, H) ∈ {14, 16} × {32, 64}.
//!
//! The paper samples synthetic data sets from [0 : 2^32−1] and plots
//! max / median / min standard error per cardinality. `quick` mode sweeps
//! to 10^7 with few trials (seconds); `full` extends to ~10^9 inputs
//! where the 32-bit hash saturates (the paper's headline message).

use crate::hll::{HashKind, HllConfig};
use crate::stats::{log_spaced_cardinalities, sweep, ErrorCurve};
use crate::util::fmt::TextTable;

pub struct Fig1Options {
    pub full: bool,
    pub trials: usize,
    /// Override the top-of-sweep exponent (default: 7, or 9 with
    /// `full`). Used by `--quick` runs and the smoke bench.
    pub max_exp: Option<u32>,
}

impl Default for Fig1Options {
    fn default() -> Self {
        Self { full: false, trials: 5, max_exp: None }
    }
}

pub fn curves(opts: &Fig1Options) -> Vec<ErrorCurve> {
    let hi_exp = opts.max_exp.unwrap_or(if opts.full { 9 } else { 7 });
    let cardinalities = log_spaced_cardinalities(2, hi_exp, 1);
    let mut out = Vec::new();
    for p in [14u8, 16] {
        for h in [HashKind::H32, HashKind::H64] {
            let cfg = HllConfig::new(p, h).unwrap();
            out.push(sweep(cfg, &cardinalities, opts.trials));
        }
    }
    out
}

pub fn render(curves: &[ErrorCurve]) -> String {
    let mut out = String::new();
    out.push_str("Fig 1 — HLL standard error vs cardinality\n");
    out.push_str("(paper: Fig 1(a) p=14, Fig 1(b) p=16; rel. error in %)\n\n");
    for curve in curves {
        let cfg = curve.config;
        out.push_str(&format!(
            "{} p={} (theoretical σ = {:.2}%)  [LC→HLL transition at {}]\n",
            cfg.hash().label(),
            cfg.p(),
            cfg.standard_error() * 100.0,
            crate::util::fmt::count(crate::stats::transition_cardinality(&cfg)),
        ));
        let mut t = TextTable::new(vec!["cardinality", "min %", "median %", "max %", "rms %"]);
        for pt in &curve.points {
            t.row(vec![
                crate::util::fmt::count(pt.cardinality),
                format!("{:.3}", pt.min * 100.0),
                format!("{:.3}", pt.median * 100.0),
                format!("{:.3}", pt.max * 100.0),
                format!("{:.3}", pt.rms * 100.0),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// Headline checks against the paper's observations; returns a list of
/// (claim, holds, detail).
pub fn check_claims(curves: &[ErrorCurve]) -> Vec<(String, bool, String)> {
    let mut checks = Vec::new();
    for curve in curves {
        let cfg = curve.config;
        // "A 32-bit hash achieves a standard error less than 2% for all
        // data sets of a cardinality below 10^8" (p=16); the 64-bit hash
        // stays near the theoretical σ everywhere.
        if cfg.hash() == HashKind::H64 {
            let bad = curve
                .points
                .iter()
                .filter(|pt| pt.rms > 5.0 * cfg.standard_error().max(0.004))
                .count();
            checks.push((
                format!("{} p={}: rms error stays near σ across range", cfg.hash().label(), cfg.p()),
                bad == 0,
                format!("{bad} outlier points"),
            ));
        }
    }
    checks
}
