//! Fig 4 — throughput scaling: (a) FPGA pipelines against the PCIe
//! bound; (b) CPU thread scaling for both hash widths with the FPGA
//! reference lines.

use crate::cpu_baseline::ScalingModel;
use crate::fpga::theoretical_throughput_bytes_per_s;
use crate::hll::{HashKind, HllConfig};
use crate::pcie::CoProcessorModel;
use crate::util::fmt::TextTable;

/// One Fig 4(a) row.
#[derive(Debug, Clone, Copy)]
pub struct Fig4aRow {
    pub pipelines: usize,
    pub theoretical_gb_s: f64,
    pub measured_gb_s: f64,
}

/// Sweep pipelines through the co-processor model (simulated "measured")
/// against the aggregated pipeline rate ("theoretical").
pub fn fig4a_rows(bytes_per_run: u64) -> Vec<Fig4aRow> {
    let model = CoProcessorModel::default();
    let cfg = HllConfig::PAPER;
    (1..=16)
        .map(|k| {
            let run = model.run(&cfg, k, bytes_per_run);
            Fig4aRow {
                pipelines: k,
                theoretical_gb_s: theoretical_throughput_bytes_per_s(k) / 1e9,
                measured_gb_s: run.throughput_bytes_per_s() / 1e9,
            }
        })
        .collect()
}

pub fn render_fig4a(rows: &[Fig4aRow]) -> String {
    let mut out = String::new();
    out.push_str("Fig 4(a) — FPGA throughput vs #pipelines (GByte/s)\n");
    out.push_str("(PCIe 3.0 x16 XDMA bound: 12.48 GB/s; saturation at 10 pipelines)\n\n");
    let mut t = TextTable::new(vec!["Pipelines", "Theoretical", "Measured (sim)", "Bound"]);
    for r in rows {
        // I/O-bound once the aggregate pipeline rate exceeds the XDMA
        // envelope (the paper's "PCIe bound" regime, k > 9).
        let bound = if r.theoretical_gb_s > 12.48 { "PCIe" } else { "compute" };
        t.row(vec![
            r.pipelines.to_string(),
            format!("{:.2}", r.theoretical_gb_s),
            format!("{:.2}", r.measured_gb_s),
            bound.to_string(),
        ]);
    }
    out.push_str(&t.render());
    out
}

/// One Fig 4(b) row.
#[derive(Debug, Clone, Copy)]
pub struct Fig4bRow {
    pub threads: usize,
    pub cpu32_gb_s: f64,
    pub cpu64_gb_s: f64,
}

/// The CPU curves on the paper's Xeon (modelled; see DESIGN.md §7), plus
/// optional calibration from a measured single-thread rate on this
/// machine.
pub fn fig4b_rows(model: &ScalingModel) -> Vec<Fig4bRow> {
    [1usize, 2, 4, 8, 16, 24, 32, 48, 64]
        .iter()
        .map(|&t| Fig4bRow {
            threads: t,
            cpu32_gb_s: model.rate(HashKind::H32, t) / 1e9,
            cpu64_gb_s: model.rate(HashKind::H64, t) / 1e9,
        })
        .collect()
}

pub fn render_fig4b(rows: &[Fig4bRow], model_label: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Fig 4(b) — CPU throughput vs #threads ({model_label}), GByte/s\n\n"
    ));
    let mut t = TextTable::new(vec!["Threads", "CPU 32-bit hash", "CPU 64-bit hash"]);
    for r in rows {
        t.row(vec![
            r.threads.to_string(),
            format!("{:.2}", r.cpu32_gb_s),
            format!("{:.2}", r.cpu64_gb_s),
        ]);
    }
    out.push_str(&t.render());
    let fpga10 = 12.48;
    let best64 = rows.iter().map(|r| r.cpu64_gb_s).fold(0.0, f64::max);
    let best32 = rows.iter().map(|r| r.cpu32_gb_s).fold(0.0, f64::max);
    out.push_str(&format!(
        "\nFPGA reference lines: 1 pipeline = {:.2} GB/s, 10 pipelines (PCIe-bound) = {fpga10} GB/s.\n",
        theoretical_throughput_bytes_per_s(1) / 1e9
    ));
    out.push_str(&format!(
        "Headline ratios: FPGA/CPU64 = {:.2}x (paper: >1.8x), FPGA/CPU32 = {:.2}x, \
         CPU64/CPU32 = {:.0}% (paper: ~60%).\n",
        fpga10 / best64,
        fpga10 / best32,
        100.0 * best64 / best32,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4a_saturates_at_ten() {
        let rows = fig4a_rows(1 << 30);
        // Linear region: measured ≈ theoretical for k ≤ 9.
        for r in &rows[..9] {
            assert!((r.measured_gb_s - r.theoretical_gb_s).abs() / r.theoretical_gb_s < 0.02);
        }
        // Saturated region: flat at the PCIe envelope.
        let r16 = rows.last().unwrap();
        assert!(r16.measured_gb_s < 12.5 && r16.measured_gb_s > 12.2, "{}", r16.measured_gb_s);
    }

    #[test]
    fn fig4b_paper_ratios() {
        let rows = fig4b_rows(&ScalingModel::paper_xeon());
        let best64 = rows.iter().map(|r| r.cpu64_gb_s).fold(0.0, f64::max);
        let ratio = 12.48 / best64;
        assert!(ratio > 1.7 && ratio < 2.0, "FPGA/CPU64 {ratio}");
    }

    #[test]
    fn renders_contain_key_markers() {
        let a = render_fig4a(&fig4a_rows(1 << 28));
        assert!(a.contains("PCIe"));
        let b = render_fig4b(&fig4b_rows(&ScalingModel::paper_xeon()), "paper Xeon model");
        assert!(b.contains("~60%"));
    }
}
