//! Multiset operations over sketches — the standard production uses of
//! HLL that motivate the paper's intro (distinct users across services,
//! COUNT(DISTINCT ...) over unions): union cardinality (exact via merge)
//! and intersection/Jaccard estimation via inclusion–exclusion.

use super::sketch::{HllSketch, SketchError};

/// |A ∪ B| — exact at sketch level: merge is lossless.
pub fn union_cardinality(a: &HllSketch, b: &HllSketch) -> Result<f64, SketchError> {
    let mut u = a.clone();
    u.merge(b)?;
    Ok(u.estimate())
}

/// |A ∩ B| via inclusion–exclusion: |A| + |B| − |A ∪ B|.
///
/// The estimator's error grows with |A ∪ B| / |A ∩ B| (both operands'
/// σ·|·| errors add); clamped at 0 — small true intersections can come
/// back negative from estimation noise.
pub fn intersection_cardinality(a: &HllSketch, b: &HllSketch) -> Result<f64, SketchError> {
    let union = union_cardinality(a, b)?;
    Ok((a.estimate() + b.estimate() - union).max(0.0))
}

/// Jaccard similarity estimate |A ∩ B| / |A ∪ B| ∈ [0, 1].
pub fn jaccard(a: &HllSketch, b: &HllSketch) -> Result<f64, SketchError> {
    let union = union_cardinality(a, b)?;
    if union <= 0.0 {
        return Ok(0.0);
    }
    Ok((intersection_cardinality(a, b)? / union).clamp(0.0, 1.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::HllConfig;
    use crate::stats::DistinctStream;

    /// Build sketches over [0, n_a) and [offset, offset + n_b) with a
    /// known overlap.
    fn pair(n_a: u64, n_b: u64, overlap: u64) -> (HllSketch, HllSketch) {
        let mut a = HllSketch::new(HllConfig::PAPER);
        let mut b = HllSketch::new(HllConfig::PAPER);
        let values: Vec<u32> = DistinctStream::new(n_a + n_b - overlap, 1).collect();
        for &v in &values[..n_a as usize] {
            a.insert_u32(v);
        }
        for &v in &values[(n_a - overlap) as usize..] {
            b.insert_u32(v);
        }
        (a, b)
    }

    #[test]
    fn union_matches_truth() {
        let (a, b) = pair(100_000, 80_000, 30_000);
        let u = union_cardinality(&a, &b).unwrap();
        let truth = 150_000.0;
        assert!((u - truth).abs() / truth < 0.02, "union {u}");
    }

    #[test]
    fn intersection_recovers_overlap() {
        let (a, b) = pair(200_000, 150_000, 100_000);
        let i = intersection_cardinality(&a, &b).unwrap();
        // Inclusion–exclusion compounds errors; allow 10%.
        assert!((i - 100_000.0).abs() / 100_000.0 < 0.10, "intersection {i}");
    }

    #[test]
    fn disjoint_sets_intersect_near_zero() {
        let (a, b) = pair(100_000, 100_000, 0);
        let i = intersection_cardinality(&a, &b).unwrap();
        assert!(i < 5_000.0, "phantom intersection {i}");
        assert!(jaccard(&a, &b).unwrap() < 0.05);
    }

    #[test]
    fn identical_sets_jaccard_one() {
        let mut a = HllSketch::new(HllConfig::PAPER);
        for v in DistinctStream::new(50_000, 9) {
            a.insert_u32(v);
        }
        let b = a.clone();
        let j = jaccard(&a, &b).unwrap();
        assert!((j - 1.0).abs() < 0.02, "jaccard {j}");
    }

    #[test]
    fn empty_sketches() {
        let a = HllSketch::new(HllConfig::PAPER);
        let b = HllSketch::new(HllConfig::PAPER);
        assert_eq!(union_cardinality(&a, &b).unwrap(), 0.0);
        assert_eq!(intersection_cardinality(&a, &b).unwrap(), 0.0);
        assert_eq!(jaccard(&a, &b).unwrap(), 0.0);
    }

    #[test]
    fn config_mismatch_rejected() {
        let a = HllSketch::new(HllConfig::PAPER);
        let b = HllSketch::new(HllConfig::new(14, crate::hll::HashKind::H64).unwrap());
        assert!(union_cardinality(&a, &b).is_err());
    }
}
