//! Lock-free shared HLL sketch — the software analogue of the paper's
//! multi-pipeline register merge (Fig 3).
//!
//! The hardware runs k aggregation pipelines into private register files
//! and folds them by bucket-wise max. That fold is only correct because
//! register updates are commutative, associative, idempotent maxes — and
//! the same property lets *software* threads share one register file
//! without locks: each register is an [`AtomicU8`] raised by a CAS-max
//! loop. Any interleaving of concurrent inserts yields exactly the
//! register file a serial replay of the same multiset would, so an
//! N-thread ingest is bit-identical to [`HllSketch::insert_batch`] over
//! the concatenated input (asserted by the differential tests and the
//! `registry_scale` bench).
//!
//! Orderings are `Relaxed` throughout: register values are monotone and
//! independent, and readers that need a cross-register-consistent view
//! (estimates after ingest) obtain it from the happens-before edge of
//! joining the writer threads. Mid-ingest [`ConcurrentHllSketch::snapshot`]
//! calls see some valid intermediate multiset's sketch — never a torn
//! register.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::OnceLock;

use super::config::HllConfig;
use super::estimate::{estimate, EstimateBreakdown};
use super::sketch::{HllSketch, SketchError};

/// A dense HLL sketch whose register file may be written by many threads
/// concurrently, lock-free.
///
/// With [`ConcurrentHllSketch::enable_dirty_tracking`] on, a lock-free
/// **dirty bitmap** rides alongside the registers (one bit per
/// register, set whenever a raise lands): the replication layer drains
/// it ([`ConcurrentHllSketch::drain_dirty_registers`]) to ship exactly
/// the global-union registers that moved since the last capture. The
/// bitmap costs `m/8` bytes (8 KiB at the paper's p=16) and one extra
/// RMW per *raise* — and is off by default, so non-replicating users
/// (the same "off = no cost" switch the registry shards use) pay a
/// single relaxed load per raise and no memory.
#[derive(Debug)]
pub struct ConcurrentHllSketch {
    cfg: HllConfig,
    regs: Vec<AtomicU8>,
    /// Bit i set = register i was raised since the last drain.
    /// Allocated by [`ConcurrentHllSketch::enable_dirty_tracking`];
    /// absent = tracking off.
    dirty: OnceLock<Vec<AtomicU64>>,
}

impl ConcurrentHllSketch {
    pub fn new(cfg: HllConfig) -> Self {
        let mut regs = Vec::with_capacity(cfg.m());
        regs.resize_with(cfg.m(), || AtomicU8::new(0));
        Self { cfg, regs, dirty: OnceLock::new() }
    }

    /// Turn on raised-register tracking (idempotent; safe alongside
    /// concurrent inserts). Raises that landed *before* this call are
    /// not tracked — a replication primary enables tracking before any
    /// subscriber connects, so earlier state reaches followers through
    /// their bootstrap full sync, exactly like the shard-level switch.
    pub fn enable_dirty_tracking(&self) {
        self.dirty.get_or_init(|| {
            let words = self.cfg.m().div_ceil(64);
            let mut bits = Vec::with_capacity(words);
            bits.resize_with(words, || AtomicU64::new(0));
            bits
        });
    }

    /// The paper's hardware configuration (p=16, 64-bit hash).
    pub fn paper() -> Self {
        Self::new(HllConfig::PAPER)
    }

    /// Seed from an existing dense sketch's registers.
    pub fn from_sketch(sketch: &HllSketch) -> Self {
        let out = Self::new(*sketch.config());
        for (slot, &r) in out.regs.iter().zip(sketch.registers()) {
            slot.store(r, Ordering::Relaxed);
        }
        out
    }

    #[inline]
    pub fn config(&self) -> &HllConfig {
        &self.cfg
    }

    /// Raise one register to at least `rank` via a CAS-max loop,
    /// returning whether a store landed. The common case (rank does not
    /// beat the current value) is a single relaxed load with no RMW
    /// traffic — important under key skew, where hot buckets saturate
    /// early.
    #[inline]
    fn cas_max(slot: &AtomicU8, rank: u8) -> bool {
        let mut cur = slot.load(Ordering::Relaxed);
        while rank > cur {
            match slot.compare_exchange_weak(cur, rank, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
        false
    }

    /// Record a landed raise in the dirty bitmap (no-op with tracking
    /// off). `Release` pairs with the `Acquire` swap in
    /// [`Self::drain_dirty_registers`]: a drain that observes the bit
    /// is guaranteed to read a register value at least as high as the
    /// raise that set it.
    #[inline]
    fn mark_dirty(&self, idx: usize) {
        if let Some(bits) = self.dirty.get() {
            bits[idx / 64].fetch_or(1u64 << (idx % 64), Ordering::Release);
        }
    }

    /// Raise one register and track the raise in the dirty bitmap —
    /// the one implementation behind every write path.
    #[inline]
    fn raise(&self, idx: usize, rank: u8) {
        if Self::cas_max(&self.regs[idx], rank) {
            self.mark_dirty(idx);
        }
    }

    /// Insert a pre-computed H-bit hash (Algorithm 1 line 9), shared.
    #[inline]
    pub fn insert_hash(&self, hash: u64) {
        let (idx, rank) = self.cfg.split_hash(hash);
        self.raise(idx, rank);
    }

    /// Fold a run of pre-computed hashes into the shared union in one
    /// pass — the global-sketch leg of the registry's batch ingest path.
    /// Each store is still a CAS-max (the union is shared across shard
    /// locks, so stores here cannot drop the atomics), but the
    /// split/compare work runs in a tight loop and the common case — a
    /// register already at or above the incoming rank — takes the
    /// load-only early exit inside `cas_max` without ever writing.
    pub fn insert_hashes(&self, hashes: &[u64]) {
        let w_bits = self.cfg.w_bits();
        let mask = (1u64 << w_bits) - 1;
        for &h in hashes {
            let idx = (h >> w_bits) as usize;
            let rank = crate::util::bits::rho(h & mask, w_bits);
            self.raise(idx, rank);
        }
    }

    /// Raise one register to at least `rank` (CAS-max) — the follower's
    /// global-union apply path for replicated register diffs. Same
    /// monotone semantics as a word insert that hashed to this bucket.
    #[inline]
    pub fn update_register(&self, idx: usize, rank: u8) {
        debug_assert!(rank <= self.cfg.max_rank());
        self.raise(idx, rank);
    }

    /// Insert a 32-bit stream word (the paper's stream element type).
    #[inline]
    pub fn insert_u32(&self, v: u32) {
        self.insert_hash(self.cfg.hash_word(v));
    }

    /// Insert a whole batch. Hashing is phase-split four-wide like the
    /// dense hot path so the hash chains pipeline; the register updates
    /// are CAS-maxes instead of private stores.
    pub fn insert_batch(&self, batch: &[u32]) {
        let mut chunks = batch.chunks_exact(4);
        for chunk in &mut chunks {
            let h0 = self.cfg.hash_word(chunk[0]);
            let h1 = self.cfg.hash_word(chunk[1]);
            let h2 = self.cfg.hash_word(chunk[2]);
            let h3 = self.cfg.hash_word(chunk[3]);
            for h in [h0, h1, h2, h3] {
                self.insert_hash(h);
            }
        }
        for &v in chunks.remainder() {
            self.insert_u32(v);
        }
    }

    /// Bucket-wise max of a plain sketch into this one (Fig 3's fold,
    /// against a live shared register file).
    pub fn merge_sketch(&self, other: &HllSketch) -> Result<(), SketchError> {
        if self.cfg != *other.config() {
            return Err(SketchError::ConfigMismatch(self.cfg, *other.config()));
        }
        for (idx, &r) in other.registers().iter().enumerate() {
            if r > 0 {
                self.raise(idx, r);
            }
        }
        Ok(())
    }

    /// Bucket-wise max of another concurrent sketch into this one.
    pub fn merge_concurrent(&self, other: &ConcurrentHllSketch) -> Result<(), SketchError> {
        if self.cfg != other.cfg {
            return Err(SketchError::ConfigMismatch(self.cfg, other.cfg));
        }
        for (idx, src) in other.regs.iter().enumerate() {
            let r = src.load(Ordering::Relaxed);
            if r > 0 {
                self.raise(idx, r);
            }
        }
        Ok(())
    }

    /// Copy the register file into an owned plain sketch.
    pub fn snapshot(&self) -> HllSketch {
        let regs: Vec<u8> = self.regs.iter().map(|r| r.load(Ordering::Relaxed)).collect();
        HllSketch::from_registers(self.cfg, regs).expect("live registers are in range")
    }

    /// Number of registers still at zero.
    pub fn zero_registers(&self) -> usize {
        self.regs
            .iter()
            .filter(|r| r.load(Ordering::Relaxed) == 0)
            .count()
    }

    /// Cardinality estimate with all Algorithm-1 corrections, over a
    /// point-in-time register snapshot.
    pub fn estimate(&self) -> f64 {
        self.estimate_breakdown().estimate
    }

    /// Full estimate breakdown over a point-in-time register snapshot.
    pub fn estimate_breakdown(&self) -> EstimateBreakdown {
        let regs: Vec<u8> = self.regs.iter().map(|r| r.load(Ordering::Relaxed)).collect();
        estimate(&self.cfg, &regs)
    }

    /// Reset all registers to zero (and the dirty bitmap with them — a
    /// cleared sketch has nothing worth shipping).
    pub fn clear(&self) {
        for r in &self.regs {
            r.store(0, Ordering::Relaxed);
        }
        if let Some(bits) = self.dirty.get() {
            for w in bits {
                w.store(0, Ordering::Relaxed);
            }
        }
    }

    /// Swap the dirty bitmap out and return `(index, current value)`
    /// for every register raised since the last drain, sorted by index
    /// (canonical register-diff order). Values are read *after* the
    /// `Acquire` swap observes the bit, so each is at least the raise
    /// that set it — a raise racing the drain lands either in this
    /// drain (its value already visible) or re-sets the bit for the
    /// next one; under max-merge both are correct. Zero-valued
    /// registers (bits left by a concurrent [`Self::clear`]) are
    /// skipped — a zero never ships.
    pub fn drain_dirty_registers(&self) -> Vec<(u32, u8)> {
        let Some(dirty) = self.dirty.get() else { return Vec::new() };
        let mut out = Vec::new();
        for (w, word) in dirty.iter().enumerate() {
            let mut bits = word.swap(0, Ordering::AcqRel);
            while bits != 0 {
                let bit = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                let idx = w * 64 + bit;
                let val = self.regs[idx].load(Ordering::Relaxed);
                if val > 0 {
                    out.push((idx as u32, val));
                }
            }
        }
        out
    }

    /// Registers currently marked dirty (raised since the last drain),
    /// read non-destructively. 0 with tracking off.
    pub fn dirty_registers(&self) -> usize {
        self.dirty
            .get()
            .map_or(0, |bits| bits.iter().map(|w| w.load(Ordering::Relaxed).count_ones() as usize).sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::config::HashKind;
    use crate::util::Xoshiro256StarStar;

    fn words(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
        (0..n).map(|_| rng.next_u32()).collect()
    }

    #[test]
    fn single_thread_matches_dense() {
        for h in [HashKind::H32, HashKind::H64] {
            let cfg = HllConfig::new(14, h).unwrap();
            let data = words(20_000, 11);
            let shared = ConcurrentHllSketch::new(cfg);
            shared.insert_batch(&data);
            let mut dense = HllSketch::new(cfg);
            dense.insert_batch(&data);
            assert_eq!(shared.snapshot(), dense, "hash={h:?}");
            assert_eq!(shared.estimate(), dense.estimate());
            assert_eq!(shared.zero_registers(), dense.zero_registers());
        }
    }

    #[test]
    fn n_thread_ingest_is_register_identical_to_sequential() {
        let cfg = HllConfig::PAPER;
        let data = words(64_000, 23);
        let mut serial = HllSketch::new(cfg);
        serial.insert_batch(&data);
        for threads in [2usize, 4, 8] {
            let shared = ConcurrentHllSketch::new(cfg);
            let chunk = data.len().div_ceil(threads);
            std::thread::scope(|scope| {
                for slice in data.chunks(chunk) {
                    let shared = &shared;
                    scope.spawn(move || shared.insert_batch(slice));
                }
            });
            assert_eq!(shared.snapshot(), serial, "threads={threads}");
        }
    }

    #[test]
    fn merge_against_live_sketch() {
        let cfg = HllConfig::PAPER;
        let data = words(10_000, 5);
        let (left, right) = data.split_at(4_000);
        let shared = ConcurrentHllSketch::new(cfg);
        shared.insert_batch(left);
        let mut other = HllSketch::new(cfg);
        other.insert_batch(right);
        shared.merge_sketch(&other).unwrap();
        let mut all = HllSketch::new(cfg);
        all.insert_batch(&data);
        assert_eq!(shared.snapshot(), all);
    }

    #[test]
    fn merge_rejects_config_and_seed_mismatch() {
        let a = ConcurrentHllSketch::new(HllConfig::new(14, HashKind::H64).unwrap());
        let b = HllSketch::new(HllConfig::new(16, HashKind::H64).unwrap());
        assert!(matches!(a.merge_sketch(&b), Err(SketchError::ConfigMismatch(..))));
        let seeded = HllSketch::new(HllConfig::new(14, HashKind::H64).unwrap().with_seed(9));
        assert!(a.merge_sketch(&seeded).is_err());
        let c = ConcurrentHllSketch::new(HllConfig::new(12, HashKind::H64).unwrap());
        assert!(a.merge_concurrent(&c).is_err());
    }

    #[test]
    fn from_sketch_and_clear_roundtrip() {
        let mut dense = HllSketch::paper();
        dense.insert_batch(&words(5_000, 3));
        let shared = ConcurrentHllSketch::from_sketch(&dense);
        assert_eq!(shared.snapshot(), dense);
        shared.clear();
        assert_eq!(shared.zero_registers(), dense.config().m());
    }

    #[test]
    fn dirty_bitmap_tracks_exactly_the_raised_registers() {
        let cfg = HllConfig::new(12, HashKind::H64).unwrap();
        // Off by default: raises cost nothing and drain nothing.
        let untracked = ConcurrentHllSketch::new(cfg);
        untracked.insert_batch(&words(500, 3));
        assert_eq!(untracked.dirty_registers(), 0);
        assert!(untracked.drain_dirty_registers().is_empty());

        let shared = ConcurrentHllSketch::new(cfg);
        shared.enable_dirty_tracking();
        assert_eq!(shared.dirty_registers(), 0);
        assert!(shared.drain_dirty_registers().is_empty());

        let data = words(3_000, 17);
        shared.insert_batch(&data);
        let live = shared.snapshot();
        let nonzero = cfg.m() - live.zero_registers();
        assert_eq!(shared.dirty_registers(), nonzero, "every nonzero register was raised once");

        // The drain is sorted, carries current maxima, and applying it
        // to an empty sketch reproduces the register file bit-exactly.
        let drained = shared.drain_dirty_registers();
        assert_eq!(drained.len(), nonzero);
        assert!(drained.windows(2).all(|w| w[0].0 < w[1].0), "must be index-sorted");
        let mut rebuilt = HllSketch::new(cfg);
        rebuilt.apply_register_diff(&drained);
        assert_eq!(rebuilt, live);
        assert_eq!(shared.dirty_registers(), 0, "drain must clear the bitmap");

        // Re-inserting the same words raises nothing: no new dirt.
        shared.insert_batch(&data);
        assert!(shared.drain_dirty_registers().is_empty(), "no-op inserts must not re-dirty");

        // A genuinely new raise dirties exactly that register; merges
        // mark what they raise too.
        shared.update_register(7, cfg.max_rank());
        assert_eq!(shared.drain_dirty_registers(), vec![(7, cfg.max_rank())]);
        let mut other = HllSketch::new(cfg);
        other.update_register(9, 3);
        shared.merge_sketch(&other).unwrap();
        let merged_dirt = shared.drain_dirty_registers();
        // Either the merge raised register 9 (and so dirtied it), or
        // the random stream had already put it at 3 or higher and the
        // merge was correctly a no-op.
        assert!(
            merged_dirt.iter().any(|&(idx, val)| idx == 9 && val >= 3)
                || shared.snapshot().registers()[9] >= 3
        );
    }
}
