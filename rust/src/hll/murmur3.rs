//! Canonical MurmurHash3 (Appleby, SMHasher) — the paper's hash function.
//!
//! Two variants are provided, matching the paper's H ∈ {32, 64} study:
//!
//! * [`murmur3_x86_32`] — the 32-bit variant, used by the paper's
//!   AVX2-vectorized CPU baseline and the H=32 FPGA configuration;
//! * [`murmur3_x64_128`] — the 128-bit x64 variant; the paper's "64-bit
//!   Murmur3 hash" is its low 64 bits ([`murmur3_x64_64`]).
//!
//! The implementations follow the reference C++ (`MurmurHash3.cpp`)
//! exactly and are validated against published test vectors plus the
//! independent JAX implementation in `python/compile/kernels/ref.py`
//! (bit-exact agreement is asserted by an integration test through the
//! PJRT runtime).

use crate::util::bits::{rotl32, rotl64};

const C1_32: u32 = 0xcc9e2d51;
const C2_32: u32 = 0x1b873593;

#[inline(always)]
fn fmix32(mut h: u32) -> u32 {
    h ^= h >> 16;
    h = h.wrapping_mul(0x85ebca6b);
    h ^= h >> 13;
    h = h.wrapping_mul(0xc2b2ae35);
    h ^= h >> 16;
    h
}

/// MurmurHash3_x86_32 over an arbitrary byte slice.
pub fn murmur3_x86_32(data: &[u8], seed: u32) -> u32 {
    let nblocks = data.len() / 4;
    let mut h1 = seed;

    // Body.
    for i in 0..nblocks {
        let mut k1 = u32::from_le_bytes(data[i * 4..i * 4 + 4].try_into().unwrap());
        k1 = k1.wrapping_mul(C1_32);
        k1 = rotl32(k1, 15);
        k1 = k1.wrapping_mul(C2_32);
        h1 ^= k1;
        h1 = rotl32(h1, 13);
        h1 = h1.wrapping_mul(5).wrapping_add(0xe6546b64);
    }

    // Tail.
    let tail = &data[nblocks * 4..];
    let mut k1: u32 = 0;
    if !tail.is_empty() {
        for (i, &b) in tail.iter().enumerate() {
            k1 ^= (b as u32) << (8 * i);
        }
        k1 = k1.wrapping_mul(C1_32);
        k1 = rotl32(k1, 15);
        k1 = k1.wrapping_mul(C2_32);
        h1 ^= k1;
    }

    // Finalization.
    h1 ^= data.len() as u32;
    fmix32(h1)
}

/// MurmurHash3_x86_32 of a single little-endian `u32` key — the hot path
/// for the paper's 32-bit-word data stream. Equivalent to
/// `murmur3_x86_32(&key.to_le_bytes(), seed)` but with the 4-byte body
/// block inlined (no tail).
#[inline(always)]
pub fn murmur3_x86_32_u32(key: u32, seed: u32) -> u32 {
    let mut k1 = key.wrapping_mul(C1_32);
    k1 = rotl32(k1, 15);
    k1 = k1.wrapping_mul(C2_32);
    let mut h1 = seed ^ k1;
    h1 = rotl32(h1, 13);
    h1 = h1.wrapping_mul(5).wrapping_add(0xe6546b64);
    h1 ^= 4; // len
    fmix32(h1)
}

const C1_64: u64 = 0x87c37b91114253d5;
const C2_64: u64 = 0x4cf5aa3d36495958;

#[inline(always)]
fn fmix64(mut k: u64) -> u64 {
    k ^= k >> 33;
    k = k.wrapping_mul(0xff51afd7ed558ccd);
    k ^= k >> 33;
    k = k.wrapping_mul(0xc4ceb9fe1a85ec53);
    k ^= k >> 33;
    k
}

/// MurmurHash3_x64_128 over an arbitrary byte slice. Returns `(h1, h2)`.
pub fn murmur3_x64_128(data: &[u8], seed: u64) -> (u64, u64) {
    let nblocks = data.len() / 16;
    let mut h1 = seed;
    let mut h2 = seed;

    // Body.
    for i in 0..nblocks {
        let base = i * 16;
        let mut k1 = u64::from_le_bytes(data[base..base + 8].try_into().unwrap());
        let mut k2 = u64::from_le_bytes(data[base + 8..base + 16].try_into().unwrap());

        k1 = k1.wrapping_mul(C1_64);
        k1 = rotl64(k1, 31);
        k1 = k1.wrapping_mul(C2_64);
        h1 ^= k1;
        h1 = rotl64(h1, 27);
        h1 = h1.wrapping_add(h2);
        h1 = h1.wrapping_mul(5).wrapping_add(0x52dce729);

        k2 = k2.wrapping_mul(C2_64);
        k2 = rotl64(k2, 33);
        k2 = k2.wrapping_mul(C1_64);
        h2 ^= k2;
        h2 = rotl64(h2, 31);
        h2 = h2.wrapping_add(h1);
        h2 = h2.wrapping_mul(5).wrapping_add(0x38495ab5);
    }

    // Tail.
    let tail = &data[nblocks * 16..];
    let mut k1: u64 = 0;
    let mut k2: u64 = 0;
    if tail.len() > 8 {
        for (i, &b) in tail[8..].iter().enumerate() {
            k2 ^= (b as u64) << (8 * i);
        }
        k2 = k2.wrapping_mul(C2_64);
        k2 = rotl64(k2, 33);
        k2 = k2.wrapping_mul(C1_64);
        h2 ^= k2;
    }
    if !tail.is_empty() {
        for (i, &b) in tail.iter().take(8).enumerate() {
            k1 ^= (b as u64) << (8 * i);
        }
        k1 = k1.wrapping_mul(C1_64);
        k1 = rotl64(k1, 31);
        k1 = k1.wrapping_mul(C2_64);
        h1 ^= k1;
    }

    // Finalization.
    h1 ^= data.len() as u64;
    h2 ^= data.len() as u64;
    h1 = h1.wrapping_add(h2);
    h2 = h2.wrapping_add(h1);
    h1 = fmix64(h1);
    h2 = fmix64(h2);
    h1 = h1.wrapping_add(h2);
    h2 = h2.wrapping_add(h1);
    (h1, h2)
}

/// The paper's "64-bit Murmur3": low 64 bits (h1) of MurmurHash3_x64_128.
#[inline]
pub fn murmur3_x64_64(data: &[u8], seed: u64) -> u64 {
    murmur3_x64_128(data, seed).0
}

/// 64-bit Murmur3 of a single little-endian `u32` key — the hot path for
/// the 64-bit-hash HLL configuration. Tail-only (len 4 < 16), inlined.
#[inline(always)]
pub fn murmur3_x64_64_u32(key: u32, seed: u64) -> u64 {
    let mut k1 = key as u64;
    k1 = k1.wrapping_mul(C1_64);
    k1 = rotl64(k1, 31);
    k1 = k1.wrapping_mul(C2_64);
    let mut h1 = seed ^ k1;
    let mut h2 = seed;
    h1 ^= 4;
    h2 ^= 4;
    h1 = h1.wrapping_add(h2);
    h2 = h2.wrapping_add(h1);
    h1 = fmix64(h1);
    h2 = fmix64(h2);
    h1 = h1.wrapping_add(h2);
    let _ = h2;
    h1
}

#[cfg(test)]
mod tests {
    use super::*;

    // Published MurmurHash3_x86_32 test vectors (Wikipedia / SMHasher).
    #[test]
    fn x86_32_published_vectors() {
        assert_eq!(murmur3_x86_32(b"", 0), 0);
        assert_eq!(murmur3_x86_32(b"", 1), 0x514E28B7);
        assert_eq!(murmur3_x86_32(b"", 0xffffffff), 0x81F16F39);
        assert_eq!(murmur3_x86_32(&[0xff, 0xff, 0xff, 0xff], 0), 0x76293B50);
        assert_eq!(murmur3_x86_32(&[0x21, 0x43, 0x65, 0x87], 0), 0xF55B516B);
        assert_eq!(murmur3_x86_32(&[0x21, 0x43, 0x65, 0x87], 0x5082EDEE), 0x2362F9DE);
        assert_eq!(murmur3_x86_32(&[0x21, 0x43, 0x65], 0), 0x7E4A8634);
        assert_eq!(murmur3_x86_32(&[0x21, 0x43], 0), 0xA0F7B07A);
        assert_eq!(murmur3_x86_32(&[0x21], 0), 0x72661CF4);
        assert_eq!(murmur3_x86_32(&[0, 0, 0, 0], 0), 0x2362F9DE);
        assert_eq!(murmur3_x86_32(&[0, 0, 0], 0), 0x85F0B427);
        assert_eq!(murmur3_x86_32(&[0, 0], 0), 0x30F4C306);
        assert_eq!(murmur3_x86_32(&[0], 0), 0x514E28B7);
    }

    #[test]
    fn x86_32_u32_fast_path_matches_general() {
        for (key, seed) in [
            (0u32, 0u32),
            (1, 0),
            (0xdeadbeef, 0),
            (0x87654321, 0x5082EDEE),
            (u32::MAX, 12345),
        ] {
            assert_eq!(
                murmur3_x86_32_u32(key, seed),
                murmur3_x86_32(&key.to_le_bytes(), seed),
                "key={key:#x} seed={seed:#x}"
            );
        }
    }

    #[test]
    fn x64_128_empty_is_zero() {
        assert_eq!(murmur3_x64_128(b"", 0), (0, 0));
    }

    #[test]
    fn x64_64_u32_fast_path_matches_general() {
        for (key, seed) in [
            (0u32, 0u64),
            (1, 0),
            (0xdeadbeef, 0),
            (0x87654321, 0xabcdef0123456789),
            (u32::MAX, 42),
        ] {
            assert_eq!(
                murmur3_x64_64_u32(key, seed),
                murmur3_x64_64(&key.to_le_bytes(), seed),
                "key={key:#x} seed={seed:#x}"
            );
        }
    }

    #[test]
    fn x64_128_block_and_tail_paths() {
        // Exercise every tail length 0..=15 plus multi-block bodies; the
        // check here is self-consistency of incremental lengths (distinct
        // outputs) — bit-exactness vs the independent JAX implementation
        // is asserted in python/tests and the runtime integration test.
        let data: Vec<u8> = (0u8..64).collect();
        let mut seen = std::collections::HashSet::new();
        for len in 0..=data.len() {
            let h = murmur3_x64_128(&data[..len], 0);
            assert!(seen.insert(h), "collision at len={len}");
        }
    }

    #[test]
    fn seeds_change_output() {
        assert_ne!(murmur3_x64_64_u32(7, 0), murmur3_x64_64_u32(7, 1));
        assert_ne!(murmur3_x86_32_u32(7, 0), murmur3_x86_32_u32(7, 1));
    }

    #[test]
    fn avalanche_quality_rough() {
        // Flipping one input bit should flip ~half the output bits on
        // average (loose 3σ-ish bounds; catches gross implementation bugs).
        let mut total = 0u32;
        let n = 256;
        for i in 0..n {
            let k = 0x9E3779B9u32.wrapping_mul(i);
            let h0 = murmur3_x64_64_u32(k, 0);
            let h1 = murmur3_x64_64_u32(k ^ (1 << (i % 32)), 0);
            total += (h0 ^ h1).count_ones();
        }
        let avg = total as f64 / n as f64;
        assert!((24.0..40.0).contains(&avg), "poor avalanche: avg flipped bits = {avg}");
    }
}
