//! The packed register tier: HyperLogLogLog-style compression
//! (arXiv 2205.11327) of a dense register file into a shared base
//! offset plus 3-bit per-register deltas and a sorted exception list.
//!
//! A register with true value `v` is stored as the 3-bit field
//! `v − base` when `base ≤ v < base + 7`; the field value 7 is an
//! escape marker meaning "look the value up in the exception list".
//! Registers outside the window (including zeros when `base > 0`)
//! become exceptions. Because register values concentrate in a narrow
//! band around log₂(n/m), the window covers almost all of them and the
//! representation costs ≈ 3m/8 bytes instead of m — a ~2.6x density
//! win at realistic exception rates, with *bit-identical* estimates
//! (the round trip through [`PackedHll::to_dense`] is lossless).
//!
//! The packed tier is storage-only: it never appears on the wire.
//! Export, replication and snapshots transcode through the dense
//! format (wire v2) at capture time.

use super::config::HllConfig;
use super::estimate::{
    ertl_estimate_from_histogram, estimate_with, EstimateBreakdown, EstimatorKind,
};
use super::sketch::HllSketch;

/// 3-bit field value reserved as the exception escape marker.
const ESCAPE: u8 = 7;

/// A dense register file compressed as base + 3-bit deltas + exceptions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedHll {
    cfg: HllConfig,
    /// Shared offset `B`: a 3-bit field `d < 7` encodes value `B + d`.
    base: u8,
    /// `m` 3-bit fields packed little-endian, plus one pad byte so every
    /// field read can load two adjacent bytes unconditionally.
    deltas: Vec<u8>,
    /// Out-of-window registers, sorted by index: `(idx << 8) | value`.
    exceptions: Vec<u32>,
}

impl PackedHll {
    /// Bytes of the delta array alone (the size floor of this tier):
    /// ⌈3m/8⌉ + 1 pad byte.
    pub fn base_bytes(cfg: &HllConfig) -> usize {
        (3 * cfg.m()).div_ceil(8) + 1
    }

    /// An all-zero packed sketch (base 0, no exceptions).
    pub fn new(cfg: HllConfig) -> Self {
        Self {
            cfg,
            base: 0,
            deltas: vec![0u8; Self::base_bytes(&cfg)],
            exceptions: Vec::new(),
        }
    }

    pub fn config(&self) -> &HllConfig {
        &self.cfg
    }

    /// The shared offset `B`.
    pub fn base(&self) -> u8 {
        self.base
    }

    pub fn exceptions_len(&self) -> usize {
        self.exceptions.len()
    }

    /// True once the exception list outgrows its budget (m/16 entries);
    /// the owner should [`Self::rebase`] and, if that does not help,
    /// promote to dense.
    pub fn exception_overflow(&self) -> bool {
        self.exceptions.len() > self.cfg.m() / 16
    }

    /// Heap bytes held (capacity-based, matching the accounting of the
    /// sparse and dense tiers).
    pub fn memory_bytes(&self) -> usize {
        self.deltas.capacity() + 4 * self.exceptions.capacity()
    }

    #[inline]
    fn field(&self, idx: usize) -> u8 {
        let off = idx * 3;
        let byte = off >> 3;
        let shift = off & 7;
        let word = u16::from_le_bytes([self.deltas[byte], self.deltas[byte + 1]]);
        ((word >> shift) & 7) as u8
    }

    #[inline]
    fn set_field(&mut self, idx: usize, f: u8) {
        debug_assert!(f <= ESCAPE);
        let off = idx * 3;
        let byte = off >> 3;
        let shift = off & 7;
        let mut word = u16::from_le_bytes([self.deltas[byte], self.deltas[byte + 1]]);
        word = (word & !(7u16 << shift)) | ((f as u16) << shift);
        let le = word.to_le_bytes();
        self.deltas[byte] = le[0];
        self.deltas[byte + 1] = le[1];
    }

    fn exception_value(&self, idx: usize) -> u8 {
        let i = self
            .exceptions
            .binary_search_by_key(&(idx as u32), |e| e >> 8)
            .expect("escape field without exception entry");
        (self.exceptions[i] & 0xFF) as u8
    }

    fn upsert_exception(&mut self, idx: usize, val: u8) {
        let entry = ((idx as u32) << 8) | val as u32;
        match self.exceptions.binary_search_by_key(&(idx as u32), |e| e >> 8) {
            Ok(i) => self.exceptions[i] = entry,
            Err(i) => {
                if self.exceptions.len() == self.exceptions.capacity() {
                    // Grow by 25% instead of Vec's doubling so the
                    // capacity-based memory accounting stays tight.
                    self.exceptions.reserve_exact(self.exceptions.len() / 4 + 8);
                }
                self.exceptions.insert(i, entry);
            }
        }
    }

    fn remove_exception(&mut self, idx: usize) {
        if let Ok(i) = self.exceptions.binary_search_by_key(&(idx as u32), |e| e >> 8) {
            self.exceptions.remove(i);
        }
    }

    /// Current value of register `idx`.
    pub fn read_register(&self, idx: usize) -> u8 {
        let f = self.field(idx);
        if f < ESCAPE {
            self.base + f
        } else {
            self.exception_value(idx)
        }
    }

    fn write_register(&mut self, idx: usize, val: u8) {
        let old = self.field(idx);
        if val >= self.base && val - self.base < ESCAPE {
            self.set_field(idx, val - self.base);
            if old == ESCAPE {
                self.remove_exception(idx);
            }
        } else {
            self.set_field(idx, ESCAPE);
            self.upsert_exception(idx, val);
        }
    }

    /// Bucket-wise max update: raise register `idx` to `rank` if larger.
    /// Returns `true` if the register changed.
    pub fn update_register(&mut self, idx: usize, rank: u8) -> bool {
        debug_assert!(idx < self.cfg.m());
        debug_assert!(rank as u32 <= self.cfg.max_rank() as u32);
        if rank <= self.read_register(idx) {
            return false;
        }
        self.write_register(idx, rank);
        true
    }

    /// Insert a pre-hashed value; returns the raised register index if
    /// the sketch changed (mirrors `HllSketch::insert_hash_changed`).
    pub fn insert_hash_changed(&mut self, hash: u64) -> Option<u32> {
        let (idx, rank) = self.cfg.split_hash(hash);
        if self.update_register(idx, rank) {
            Some(idx as u32)
        } else {
            None
        }
    }

    /// Window base maximizing in-window register coverage (ties prefer
    /// the smaller base so zero registers stay in-window when possible).
    #[allow(clippy::needless_range_loop)]
    fn choose_base(hist: &[u32]) -> u8 {
        let mut best_base = 0usize;
        let mut best_cover = 0u64;
        for b in 0..hist.len() {
            let cover: u64 = hist[b..hist.len().min(b + ESCAPE as usize)]
                .iter()
                .map(|&c| c as u64)
                .sum();
            if cover > best_cover {
                best_cover = cover;
                best_base = b;
            }
        }
        best_base as u8
    }

    /// Compress a dense register file. Lossless: `to_dense` returns a
    /// sketch with identical registers.
    pub fn from_dense(sketch: &HllSketch) -> Self {
        let cfg = *sketch.config();
        let regs = sketch.registers();
        let mut hist = vec![0u32; cfg.max_rank() as usize + 1];
        for &r in regs {
            hist[r as usize] += 1;
        }
        let base = Self::choose_base(&hist);
        let cover: u32 = hist[base as usize..hist.len().min(base as usize + ESCAPE as usize)]
            .iter()
            .sum();
        let mut out = Self {
            cfg,
            base,
            deltas: vec![0u8; Self::base_bytes(&cfg)],
            exceptions: Vec::with_capacity(regs.len() - cover as usize),
        };
        for (idx, &v) in regs.iter().enumerate() {
            if v >= base && v - base < ESCAPE {
                if v != base {
                    out.set_field(idx, v - base);
                }
            } else {
                out.set_field(idx, ESCAPE);
                // Indices ascend, so pushes keep the list sorted.
                out.exceptions.push(((idx as u32) << 8) | v as u32);
            }
        }
        out
    }

    /// Decompress to the dense representation. Lossless.
    pub fn to_dense(&self) -> HllSketch {
        let m = self.cfg.m();
        let mut regs = vec![0u8; m];
        for (idx, r) in regs.iter_mut().enumerate() {
            *r = self.read_register(idx);
        }
        HllSketch::from_registers(self.cfg, regs).expect("packed registers are in range")
    }

    /// Register-value multiplicity histogram (the Ertl sufficient
    /// statistic), computed without densifying.
    pub fn register_histogram(&self) -> Vec<u32> {
        let mut hist = vec![0u32; self.cfg.max_rank() as usize + 1];
        for idx in 0..self.cfg.m() {
            let f = self.field(idx);
            if f < ESCAPE {
                hist[(self.base + f) as usize] += 1;
            }
        }
        for &e in &self.exceptions {
            hist[(e & 0xFF) as usize] += 1;
        }
        hist
    }

    /// Recompute the optimal base and rebuild. Returns `true` if the
    /// base changed (and the exception list was rebuilt around it).
    pub fn rebase(&mut self) -> bool {
        let hist = self.register_histogram();
        let best = Self::choose_base(&hist);
        if best == self.base {
            return false;
        }
        *self = Self::from_dense(&self.to_dense());
        debug_assert_eq!(self.base, best);
        true
    }

    /// Cardinality estimate (default estimator).
    pub fn estimate(&self) -> f64 {
        self.estimate_with(EstimatorKind::default()).estimate
    }

    /// Estimate breakdown with an explicit estimator. The Ertl path runs
    /// directly off the packed histogram; the legacy path densifies.
    pub fn estimate_with(&self, kind: EstimatorKind) -> EstimateBreakdown {
        match kind {
            EstimatorKind::Ertl => {
                let hist = self.register_histogram();
                let est = ertl_estimate_from_histogram(&self.cfg, &hist);
                EstimateBreakdown {
                    raw: est,
                    zero_registers: hist[0] as usize,
                    correction: super::estimate::Correction::ErtlTailCorrected,
                    estimate: est,
                }
            }
            EstimatorKind::Legacy => {
                let dense = self.to_dense();
                estimate_with(&self.cfg, dense.registers(), kind)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::config::HashKind;
    use crate::util::Xoshiro256StarStar;

    fn cfg(p: u8) -> HllConfig {
        HllConfig::new(p, HashKind::H64).unwrap()
    }

    fn random_dense(p: u8, n: usize, seed: u64) -> HllSketch {
        let mut s = HllSketch::new(cfg(p));
        let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
        for _ in 0..n {
            s.insert_u32(rng.next_u32());
        }
        s
    }

    #[test]
    fn round_trip_is_lossless() {
        for &n in &[0usize, 10, 500, 20_000, 200_000] {
            let dense = random_dense(10, n, n as u64 + 1);
            let packed = PackedHll::from_dense(&dense);
            assert_eq!(packed.to_dense().registers(), dense.registers(), "n={n}");
            assert_eq!(packed.estimate(), dense.estimate(), "n={n}");
        }
    }

    #[test]
    fn incremental_inserts_match_dense() {
        let c = cfg(8);
        let mut dense = HllSketch::new(c);
        let mut packed = PackedHll::new(c);
        let mut rng = Xoshiro256StarStar::seed_from_u64(42);
        for i in 0..30_000u64 {
            let h = rng.next_u64();
            let d = dense.insert_hash_changed(h);
            let p = packed.insert_hash_changed(h);
            assert_eq!(d, p, "changed-register reports diverged at insert {i}");
            if i % 5_000 == 0 {
                assert_eq!(packed.to_dense().registers(), dense.registers());
            }
        }
        assert_eq!(packed.to_dense().registers(), dense.registers());
        assert_eq!(packed.estimate(), dense.estimate());
    }

    #[test]
    fn reads_and_updates_cover_window_and_exceptions() {
        let c = cfg(8);
        let mut p = PackedHll::new(c);
        assert_eq!(p.read_register(5), 0);
        // In-window raise.
        assert!(p.update_register(5, 3));
        assert_eq!(p.read_register(5), 3);
        // Max semantics: lower rank is a no-op.
        assert!(!p.update_register(5, 2));
        assert_eq!(p.read_register(5), 3);
        // Beyond the window (base 0, escape at 7) → exception.
        assert!(p.update_register(5, 9));
        assert_eq!(p.read_register(5), 9);
        assert_eq!(p.exceptions_len(), 1);
        // Raising an existing exception updates it in place.
        assert!(p.update_register(5, 12));
        assert_eq!(p.read_register(5), 12);
        assert_eq!(p.exceptions_len(), 1);
        // Other registers are untouched.
        assert_eq!(p.read_register(4), 0);
        assert_eq!(p.read_register(6), 0);
    }

    #[test]
    fn below_base_exceptions_return_to_window_when_raised() {
        // A dense file concentrated at high values gets base > 0; its
        // zero registers become exceptions, which must disappear again
        // once raised into the window.
        let c = cfg(6);
        let mut regs = vec![9u8; c.m()];
        regs[3] = 0;
        let dense = HllSketch::from_registers(c, regs).unwrap();
        let mut p = PackedHll::from_dense(&dense);
        assert!(
            (3..=9).contains(&p.base()),
            "base should sit near the value mass, got {}",
            p.base()
        );
        assert_eq!(p.read_register(3), 0);
        assert_eq!(p.exceptions_len(), 1);
        assert!(p.update_register(3, p.base() + 2));
        assert_eq!(p.read_register(3), p.base() + 2);
        assert_eq!(p.exceptions_len(), 0, "raised exception must leave the list");
        assert_eq!(p.to_dense().registers()[3], p.base() + 2);
    }

    #[test]
    fn rebase_shrinks_exception_list_and_preserves_registers() {
        // Grow from empty (base 0) to a register file centered at 8..14:
        // nearly everything becomes an exception until rebase moves the
        // window up.
        let c = cfg(8);
        let mut p = PackedHll::new(c);
        let mut rng = Xoshiro256StarStar::seed_from_u64(7);
        for idx in 0..c.m() {
            p.update_register(idx, 8 + (rng.next_u32() % 6) as u8);
        }
        let before = p.to_dense();
        assert!(p.exception_overflow());
        assert!(p.rebase());
        assert!((7..=8).contains(&p.base()), "window must move up, base {}", p.base());
        assert_eq!(p.exceptions_len(), 0);
        assert!(!p.exception_overflow());
        assert_eq!(p.to_dense().registers(), before.registers());
    }

    #[test]
    fn histogram_matches_dense_histogram() {
        let dense = random_dense(9, 3_000, 13);
        let packed = PackedHll::from_dense(&dense);
        let want = crate::hll::estimate::register_histogram(dense.config(), dense.registers());
        assert_eq!(packed.register_histogram(), want);
        assert_eq!(
            packed.estimate_with(EstimatorKind::Legacy),
            dense.estimate_breakdown_with(EstimatorKind::Legacy)
        );
    }

    #[test]
    fn memory_stays_near_the_three_bit_floor() {
        let c = cfg(12);
        let dense = random_dense(12, 800, 3);
        let packed = PackedHll::from_dense(&dense);
        let floor = PackedHll::base_bytes(&c);
        assert!(packed.memory_bytes() >= floor);
        assert!(
            packed.memory_bytes() < floor + c.m() / 16,
            "packed {} bytes vs floor {}",
            packed.memory_bytes(),
            floor
        );
        // Far below the dense tier's m bytes.
        assert!(packed.memory_bytes() * 2 < c.m());
    }

    #[test]
    fn bimodal_files_pack_without_loss_even_when_overflowing() {
        // Pathological: half zeros, half 12s. No 7-wide window covers
        // both modes, so half the registers are exceptions — the round
        // trip must still be exact (the owner promotes to dense).
        let c = cfg(6);
        let mut regs = vec![0u8; c.m()];
        for (i, r) in regs.iter_mut().enumerate() {
            if i % 2 == 0 {
                *r = 12;
            }
        }
        let dense = HllSketch::from_registers(c, regs).unwrap();
        let p = PackedHll::from_dense(&dense);
        assert!(p.exception_overflow());
        assert_eq!(p.to_dense().registers(), dense.registers());
    }
}
