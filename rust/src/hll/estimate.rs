//! Algorithm 1 Phase 4 — the computation phase: raw harmonic-mean
//! estimate plus the small/intermediate/large-range corrections.
//!
//! This mirrors the hardware's "Harmonic Mean" + "Correction" modules
//! (Section V-A-6/7). Like the hardware, the power sum Σ 2^−M[j] is exact:
//! each addend is a single bit in a wide fixed-point accumulator; we use
//! an integer accumulator scaled by 2^max_rank, which is exact for every
//! p/H combination the library admits (m · 2^max_rank < 2^128 does not
//! hold for all, so a u128 fast path with f64 fallback is used — for the
//! paper's p=16/H=64 the fast path applies).

use super::config::HllConfig;

/// Which branch of Algorithm 1 produced the final estimate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Correction {
    /// Line 15: E ≤ 5/2·m and V ≠ 0 → LinearCounting.
    SmallRangeLinearCounting,
    /// Line 17 / 20: no correction applied.
    None,
    /// Line 22: E > 2^32/30 with a 32-bit hash.
    LargeRange,
}

/// Full decomposition of one estimate computation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EstimateBreakdown {
    /// Raw estimate E = α_m · m² / Σ 2^−M[j] (line 11).
    pub raw: f64,
    /// Number of zero registers V (line 13).
    pub zero_registers: usize,
    /// Which correction branch fired.
    pub correction: Correction,
    /// Final estimate E* (line 15/17/20/22).
    pub estimate: f64,
}

/// LinearCounting estimate m·ln(m/V) (Algorithm 1 lines 24–25).
#[inline]
pub fn linear_counting(m: usize, v: usize) -> f64 {
    debug_assert!(v > 0 && v <= m);
    let m = m as f64;
    m * (m / v as f64).ln()
}

/// Exact power sum Σ_j 2^−M[j] and zero count V over a register file.
///
/// Returns the sum as f64 (exact: it is a dyadic rational with ≤ max_rank
/// fractional bits accumulated in an integer when possible).
pub fn power_sum(cfg: &HllConfig, regs: &[u8]) -> (f64, usize) {
    let max_rank = cfg.max_rank() as u32;
    let mut zeros = 0usize;
    if max_rank <= 63 && (regs.len() as u128) << max_rank <= u128::MAX >> 1 {
        // Exact integer accumulation scaled by 2^max_rank — the software
        // analogue of the hardware's wide fixed-point accumulator.
        let mut acc: u128 = 0;
        for &r in regs {
            if r == 0 {
                zeros += 1;
            }
            debug_assert!(r as u32 <= max_rank);
            acc += 1u128 << (max_rank - r as u32);
        }
        (acc as f64 / (1u128 << max_rank) as f64, zeros)
    } else {
        let mut acc = 0.0f64;
        for &r in regs {
            if r == 0 {
                zeros += 1;
            }
            acc += (-(r as f64)).exp2();
        }
        (acc, zeros)
    }
}

/// Algorithm 1, computation phase, over a raw register file.
pub fn estimate(cfg: &HllConfig, regs: &[u8]) -> EstimateBreakdown {
    debug_assert_eq!(regs.len(), cfg.m());
    let m = cfg.m();
    let (sum, zeros) = power_sum(cfg, regs);
    let raw = cfg.alpha() * (m as f64) * (m as f64) / sum;

    let (correction, est) = if raw <= cfg.small_range_threshold() {
        if zeros != 0 {
            (Correction::SmallRangeLinearCounting, linear_counting(m, zeros))
        } else {
            (Correction::None, raw)
        }
    } else if let Some(thr) = cfg.large_range_threshold() {
        if raw <= thr {
            (Correction::None, raw)
        } else {
            // Line 22. For pathological register files the raw estimate
            // can reach/exceed 2^32, where the correction's log argument
            // would be ≤ 0; saturate instead of returning NaN (the sketch
            // is beyond what a 32-bit hash can distinguish at that point).
            let two32 = (1u64 << 32) as f64;
            let ratio = (1.0 - raw / two32).max(f64::MIN_POSITIVE);
            (Correction::LargeRange, -two32 * ratio.ln())
        }
    } else {
        (Correction::None, raw)
    };

    EstimateBreakdown { raw, zero_registers: zeros, correction, estimate: est }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::config::HashKind;
    use crate::hll::sketch::HllSketch;
    use crate::util::Xoshiro256StarStar;

    fn cfg(p: u8, h: HashKind) -> HllConfig {
        HllConfig::new(p, h).unwrap()
    }

    #[test]
    fn empty_sketch_estimates_zero() {
        let c = cfg(16, HashKind::H64);
        let b = estimate(&c, &vec![0; c.m()]);
        // All registers zero → LinearCounting(m, m) = m·ln(1) = 0.
        assert_eq!(b.correction, Correction::SmallRangeLinearCounting);
        assert_eq!(b.estimate, 0.0);
        assert_eq!(b.zero_registers, c.m());
    }

    #[test]
    fn power_sum_exact_small_case() {
        let c = cfg(4, HashKind::H32); // m=16, max_rank=29
        let mut regs = vec![0u8; 16];
        regs[0] = 1;
        regs[1] = 2;
        let (s, z) = power_sum(&c, &regs);
        assert_eq!(z, 14);
        assert_eq!(s, 14.0 + 0.5 + 0.25);
    }

    #[test]
    fn small_range_uses_linear_counting() {
        let mut s = HllSketch::new(cfg(12, HashKind::H64));
        for v in 0..100u32 {
            s.insert_u32(v);
        }
        let b = s.estimate_breakdown();
        assert_eq!(b.correction, Correction::SmallRangeLinearCounting);
        // LinearCounting is very accurate here.
        assert!((b.estimate - 100.0).abs() / 100.0 < 0.05, "est {}", b.estimate);
    }

    #[test]
    fn intermediate_range_no_correction() {
        let mut s = HllSketch::new(cfg(12, HashKind::H64));
        let mut rng = Xoshiro256StarStar::seed_from_u64(5);
        for _ in 0..200_000 {
            s.insert_u32(rng.next_u32());
        }
        let b = s.estimate_breakdown();
        assert_eq!(b.correction, Correction::None);
    }

    #[test]
    fn linear_counting_formula() {
        assert_eq!(linear_counting(16, 16), 0.0);
        let lc = linear_counting(1 << 16, 1 << 15);
        assert!((lc - 65536.0 * 2.0f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn large_range_correction_fires_only_for_h32() {
        // Force a huge raw estimate by maxing registers.
        let c32 = cfg(14, HashKind::H32);
        let regs = vec![c32.max_rank(); c32.m()];
        let b = estimate(&c32, &regs);
        assert_eq!(b.correction, Correction::LargeRange);
        assert!(b.estimate.is_finite() && b.estimate > 0.0, "saturated, not NaN");

        let c64 = cfg(14, HashKind::H64);
        let regs = vec![20u8; c64.m()];
        let b = estimate(&c64, &regs);
        assert_eq!(b.correction, Correction::None, "64-bit hash never large-range corrects");
    }

    #[test]
    fn estimate_monotone_under_register_increase() {
        // Raising any register can only increase the raw estimate.
        let c = cfg(8, HashKind::H64);
        let mut regs = vec![1u8; c.m()];
        let e1 = estimate(&c, &regs).raw;
        regs[17] = 9;
        let e2 = estimate(&c, &regs).raw;
        assert!(e2 > e1);
    }

    #[test]
    fn breakdown_consistency() {
        let mut s = HllSketch::paper();
        let mut rng = Xoshiro256StarStar::seed_from_u64(8);
        for _ in 0..500_000 {
            s.insert_u32(rng.next_u32());
        }
        let b = s.estimate_breakdown();
        assert_eq!(b.zero_registers, s.zero_registers());
        assert_eq!(b.estimate, s.estimate());
        assert!(b.raw > 0.0);
    }
}
