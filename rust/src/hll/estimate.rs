//! The computation phase: cardinality estimation over a register file.
//!
//! Two estimators are provided behind [`EstimatorKind`]:
//!
//! * [`EstimatorKind::Ertl`] (the default) — Ertl's improved estimator
//!   (arXiv 1702.01284, Algorithm 6). The raw harmonic mean is computed
//!   from the register-value *histogram* with the σ/τ tail corrections
//!   folded in, which removes the small/large-range branches and the
//!   empirical bias constants of the original algorithm. Because it
//!   depends only on the histogram, every storage tier (sparse, packed,
//!   dense) produces bit-identical estimates without densifying.
//! * [`EstimatorKind::Legacy`] — Algorithm 1 Phase 4 as in the paper:
//!   raw estimate plus the small/intermediate/large-range corrections.
//!   This mirrors the hardware's "Harmonic Mean" + "Correction" modules
//!   (Section V-A-6/7) and the JAX/Pallas estimate kernel, and is kept
//!   for differential tests and cross-language parity.
//!
//! Like the hardware, the legacy power sum Σ 2^−M[j] is exact: each
//! addend is a single bit in a wide fixed-point accumulator; we use an
//! integer accumulator scaled by 2^max_rank, which is exact for every
//! p/H combination the library admits (m · 2^max_rank < 2^128 does not
//! hold for all, so a u128 fast path with f64 fallback is used — for the
//! paper's p=16/H=64 the fast path applies).

use super::config::HllConfig;

/// Which estimator computes the final cardinality from the registers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EstimatorKind {
    /// Ertl's improved estimator — branch-free, histogram-based,
    /// tail-corrected. The default.
    #[default]
    Ertl,
    /// The paper's Algorithm 1 range-split estimator (LinearCounting /
    /// raw / large-range branches). Matches the Pallas estimate kernel.
    Legacy,
}

impl EstimatorKind {
    /// Stable single-byte encoding for the wire (`Stats` reply).
    pub fn as_wire_byte(self) -> u8 {
        match self {
            EstimatorKind::Ertl => 0,
            EstimatorKind::Legacy => 1,
        }
    }

    /// Inverse of [`Self::as_wire_byte`].
    pub fn from_wire_byte(b: u8) -> Option<Self> {
        match b {
            0 => Some(EstimatorKind::Ertl),
            1 => Some(EstimatorKind::Legacy),
            _ => None,
        }
    }
}

/// Which branch of the estimator produced the final estimate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Correction {
    /// Legacy line 15: E ≤ 5/2·m and V ≠ 0 → LinearCounting.
    SmallRangeLinearCounting,
    /// Legacy line 17 / 20: no correction applied.
    None,
    /// Legacy line 22: E > 2^32/30 with a 32-bit hash.
    LargeRange,
    /// Ertl's estimator: σ/τ tail corrections folded into the harmonic
    /// mean — there is no separate branch to report.
    ErtlTailCorrected,
}

/// Full decomposition of one estimate computation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EstimateBreakdown {
    /// Raw estimate before any range correction. For the Ertl estimator
    /// the tail corrections are part of the harmonic mean itself, so
    /// `raw == estimate`.
    pub raw: f64,
    /// Number of zero registers V.
    pub zero_registers: usize,
    /// Which correction branch fired.
    pub correction: Correction,
    /// Final estimate E*.
    pub estimate: f64,
}

/// LinearCounting estimate m·ln(m/V) (Algorithm 1 lines 24–25).
#[inline]
pub fn linear_counting(m: usize, v: usize) -> f64 {
    debug_assert!(v > 0 && v <= m);
    let m = m as f64;
    m * (m / v as f64).ln()
}

/// Exact power sum Σ_j 2^−M[j] and zero count V over a register file.
///
/// Returns the sum as f64 (exact: it is a dyadic rational with ≤ max_rank
/// fractional bits accumulated in an integer when possible).
pub fn power_sum(cfg: &HllConfig, regs: &[u8]) -> (f64, usize) {
    let max_rank = cfg.max_rank() as u32;
    let mut zeros = 0usize;
    if max_rank <= 63 && (regs.len() as u128) << max_rank <= u128::MAX >> 1 {
        // Exact integer accumulation scaled by 2^max_rank — the software
        // analogue of the hardware's wide fixed-point accumulator.
        let mut acc: u128 = 0;
        for &r in regs {
            if r == 0 {
                zeros += 1;
            }
            debug_assert!(r as u32 <= max_rank);
            acc += 1u128 << (max_rank - r as u32);
        }
        (acc as f64 / (1u128 << max_rank) as f64, zeros)
    } else {
        let mut acc = 0.0f64;
        for &r in regs {
            if r == 0 {
                zeros += 1;
            }
            acc += (-(r as f64)).exp2();
        }
        (acc, zeros)
    }
}

/// Register-value multiplicity histogram `C[k] = #{j : M[j] = k}` for
/// `k ∈ 0..=max_rank`. This is the sufficient statistic for Ertl's
/// estimator; sparse and packed tiers build it without densifying.
pub fn register_histogram(cfg: &HllConfig, regs: &[u8]) -> Vec<u32> {
    let mut hist = vec![0u32; cfg.max_rank() as usize + 1];
    for &r in regs {
        hist[r as usize] += 1;
    }
    hist
}

/// α∞ = 1/(2·ln 2) — the bias constant of Ertl's estimator (no
/// per-m empirical constants needed).
const ALPHA_INF: f64 = 0.5 / std::f64::consts::LN_2;

/// Ertl's σ(x) = x + Σ_{k≥1} x^(2^k) · 2^(k−1) (Algorithm 3): the
/// zero-register tail correction. σ(1) = +∞.
fn ertl_sigma(x: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&x));
    if x == 1.0 {
        return f64::INFINITY;
    }
    let mut x = x;
    let mut y = 1.0f64;
    let mut z = x;
    loop {
        x *= x;
        let z_prev = z;
        z += x * y;
        y += y;
        if z == z_prev {
            return z;
        }
    }
}

/// Ertl's τ(x) = (1 − x − Σ_{k≥1} (1 − x^(2^−k))² · 2^−k) / 3
/// (Algorithm 4): the saturated-register tail correction.
fn ertl_tau(x: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&x));
    if x == 0.0 || x == 1.0 {
        return 0.0;
    }
    let mut x = x;
    let mut y = 1.0f64;
    let mut z = 1.0 - x;
    loop {
        x = x.sqrt();
        let z_prev = z;
        y *= 0.5;
        let d = 1.0 - x;
        z -= d * d * y;
        if z == z_prev {
            return z / 3.0;
        }
    }
}

/// Ertl's improved estimator (Algorithm 6) over a register-value
/// histogram `C[0..=max_rank]` (see [`register_histogram`]).
///
/// Register values live in `0..=q+1` with `q + 1 = max_rank`; the
/// formula is `E = α∞·m² / (m·τ(1−C[q+1]/m) + Σ C[k]/2^(q−k) + m·σ(C[0]/m))`
/// evaluated with the numerically stable halving recurrence.
pub fn ertl_estimate_from_histogram(cfg: &HllConfig, hist: &[u32]) -> f64 {
    let m_usize = cfg.m();
    let q = cfg.max_rank() as usize - 1;
    debug_assert_eq!(hist.len(), q + 2);
    debug_assert_eq!(hist.iter().map(|&c| c as usize).sum::<usize>(), m_usize);
    if hist[0] as usize == m_usize {
        // Empty sketch: σ(1) diverges; the true count is exactly 0.
        return 0.0;
    }
    let m = m_usize as f64;
    let mut z = m * ertl_tau((m - hist[q + 1] as f64) / m);
    for k in (1..=q).rev() {
        z = 0.5 * (z + hist[k] as f64);
    }
    z += m * ertl_sigma(hist[0] as f64 / m);
    if z > 0.0 {
        ALPHA_INF * m * m / z
    } else {
        // Every register saturated: the sketch carries no information
        // beyond "astronomically large".
        f64::INFINITY
    }
}

fn ertl_estimate(cfg: &HllConfig, regs: &[u8]) -> EstimateBreakdown {
    let hist = register_histogram(cfg, regs);
    let est = ertl_estimate_from_histogram(cfg, &hist);
    EstimateBreakdown {
        raw: est,
        zero_registers: hist[0] as usize,
        correction: Correction::ErtlTailCorrected,
        estimate: est,
    }
}

/// Algorithm 1, computation phase, over a raw register file.
fn legacy_estimate(cfg: &HllConfig, regs: &[u8]) -> EstimateBreakdown {
    let m = cfg.m();
    let (sum, zeros) = power_sum(cfg, regs);
    let raw = cfg.alpha() * (m as f64) * (m as f64) / sum;

    let (correction, est) = if raw <= cfg.small_range_threshold() {
        if zeros != 0 {
            (Correction::SmallRangeLinearCounting, linear_counting(m, zeros))
        } else {
            (Correction::None, raw)
        }
    } else if let Some(thr) = cfg.large_range_threshold() {
        if raw <= thr {
            (Correction::None, raw)
        } else {
            // Line 22. For pathological register files the raw estimate
            // can reach/exceed 2^32, where the correction's log argument
            // would be ≤ 0; saturate instead of returning NaN (the sketch
            // is beyond what a 32-bit hash can distinguish at that point).
            let two32 = (1u64 << 32) as f64;
            let ratio = (1.0 - raw / two32).max(f64::MIN_POSITIVE);
            (Correction::LargeRange, -two32 * ratio.ln())
        }
    } else {
        (Correction::None, raw)
    };

    EstimateBreakdown { raw, zero_registers: zeros, correction, estimate: est }
}

/// Computation phase with an explicit estimator selection.
pub fn estimate_with(cfg: &HllConfig, regs: &[u8], kind: EstimatorKind) -> EstimateBreakdown {
    debug_assert_eq!(regs.len(), cfg.m());
    match kind {
        EstimatorKind::Ertl => ertl_estimate(cfg, regs),
        EstimatorKind::Legacy => legacy_estimate(cfg, regs),
    }
}

/// Computation phase with the default estimator ([`EstimatorKind::Ertl`]).
pub fn estimate(cfg: &HllConfig, regs: &[u8]) -> EstimateBreakdown {
    estimate_with(cfg, regs, EstimatorKind::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::config::HashKind;
    use crate::hll::sketch::HllSketch;
    use crate::util::Xoshiro256StarStar;

    fn cfg(p: u8, h: HashKind) -> HllConfig {
        HllConfig::new(p, h).unwrap()
    }

    #[test]
    fn empty_sketch_estimates_zero_under_both_estimators() {
        let c = cfg(16, HashKind::H64);
        let regs = vec![0; c.m()];
        let b = estimate_with(&c, &regs, EstimatorKind::Ertl);
        assert_eq!(b.estimate, 0.0);
        assert_eq!(b.zero_registers, c.m());
        assert_eq!(b.correction, Correction::ErtlTailCorrected);
        // Legacy: all registers zero → LinearCounting(m, m) = m·ln(1) = 0.
        let b = estimate_with(&c, &regs, EstimatorKind::Legacy);
        assert_eq!(b.correction, Correction::SmallRangeLinearCounting);
        assert_eq!(b.estimate, 0.0);
        assert_eq!(b.zero_registers, c.m());
    }

    #[test]
    fn power_sum_exact_small_case() {
        let c = cfg(4, HashKind::H32); // m=16, max_rank=29
        let mut regs = vec![0u8; 16];
        regs[0] = 1;
        regs[1] = 2;
        let (s, z) = power_sum(&c, &regs);
        assert_eq!(z, 14);
        assert_eq!(s, 14.0 + 0.5 + 0.25);
    }

    #[test]
    fn register_histogram_counts_all_values() {
        let c = cfg(4, HashKind::H64); // m=16, max_rank=61
        let mut regs = vec![0u8; 16];
        regs[0] = 1;
        regs[1] = 1;
        regs[2] = 61;
        let hist = register_histogram(&c, &regs);
        assert_eq!(hist.len(), 62);
        assert_eq!(hist[0], 13);
        assert_eq!(hist[1], 2);
        assert_eq!(hist[61], 1);
        assert_eq!(hist.iter().sum::<u32>(), 16);
    }

    #[test]
    fn small_range_uses_linear_counting() {
        let mut s = HllSketch::new(cfg(12, HashKind::H64));
        for v in 0..100u32 {
            s.insert_u32(v);
        }
        let b = estimate_with(s.config(), s.registers(), EstimatorKind::Legacy);
        assert_eq!(b.correction, Correction::SmallRangeLinearCounting);
        // LinearCounting is very accurate here.
        assert!((b.estimate - 100.0).abs() / 100.0 < 0.05, "est {}", b.estimate);
        // Ertl tracks LinearCounting closely in this regime.
        let e = s.estimate();
        assert!((e - b.estimate).abs() / b.estimate < 0.01, "ertl {e} vs lc {}", b.estimate);
    }

    #[test]
    fn intermediate_range_no_correction() {
        let mut s = HllSketch::new(cfg(12, HashKind::H64));
        let mut rng = Xoshiro256StarStar::seed_from_u64(5);
        for _ in 0..200_000 {
            s.insert_u32(rng.next_u32());
        }
        let b = estimate_with(s.config(), s.registers(), EstimatorKind::Legacy);
        assert_eq!(b.correction, Correction::None);
        // Both estimators agree closely away from the range boundaries.
        let e = s.estimate();
        assert!((e - b.estimate).abs() / b.estimate < 0.02, "ertl {e} vs raw {}", b.estimate);
    }

    #[test]
    fn linear_counting_formula() {
        assert_eq!(linear_counting(16, 16), 0.0);
        let lc = linear_counting(1 << 16, 1 << 15);
        assert!((lc - 65536.0 * 2.0f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn large_range_correction_fires_only_for_h32_legacy() {
        // Force a huge raw estimate by maxing registers.
        let c32 = cfg(14, HashKind::H32);
        let regs = vec![c32.max_rank(); c32.m()];
        let b = estimate_with(&c32, &regs, EstimatorKind::Legacy);
        assert_eq!(b.correction, Correction::LargeRange);
        assert!(b.estimate.is_finite() && b.estimate > 0.0, "saturated, not NaN");

        let c64 = cfg(14, HashKind::H64);
        let regs = vec![20u8; c64.m()];
        let b = estimate_with(&c64, &regs, EstimatorKind::Legacy);
        assert_eq!(b.correction, Correction::None, "64-bit hash never large-range corrects");
    }

    #[test]
    fn ertl_has_no_range_branches() {
        // Fully saturated registers: the sketch carries no information;
        // Ertl reports divergence rather than a bias-corrected guess.
        let c = cfg(14, HashKind::H32);
        let regs = vec![c.max_rank(); c.m()];
        let b = estimate_with(&c, &regs, EstimatorKind::Ertl);
        assert_eq!(b.correction, Correction::ErtlTailCorrected);
        assert!(b.estimate.is_infinite());
        // High-but-unsaturated registers stay finite and huge.
        let regs = vec![20u8; c.m()];
        let b = estimate_with(&c, &regs, EstimatorKind::Ertl);
        assert!(b.estimate.is_finite() && b.estimate > 1e9);
    }

    #[test]
    fn sigma_tau_boundaries() {
        assert_eq!(ertl_sigma(0.0), 0.0);
        assert!(ertl_sigma(1.0).is_infinite());
        assert_eq!(ertl_tau(0.0), 0.0);
        assert_eq!(ertl_tau(1.0), 0.0);
        // Interior values are finite, positive, and monotone enough to
        // keep z positive.
        let s = ertl_sigma(0.5);
        assert!(s > 0.5 && s.is_finite());
        let t = ertl_tau(0.5);
        assert!(t > 0.0 && t < 1.0);
    }

    #[test]
    fn estimate_monotone_under_register_increase() {
        // Raising any register can only increase the estimate — for both
        // estimators.
        let c = cfg(8, HashKind::H64);
        for kind in [EstimatorKind::Ertl, EstimatorKind::Legacy] {
            let mut regs = vec![1u8; c.m()];
            let e1 = estimate_with(&c, &regs, kind).raw;
            regs[17] = 9;
            let e2 = estimate_with(&c, &regs, kind).raw;
            assert!(e2 > e1, "{kind:?}: {e2} !> {e1}");
        }
    }

    #[test]
    fn ertl_matches_histogram_path_exactly() {
        let mut s = HllSketch::new(cfg(10, HashKind::H64));
        let mut rng = Xoshiro256StarStar::seed_from_u64(11);
        for _ in 0..5_000 {
            s.insert_u32(rng.next_u32());
        }
        let via_regs = estimate_with(s.config(), s.registers(), EstimatorKind::Ertl).estimate;
        let hist = register_histogram(s.config(), s.registers());
        let via_hist = ertl_estimate_from_histogram(s.config(), &hist);
        assert_eq!(via_regs, via_hist, "estimate must be a pure function of the histogram");
    }

    #[test]
    fn ertl_is_accurate_across_ranges() {
        // Spot-check accuracy at three cardinalities spanning the legacy
        // LC/raw boundary (2.5m = 10240 at p=12).
        let c = cfg(12, HashKind::H64);
        for &n in &[1_000u32, 10_240, 300_000] {
            let mut s = HllSketch::new(c);
            let mut rng = Xoshiro256StarStar::seed_from_u64(n as u64);
            let mut seen = 0u32;
            while seen < n {
                s.insert_u32(rng.next_u32());
                seen += 1;
            }
            // Stream values are effectively distinct at these sizes; allow
            // generous 5σ slack (σ = 1.625% at p=12).
            let est = s.estimate();
            let rel = (est - n as f64).abs() / n as f64;
            assert!(rel < 5.0 * c.standard_error() + 0.01, "n={n}: est {est} rel {rel}");
        }
    }

    #[test]
    fn estimator_kind_wire_byte_round_trips() {
        for kind in [EstimatorKind::Ertl, EstimatorKind::Legacy] {
            assert_eq!(EstimatorKind::from_wire_byte(kind.as_wire_byte()), Some(kind));
        }
        assert_eq!(EstimatorKind::from_wire_byte(7), None);
        assert_eq!(EstimatorKind::default(), EstimatorKind::Ertl);
    }

    #[test]
    fn breakdown_consistency() {
        let mut s = HllSketch::paper();
        let mut rng = Xoshiro256StarStar::seed_from_u64(8);
        for _ in 0..500_000 {
            s.insert_u32(rng.next_u32());
        }
        let b = s.estimate_breakdown();
        assert_eq!(b.zero_registers, s.zero_registers());
        assert_eq!(b.estimate, s.estimate());
        assert!(b.raw > 0.0);
    }
}
