//! HLL configuration: precision `p`, hash width `H`, and the derived
//! constants of Algorithm 1 (α_m, thresholds, memory footprint).

use super::murmur3::{murmur3_x64_64_u32, murmur3_x86_32_u32};
use crate::util::bits::{ceil_log2, rho};

/// Hash width H — the paper studies H ∈ {32, 64} (Section IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HashKind {
    /// MurmurHash3_x86_32.
    H32,
    /// Low 64 bits of MurmurHash3_x64_128 (the paper's "64-bit Murmur3").
    H64,
}

impl HashKind {
    #[inline]
    pub fn bits(self) -> u32 {
        match self {
            HashKind::H32 => 32,
            HashKind::H64 => 64,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            HashKind::H32 => "HLL32",
            HashKind::H64 => "HLL64",
        }
    }
}

/// Errors constructing an [`HllConfig`].
#[derive(Debug, PartialEq, Eq)]
pub enum ConfigError {
    PrecisionOutOfRange(u8),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::PrecisionOutOfRange(p) => {
                write!(f, "precision p={p} out of range [4, 16] (Algorithm 1, line 1)")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

/// Static HLL parameters. The paper's hardware configuration is
/// `p = 16`, `H = 64` (chosen in Section IV); the profiling study also
/// covers `p = 14` and `H = 32`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HllConfig {
    p: u8,
    hash: HashKind,
    seed: u64,
}

impl HllConfig {
    /// The configuration the paper implements in hardware (Section V).
    pub const PAPER: HllConfig = HllConfig { p: 16, hash: HashKind::H64, seed: 0 };

    pub fn new(p: u8, hash: HashKind) -> Result<Self, ConfigError> {
        if !(4..=16).contains(&p) {
            return Err(ConfigError::PrecisionOutOfRange(p));
        }
        Ok(Self { p, hash, seed: 0 })
    }

    /// Override the hash seed (all layers must agree; the AOT artifacts
    /// are lowered with seed 0).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    #[inline]
    pub fn p(&self) -> u8 {
        self.p
    }

    #[inline]
    pub fn hash(&self) -> HashKind {
        self.hash
    }

    #[inline]
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Number of buckets m = 2^p.
    #[inline]
    pub fn m(&self) -> usize {
        1usize << self.p
    }

    /// Width of the sub-hash w in bits: H − p.
    #[inline]
    pub fn w_bits(&self) -> u32 {
        self.hash.bits() - self.p as u32
    }

    /// Maximum observable rank ρ ≤ H − p + 1 (paper eq. (2)).
    #[inline]
    pub fn max_rank(&self) -> u8 {
        (self.hash.bits() - self.p as u32 + 1) as u8
    }

    /// Bias-correction constant α_m (Algorithm 1, lines 2–3).
    pub fn alpha(&self) -> f64 {
        match self.m() {
            16 => 0.673,
            32 => 0.697,
            64 => 0.709,
            m => 0.7213 / (1.0 + 1.079 / m as f64),
        }
    }

    /// Small-range correction threshold 5/2·m (Algorithm 1, line 12).
    #[inline]
    pub fn small_range_threshold(&self) -> f64 {
        2.5 * self.m() as f64
    }

    /// Large-range threshold 2^32 / 30 — only meaningful for H = 32
    /// (with a 64-bit hash the correction is obsolete; Section III).
    #[inline]
    pub fn large_range_threshold(&self) -> Option<f64> {
        match self.hash {
            HashKind::H32 => Some((1u64 << 32) as f64 / 30.0),
            HashKind::H64 => None,
        }
    }

    /// Per-bucket register width ⌈log2(H − p + 1)⌉ bits (paper eq. (3)).
    #[inline]
    pub fn register_bits(&self) -> u32 {
        ceil_log2(self.max_rank() as u64)
    }

    /// Total sketch memory footprint in bits: B = 2^p · ⌈log2(H−p+1)⌉
    /// (paper eq. (3), Table II).
    #[inline]
    pub fn footprint_bits(&self) -> u64 {
        (self.m() as u64) * self.register_bits() as u64
    }

    /// Footprint in KiB, as reported in Table II.
    #[inline]
    pub fn footprint_kib(&self) -> f64 {
        self.footprint_bits() as f64 / 8.0 / 1024.0
    }

    /// Theoretical relative standard error 1.04/√m (Section III).
    #[inline]
    pub fn standard_error(&self) -> f64 {
        1.04 / (self.m() as f64).sqrt()
    }

    /// Hash a 32-bit stream word with the configured Murmur3 variant and
    /// seed. Shared by the dense, sparse and concurrent sketch front
    /// ends so all of them are hash-compatible by construction.
    #[inline]
    pub fn hash_word(&self, v: u32) -> u64 {
        match self.hash {
            HashKind::H32 => murmur3_x86_32_u32(v, self.seed as u32) as u64,
            HashKind::H64 => murmur3_x64_64_u32(v, self.seed),
        }
    }

    /// Hash a run of 32-bit stream words into `out` (`out.len()` must
    /// equal `words.len()`) — the batch front end of [`Self::hash_word`],
    /// and the first stage of the registry's batch ingest path.
    ///
    /// The body walks explicit 8-lane groups in the style of
    /// [`crate::cpu_baseline::aggregate32_batched`] (the paper's AVX2
    /// structure, Section VI-C): eight independent straight-line hashes
    /// per iteration with no cross-lane dependency, which LLVM turns
    /// into `vpmulld`/shift sequences on x86 for the 32-bit hash. The
    /// 64-bit hash has no AVX2 vector multiply, but the fixed-width
    /// unroll still buys interleaved scalar scheduling — the same ≈60%
    /// ratio the paper reports. Each lane calls the *identical* scalar
    /// function [`Self::hash_word`] does, so batch and scalar paths are
    /// bit-exact by construction (asserted by
    /// `hash_words_matches_hash_word`).
    pub fn hash_words(&self, words: &[u32], out: &mut [u64]) {
        assert_eq!(words.len(), out.len(), "hash_words output slice must match input length");
        match self.hash {
            HashKind::H32 => {
                let seed = self.seed as u32;
                let mut chunks = words.chunks_exact(8);
                let mut outs = out.chunks_exact_mut(8);
                for (chunk, o) in (&mut chunks).zip(&mut outs) {
                    let keys: &[u32; 8] = chunk.try_into().expect("exact 8-word chunk");
                    let lanes: &mut [u64; 8] = o.try_into().expect("exact 8-slot chunk");
                    for i in 0..8 {
                        lanes[i] = murmur3_x86_32_u32(keys[i], seed) as u64;
                    }
                }
                for (o, &w) in outs.into_remainder().iter_mut().zip(chunks.remainder()) {
                    *o = murmur3_x86_32_u32(w, seed) as u64;
                }
            }
            HashKind::H64 => {
                let seed = self.seed;
                let mut chunks = words.chunks_exact(8);
                let mut outs = out.chunks_exact_mut(8);
                for (chunk, o) in (&mut chunks).zip(&mut outs) {
                    let keys: &[u32; 8] = chunk.try_into().expect("exact 8-word chunk");
                    let lanes: &mut [u64; 8] = o.try_into().expect("exact 8-slot chunk");
                    for i in 0..8 {
                        lanes[i] = murmur3_x64_64_u32(keys[i], seed);
                    }
                }
                for (o, &w) in outs.into_remainder().iter_mut().zip(chunks.remainder()) {
                    *o = murmur3_x64_64_u32(w, seed);
                }
            }
        }
    }

    /// Split an H-bit hash into (bucket index, rank) — Algorithm 1 lines
    /// 7–8: idx = first p bits, w = remaining H−p bits, rank = ρ(w).
    #[inline]
    pub fn split_hash(&self, hash: u64) -> (usize, u8) {
        let w_bits = self.w_bits();
        let idx = (hash >> w_bits) as usize; // top p bits
        let w = hash & ((1u64 << w_bits) - 1); // low H-p bits
        (idx, rho(w, w_bits))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_range_enforced() {
        assert!(HllConfig::new(3, HashKind::H32).is_err());
        assert!(HllConfig::new(17, HashKind::H64).is_err());
        for p in 4..=16 {
            assert!(HllConfig::new(p, HashKind::H64).is_ok());
        }
    }

    #[test]
    fn alpha_matches_algorithm1() {
        assert_eq!(HllConfig::new(4, HashKind::H32).unwrap().alpha(), 0.673);
        assert_eq!(HllConfig::new(5, HashKind::H32).unwrap().alpha(), 0.697);
        assert_eq!(HllConfig::new(6, HashKind::H32).unwrap().alpha(), 0.709);
        let a = HllConfig::new(16, HashKind::H64).unwrap().alpha();
        assert!((a - 0.7213 / (1.0 + 1.079 / 65536.0)).abs() < 1e-12);
    }

    #[test]
    fn table2_memory_footprint() {
        // Paper Table II: (p, H) → (register bits, total KiB).
        let cases = [
            (14u8, HashKind::H32, 5u32, 10.0f64),
            (14, HashKind::H64, 6, 12.0),
            (16, HashKind::H32, 5, 40.0),
            (16, HashKind::H64, 6, 48.0),
        ];
        for (p, h, reg_bits, kib) in cases {
            let c = HllConfig::new(p, h).unwrap();
            assert_eq!(c.register_bits(), reg_bits, "p={p} H={:?}", h);
            assert!((c.footprint_kib() - kib).abs() < 1e-9, "p={p} H={:?}", h);
        }
    }

    #[test]
    fn max_rank_eq2() {
        let c = HllConfig::new(16, HashKind::H64).unwrap();
        assert_eq!(c.max_rank(), 49); // 64 - 16 + 1
        let c = HllConfig::new(14, HashKind::H32).unwrap();
        assert_eq!(c.max_rank(), 19); // 32 - 14 + 1
    }

    #[test]
    fn paper_config() {
        assert_eq!(HllConfig::PAPER.p(), 16);
        assert_eq!(HllConfig::PAPER.hash(), HashKind::H64);
        assert_eq!(HllConfig::PAPER.m(), 65536);
        // Expected standard error 0.41% (Section IV).
        assert!((HllConfig::PAPER.standard_error() - 0.0040625).abs() < 1e-6);
    }

    #[test]
    fn hash_words_matches_hash_word() {
        for cfg in [
            HllConfig::PAPER,
            HllConfig::new(14, HashKind::H32).unwrap(),
            HllConfig::PAPER.with_seed(42),
        ] {
            // 1003 words: 125 full 8-lane groups plus a 3-word
            // remainder, so both the unrolled body and the scalar tail
            // are checked against the scalar front end.
            let words: Vec<u32> = (0..1003u32).map(|i| i.wrapping_mul(0x9E37_79B9)).collect();
            let mut out = vec![0u64; words.len()];
            cfg.hash_words(&words, &mut out);
            for (&w, &h) in words.iter().zip(&out) {
                assert_eq!(h, cfg.hash_word(w), "cfg {cfg:?} word {w:#x}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "must match input length")]
    fn hash_words_rejects_length_mismatch() {
        let mut out = vec![0u64; 3];
        HllConfig::PAPER.hash_words(&[1, 2], &mut out);
    }

    #[test]
    fn large_range_only_for_h32() {
        assert!(HllConfig::new(14, HashKind::H32).unwrap().large_range_threshold().is_some());
        assert!(HllConfig::new(14, HashKind::H64).unwrap().large_range_threshold().is_none());
    }
}
