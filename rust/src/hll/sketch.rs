//! The dense HLL sketch: Algorithm 1's register file M[0..m-1] plus the
//! aggregation phase (insert) and the merge fold used by the parallel
//! architecture (Fig 3).
//!
//! # Wire format
//!
//! [`HllSketch::to_bytes`] / [`HllSketch::from_bytes`] ship partial
//! sketches between nodes (the coordinator's merge phase and the
//! distributed-merge example). The header is:
//!
//! | offset | size | field                                            |
//! |--------|------|--------------------------------------------------|
//! | 0      | 1    | wire version ([`WIRE_VERSION`], currently 2)     |
//! | 1      | 1    | precision `p`                                    |
//! | 2      | 1    | hash width in bits (32 or 64)                    |
//! | 3      | 8    | hash seed, little-endian u64                     |
//! | 11     | m    | registers, one byte each                         |
//!
//! Version 1 (the original format) had no seed byte and silently decoded
//! every sketch as seed 0, so merging a wire-decoded sketch built with a
//! nonzero seed produced garbage without any error. Version 2 carries
//! the seed; a decoded sketch keeps its seed in its [`HllConfig`], and
//! since the seed participates in config equality, merging sketches with
//! mismatched seeds is rejected with [`SketchError::ConfigMismatch`].

use super::config::{HashKind, HllConfig};
use super::estimate::{estimate, estimate_with, EstimateBreakdown, EstimatorKind};
use super::murmur3::{murmur3_x64_64, murmur3_x64_64_u32, murmur3_x86_32};
use crate::util::bits::rho;

/// Version byte leading the serialized form (see the module docs).
pub const WIRE_VERSION: u8 = 2;

/// Serialized header length in bytes: version, p, hash bits, seed.
pub const WIRE_HEADER_LEN: usize = 11;

/// Errors from sketch operations.
#[derive(Debug, PartialEq, Eq)]
pub enum SketchError {
    ConfigMismatch(HllConfig, HllConfig),
    Malformed(String),
}

impl std::fmt::Display for SketchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SketchError::ConfigMismatch(a, b) => {
                write!(f, "cannot merge sketches with different configs ({a:?} vs {b:?})")
            }
            SketchError::Malformed(what) => write!(f, "serialized sketch is malformed: {what}"),
        }
    }
}

impl std::error::Error for SketchError {}

/// A dense HyperLogLog sketch.
///
/// Registers are stored one-per-byte (the natural software layout); the
/// bit-packed BRAM layout of the hardware is modelled by
/// [`crate::fpga::bram`], and the analytic footprint of the *packed*
/// representation is given by [`HllConfig::footprint_bits`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HllSketch {
    cfg: HllConfig,
    regs: Vec<u8>,
}

impl HllSketch {
    pub fn new(cfg: HllConfig) -> Self {
        Self { cfg, regs: vec![0; cfg.m()] }
    }

    /// The paper's hardware configuration (p=16, 64-bit hash).
    pub fn paper() -> Self {
        Self::new(HllConfig::PAPER)
    }

    #[inline]
    pub fn config(&self) -> &HllConfig {
        &self.cfg
    }

    #[inline]
    pub fn registers(&self) -> &[u8] {
        &self.regs
    }

    /// Split an H-bit hash into (bucket index, rank) — Algorithm 1 lines
    /// 7–8: idx = first p bits, w = remaining H−p bits, rank = ρ(w).
    #[inline]
    pub fn index_and_rank(&self, hash: u64) -> (usize, u8) {
        self.cfg.split_hash(hash)
    }

    /// Apply a pre-split (index, rank) update: M[idx] = max(M[idx], rank).
    /// Used by callers that compute the hash themselves (lane-batched CPU
    /// baseline, FPGA BRAM model).
    #[inline]
    pub fn update_register(&mut self, idx: usize, rank: u8) {
        debug_assert!(rank <= self.cfg.max_rank());
        let slot = &mut self.regs[idx];
        if rank > *slot {
            *slot = rank;
        }
    }

    /// Insert a pre-computed H-bit hash (Algorithm 1 line 9).
    #[inline]
    pub fn insert_hash(&mut self, hash: u64) {
        self.insert_hash_changed(hash);
    }

    /// As [`HllSketch::insert_hash`], reporting the register it raised:
    /// `Some(idx)` when the insert set a new max for bucket `idx`,
    /// `None` when the sketch is unchanged. The replication primary's
    /// dirty tracking records these indices so a delta capture can ship
    /// only the registers that moved since the last drain
    /// ([`encode_register_diff`]) instead of the full register file.
    #[inline]
    pub fn insert_hash_changed(&mut self, hash: u64) -> Option<u32> {
        debug_assert!(
            self.cfg.hash() != HashKind::H32 || hash <= u32::MAX as u64,
            "32-bit config fed a hash wider than 32 bits"
        );
        let (idx, r) = self.index_and_rank(hash);
        if r > self.regs[idx] {
            self.regs[idx] = r;
            Some(idx as u32)
        } else {
            None
        }
    }

    /// Hash a 32-bit data word with the configured Murmur3 variant.
    #[inline]
    pub fn hash_u32(&self, v: u32) -> u64 {
        self.cfg.hash_word(v)
    }

    /// Insert a 32-bit data word (the paper's stream element type).
    #[inline]
    pub fn insert_u32(&mut self, v: u32) {
        let h = self.hash_u32(v);
        self.insert_hash(h);
    }

    /// Insert an arbitrary byte string (URLs, user IDs, …).
    pub fn insert_bytes(&mut self, data: &[u8]) {
        let h = match self.cfg.hash() {
            HashKind::H32 => murmur3_x86_32(data, self.cfg.seed() as u32) as u64,
            HashKind::H64 => murmur3_x64_64(data, self.cfg.seed()),
        };
        self.insert_hash(h);
    }

    /// Insert a whole batch of 32-bit words (the coordinator's unit of
    /// work). This is the L3 hot path; see `rust/benches/hot_path.rs`.
    pub fn insert_batch(&mut self, batch: &[u32]) {
        match self.cfg.hash() {
            HashKind::H64 => self.insert_batch_h64(batch),
            HashKind::H32 => {
                for &v in batch {
                    self.insert_u32(v);
                }
            }
        }
    }

    #[inline]
    fn insert_batch_h64(&mut self, batch: &[u32]) {
        // Two-phase, 4-wide interleaved: phase 1 hashes four independent
        // keys (breaking the serial dependence of one multiply/shift
        // chain — the software analogue of the FPGA's DSP pipelining),
        // phase 2 applies the register updates. Measured ~1.9× over the
        // naive fused loop (see EXPERIMENTS.md §Perf).
        let seed = self.cfg.seed();
        let p = self.cfg.p() as u32;
        let w_bits = 64 - p;
        let mask = (1u64 << w_bits) - 1;
        let mut chunks = batch.chunks_exact(4);
        for chunk in &mut chunks {
            // Four independent hash chains; LLVM schedules these with
            // full ILP since there is no cross-lane dependence.
            let h0 = murmur3_x64_64_u32(chunk[0], seed);
            let h1 = murmur3_x64_64_u32(chunk[1], seed);
            let h2 = murmur3_x64_64_u32(chunk[2], seed);
            let h3 = murmur3_x64_64_u32(chunk[3], seed);
            for h in [h0, h1, h2, h3] {
                let idx = (h >> w_bits) as usize;
                let r = rho(h & mask, w_bits);
                // idx < 2^p == regs.len() by construction of the shift.
                let slot = unsafe { self.regs.get_unchecked_mut(idx) };
                if r > *slot {
                    *slot = r;
                }
            }
        }
        for &v in chunks.remainder() {
            let h = murmur3_x64_64_u32(v, seed);
            let idx = (h >> w_bits) as usize;
            let r = rho(h & mask, w_bits);
            let slot = &mut self.regs[idx];
            if r > *slot {
                *slot = r;
            }
        }
    }

    /// Insert a run of pre-computed H-bit hashes — the dense-tier store
    /// stage of the batch ingest path. The split/compare/max-store body
    /// has no cross-iteration dependence (register stores commute), so
    /// the loop pipelines like the FPGA's bucket-update stage.
    pub fn insert_hashes(&mut self, hashes: &[u64]) {
        let w_bits = self.cfg.w_bits();
        let mask = (1u64 << w_bits) - 1;
        for &h in hashes {
            let idx = (h >> w_bits) as usize;
            let r = rho(h & mask, w_bits);
            let slot = &mut self.regs[idx];
            if r > *slot {
                *slot = r;
            }
        }
    }

    /// As [`HllSketch::insert_hashes`], pushing the index of every
    /// register the run raised into `changed` (duplicates possible when
    /// a later hash raises the same register again; callers dedup once
    /// per batch). This is the dense-tier arm of the registry's batched
    /// dirty capture: one traced store loop per run instead of an
    /// [`HllSketch::insert_hash_changed`] call per word.
    pub fn insert_hashes_changed(&mut self, hashes: &[u64], changed: &mut Vec<u32>) {
        let w_bits = self.cfg.w_bits();
        let mask = (1u64 << w_bits) - 1;
        for &h in hashes {
            let idx = (h >> w_bits) as usize;
            let r = rho(h & mask, w_bits);
            let slot = &mut self.regs[idx];
            if r > *slot {
                *slot = r;
                changed.push(idx as u32);
            }
        }
    }

    /// Bucket-wise max merge — the "Merge buckets" fold of the parallel
    /// architecture (Fig 3). Commutative, associative, idempotent.
    pub fn merge(&mut self, other: &HllSketch) -> Result<(), SketchError> {
        if self.cfg != other.cfg {
            return Err(SketchError::ConfigMismatch(self.cfg, other.cfg));
        }
        for (a, &b) in self.regs.iter_mut().zip(&other.regs) {
            if b > *a {
                *a = b;
            }
        }
        Ok(())
    }

    /// Number of registers still at zero (the V of Algorithm 1 line 13,
    /// produced in hardware by the "Zero Counter and Bypass" module).
    pub fn zero_registers(&self) -> usize {
        self.regs.iter().filter(|&&r| r == 0).count()
    }

    /// Cardinality estimate with the default estimator
    /// ([`EstimatorKind::Ertl`]).
    pub fn estimate(&self) -> f64 {
        estimate(&self.cfg, &self.regs).estimate
    }

    /// Cardinality estimate with an explicit estimator.
    pub fn estimate_with(&self, kind: EstimatorKind) -> f64 {
        estimate_with(&self.cfg, &self.regs, kind).estimate
    }

    /// Full estimate breakdown (raw E, V, which correction fired) under
    /// the default estimator.
    pub fn estimate_breakdown(&self) -> EstimateBreakdown {
        estimate(&self.cfg, &self.regs)
    }

    /// Full estimate breakdown with an explicit estimator.
    pub fn estimate_breakdown_with(&self, kind: EstimatorKind) -> EstimateBreakdown {
        estimate_with(&self.cfg, &self.regs, kind)
    }

    /// Reset all registers to zero (Algorithm 1, initialization phase).
    pub fn clear(&mut self) {
        self.regs.iter_mut().for_each(|r| *r = 0);
    }

    /// Load a register file produced elsewhere (e.g. by the PJRT-executed
    /// JAX artifact or the FPGA simulator); lengths and value range are
    /// validated.
    pub fn from_registers(cfg: HllConfig, regs: Vec<u8>) -> Result<Self, SketchError> {
        if regs.len() != cfg.m() {
            return Err(SketchError::Malformed(format!(
                "expected {} registers, got {}",
                cfg.m(),
                regs.len()
            )));
        }
        if let Some(&bad) = regs.iter().find(|&&r| r > cfg.max_rank()) {
            return Err(SketchError::Malformed(format!(
                "register value {bad} exceeds max rank {}",
                cfg.max_rank()
            )));
        }
        Ok(Self { cfg, regs })
    }

    /// Exact serialized length of a sketch with config `cfg`: the v2
    /// header plus one byte per register. Lets callers size buffers or
    /// budget snapshot/transfer sizes up front; [`HllSketch::from_bytes`]
    /// remains the validator for untrusted bytes.
    pub fn wire_len(cfg: &HllConfig) -> usize {
        WIRE_HEADER_LEN + cfg.m()
    }

    /// Serialize to the on-wire format used by the coordinator when
    /// shipping partial sketches: `[version, p, hash_bits, seed (8 B LE),
    /// regs...]` — see the module docs for the full header layout.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(WIRE_HEADER_LEN + self.regs.len());
        out.push(WIRE_VERSION);
        out.push(self.cfg.p());
        out.push(self.cfg.hash().bits() as u8);
        out.extend_from_slice(&self.cfg.seed().to_le_bytes());
        out.extend_from_slice(&self.regs);
        out
    }

    /// Inverse of [`HllSketch::to_bytes`]. The decoded sketch carries the
    /// hash seed from the header, so a later [`HllSketch::merge`] with a
    /// differently-seeded sketch fails with
    /// [`SketchError::ConfigMismatch`] instead of silently folding
    /// incompatible register files.
    pub fn from_bytes(data: &[u8]) -> Result<Self, SketchError> {
        if data.len() < WIRE_HEADER_LEN {
            return Err(SketchError::Malformed("truncated header".into()));
        }
        if data[0] != WIRE_VERSION {
            return Err(SketchError::Malformed(format!(
                "unsupported wire version {} (expected {WIRE_VERSION})",
                data[0]
            )));
        }
        let p = data[1];
        let hash = match data[2] {
            32 => HashKind::H32,
            64 => HashKind::H64,
            other => return Err(SketchError::Malformed(format!("bad hash width {other}"))),
        };
        let seed = u64::from_le_bytes(data[3..WIRE_HEADER_LEN].try_into().unwrap());
        let cfg = HllConfig::new(p, hash)
            .map_err(|e| SketchError::Malformed(e.to_string()))?
            .with_seed(seed);
        Self::from_registers(cfg, data[WIRE_HEADER_LEN..].to_vec())
    }

    /// Apply a decoded register diff: `M[idx] = max(M[idx], val)` for
    /// every entry — the follower-side inverse of
    /// [`encode_register_diff`]. Bucket-wise max, so replaying or
    /// reordering diffs is harmless, exactly like full-sketch merges.
    /// The caller must have checked config compatibility (the decode
    /// path returns the diff's [`HllConfig`] for that purpose).
    pub fn apply_register_diff(&mut self, entries: &[(u32, u8)]) {
        for &(idx, val) in entries {
            self.update_register(idx as usize, val);
        }
    }
}

/// Wire version byte leading a serialized register diff (a format of its
/// own, versioned independently of the full-sketch format).
pub const DIFF_WIRE_VERSION: u8 = 1;

/// Exact serialized length of a register diff with `n` entries: the
/// config header (same 11-byte layout as the full-sketch format), a
/// 4-byte entry count, then 5 bytes per entry.
pub fn diff_wire_len(n: usize) -> usize {
    WIRE_HEADER_LEN + 4 + 5 * n
}

/// Serialize a sparse register diff — the `(bucket index, new value)`
/// pairs of registers that moved since the last replication capture:
///
/// | offset | size | field                                      |
/// |--------|------|--------------------------------------------|
/// | 0      | 1    | diff version ([`DIFF_WIRE_VERSION`])       |
/// | 1      | 1    | precision `p`                              |
/// | 2      | 1    | hash width in bits (32 or 64)              |
/// | 3      | 8    | hash seed, little-endian u64               |
/// | 11     | 4    | entry count, little-endian u32             |
/// | 15     | 5n   | entries: `idx` u32 LE · `val` u8           |
///
/// Entries must be sorted by strictly increasing index with values in
/// `1..=max_rank` — the canonical form [`decode_register_diff`]
/// enforces, so one encoding exists per diff and a hostile peer cannot
/// smuggle duplicates past the decoder. The config header makes a diff
/// self-describing the same way wire-v2 sketches are: a diff built
/// against a differently-seeded registry fails config comparison
/// instead of silently max-merging incompatible registers.
pub fn encode_register_diff(cfg: &HllConfig, entries: &[(u32, u8)]) -> Vec<u8> {
    debug_assert!(
        entries.windows(2).all(|w| w[0].0 < w[1].0),
        "diff entries must be sorted by strictly increasing index"
    );
    debug_assert!(
        entries.iter().all(|&(idx, val)| {
            (idx as usize) < cfg.m() && val >= 1 && val <= cfg.max_rank()
        }),
        "diff entries must be in-range for the config"
    );
    let mut out = Vec::with_capacity(diff_wire_len(entries.len()));
    out.push(DIFF_WIRE_VERSION);
    out.push(cfg.p());
    out.push(cfg.hash().bits() as u8);
    out.extend_from_slice(&cfg.seed().to_le_bytes());
    out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for &(idx, val) in entries {
        out.extend_from_slice(&idx.to_le_bytes());
        out.push(val);
    }
    out
}

/// Inverse of [`encode_register_diff`]. Strict: the declared entry
/// count must match the payload exactly (checked before any allocation,
/// so a hostile count cannot drive one), indices must be strictly
/// increasing and in `0..m`, values in `1..=max_rank`.
pub fn decode_register_diff(data: &[u8]) -> Result<(HllConfig, Vec<(u32, u8)>), SketchError> {
    if data.len() < WIRE_HEADER_LEN + 4 {
        return Err(SketchError::Malformed("truncated register-diff header".into()));
    }
    if data[0] != DIFF_WIRE_VERSION {
        return Err(SketchError::Malformed(format!(
            "unsupported register-diff version {} (expected {DIFF_WIRE_VERSION})",
            data[0]
        )));
    }
    let p = data[1];
    let hash = match data[2] {
        32 => HashKind::H32,
        64 => HashKind::H64,
        other => return Err(SketchError::Malformed(format!("bad hash width {other}"))),
    };
    let seed = u64::from_le_bytes(data[3..WIRE_HEADER_LEN].try_into().unwrap());
    let cfg = HllConfig::new(p, hash)
        .map_err(|e| SketchError::Malformed(e.to_string()))?
        .with_seed(seed);
    let count =
        u32::from_le_bytes(data[WIRE_HEADER_LEN..WIRE_HEADER_LEN + 4].try_into().unwrap());
    let body = &data[WIRE_HEADER_LEN + 4..];
    // Compare in u64: `count * 5` could wrap a hostile count on a 32-bit
    // target into a small number that passes the check.
    if body.len() as u64 != count as u64 * 5 {
        return Err(SketchError::Malformed(format!(
            "register diff declares {count} entries but carries {} body bytes",
            body.len()
        )));
    }
    let mut entries = Vec::with_capacity(count as usize);
    let mut prev: Option<u32> = None;
    for chunk in body.chunks_exact(5) {
        let idx = u32::from_le_bytes(chunk[..4].try_into().unwrap());
        let val = chunk[4];
        if (idx as usize) >= cfg.m() {
            return Err(SketchError::Malformed(format!(
                "diff index {idx} out of range for m={}",
                cfg.m()
            )));
        }
        if val == 0 || val > cfg.max_rank() {
            return Err(SketchError::Malformed(format!(
                "diff value {val} outside 1..={}",
                cfg.max_rank()
            )));
        }
        if prev.is_some_and(|p| idx <= p) {
            return Err(SketchError::Malformed(format!(
                "diff indices not strictly increasing at {idx}"
            )));
        }
        prev = Some(idx);
        entries.push((idx, val));
    }
    Ok((cfg, entries))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256StarStar;

    fn cfg(p: u8, h: HashKind) -> HllConfig {
        HllConfig::new(p, h).unwrap()
    }

    #[test]
    fn index_and_rank_split() {
        let s = HllSketch::new(cfg(16, HashKind::H64));
        // Top 16 bits are the index.
        let (idx, r) = s.index_and_rank(0xABCD_0000_0000_0001);
        assert_eq!(idx, 0xABCD);
        assert_eq!(r, 48); // 47 leading zeros in the low 48 bits + 1
        let (_, r) = s.index_and_rank(0xABCD_0000_0000_0000);
        assert_eq!(r, 49); // w == 0 -> max rank
        let (_, r) = s.index_and_rank(0xABCD_8000_0000_0000);
        assert_eq!(r, 1);
    }

    #[test]
    fn index_and_rank_split_h32() {
        let s = HllSketch::new(cfg(14, HashKind::H32));
        let (idx, r) = s.index_and_rank(0xFFFF_FFFF >> 0);
        assert_eq!(idx, (0xFFFFFFFFu64 >> 18) as usize);
        assert_eq!(r, 1);
        let (idx, r) = s.index_and_rank(0);
        assert_eq!(idx, 0);
        assert_eq!(r, 19); // 18-bit w == 0 -> max rank 19
    }

    #[test]
    fn insert_is_monotone_and_idempotent() {
        let mut s = HllSketch::paper();
        s.insert_u32(42);
        let regs1 = s.registers().to_vec();
        s.insert_u32(42);
        assert_eq!(s.registers(), &regs1[..], "re-inserting must not change state");
    }

    #[test]
    fn duplicates_do_not_grow_estimate() {
        let mut s = HllSketch::paper();
        for v in 0..1000u32 {
            s.insert_u32(v);
        }
        let e1 = s.estimate();
        for v in 0..1000u32 {
            s.insert_u32(v); // same values again
        }
        assert_eq!(s.estimate(), e1);
    }

    #[test]
    fn batch_insert_equals_loop_insert() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(3);
        let batch: Vec<u32> = (0..4096).map(|_| rng.next_u32()).collect();
        for h in [HashKind::H32, HashKind::H64] {
            let mut a = HllSketch::new(cfg(16, h));
            let mut b = HllSketch::new(cfg(16, h));
            a.insert_batch(&batch);
            for &v in &batch {
                b.insert_u32(v);
            }
            assert_eq!(a, b);
        }
    }

    #[test]
    fn merge_properties() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(17);
        let mk = |rng: &mut Xoshiro256StarStar| {
            let mut s = HllSketch::new(cfg(12, HashKind::H64));
            for _ in 0..500 {
                s.insert_u32(rng.next_u32());
            }
            s
        };
        let a = mk(&mut rng);
        let b = mk(&mut rng);
        let c = mk(&mut rng);

        // Commutative.
        let mut ab = a.clone();
        ab.merge(&b).unwrap();
        let mut ba = b.clone();
        ba.merge(&a).unwrap();
        assert_eq!(ab, ba);

        // Associative.
        let mut ab_c = ab.clone();
        ab_c.merge(&c).unwrap();
        let mut bc = b.clone();
        bc.merge(&c).unwrap();
        let mut a_bc = a.clone();
        a_bc.merge(&bc).unwrap();
        assert_eq!(ab_c, a_bc);

        // Idempotent.
        let mut aa = a.clone();
        aa.merge(&a).unwrap();
        assert_eq!(aa, a);
    }

    #[test]
    fn merge_equals_union_stream() {
        // Sketch(A) ∪ Sketch(B) == Sketch(A ++ B): the property Fig 3's
        // parallel architecture relies on.
        let mut rng = Xoshiro256StarStar::seed_from_u64(23);
        let xs: Vec<u32> = (0..2000).map(|_| rng.next_u32()).collect();
        let (left, right) = xs.split_at(800);
        let mut sa = HllSketch::paper();
        let mut sb = HllSketch::paper();
        let mut sall = HllSketch::paper();
        sa.insert_batch(left);
        sb.insert_batch(right);
        sall.insert_batch(&xs);
        sa.merge(&sb).unwrap();
        assert_eq!(sa, sall);
    }

    #[test]
    fn merge_rejects_config_mismatch() {
        let mut a = HllSketch::new(cfg(14, HashKind::H64));
        let b = HllSketch::new(cfg(16, HashKind::H64));
        assert!(matches!(a.merge(&b), Err(SketchError::ConfigMismatch(..))));
        let c = HllSketch::new(cfg(14, HashKind::H32));
        assert!(a.merge(&c).is_err());
    }

    #[test]
    fn zero_registers_counts() {
        let mut s = HllSketch::new(cfg(8, HashKind::H64));
        assert_eq!(s.zero_registers(), 256);
        s.insert_u32(1);
        assert_eq!(s.zero_registers(), 255);
        s.clear();
        assert_eq!(s.zero_registers(), 256);
    }

    #[test]
    fn serde_roundtrip() {
        let mut s = HllSketch::new(cfg(10, HashKind::H32));
        for v in 0..5000u32 {
            s.insert_u32(v.wrapping_mul(2654435761));
        }
        let bytes = s.to_bytes();
        assert_eq!(bytes.len(), HllSketch::wire_len(s.config()));
        let s2 = HllSketch::from_bytes(&bytes).unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn serde_roundtrip_preserves_seed() {
        let cfg = HllConfig::new(12, HashKind::H64).unwrap().with_seed(0xDEAD_BEEF_CAFE_F00D);
        let mut s = HllSketch::new(cfg);
        for v in 0..3000u32 {
            s.insert_u32(v.wrapping_mul(2654435761));
        }
        let s2 = HllSketch::from_bytes(&s.to_bytes()).unwrap();
        assert_eq!(s2.config().seed(), 0xDEAD_BEEF_CAFE_F00D);
        assert_eq!(s, s2);
    }

    #[test]
    fn wire_decoded_seed_mismatch_rejected_on_merge() {
        // The bug this format fixes: a sketch built with a nonzero seed
        // used to decode as seed 0 and merge silently into seed-0
        // sketches. Now the seed rides the wire and the merge is rejected.
        let seeded = HllSketch::new(cfg(12, HashKind::H64).with_seed(7));
        let decoded = HllSketch::from_bytes(&seeded.to_bytes()).unwrap();
        assert_eq!(decoded.config().seed(), 7);
        let mut plain = HllSketch::new(cfg(12, HashKind::H64));
        assert!(matches!(
            plain.merge(&decoded),
            Err(SketchError::ConfigMismatch(..))
        ));
    }

    #[test]
    fn from_bytes_rejects_garbage() {
        assert!(HllSketch::from_bytes(&[]).is_err());
        // Truncated header (needs WIRE_HEADER_LEN bytes).
        assert!(HllSketch::from_bytes(&[WIRE_VERSION, 16]).is_err());
        assert!(HllSketch::from_bytes(&vec![0u8; WIRE_HEADER_LEN - 1]).is_err());
        // Unknown wire version (v1 had no seed field).
        let mut v1 = vec![1u8, 16, 64];
        v1.extend(vec![0u8; 8 + 16]);
        assert!(HllSketch::from_bytes(&v1).is_err());
        // Bad hash width.
        let mut bad_width = vec![WIRE_VERSION, 16, 48];
        bad_width.extend(vec![0u8; 8 + 4]);
        assert!(HllSketch::from_bytes(&bad_width).is_err());
        // Bad precision.
        let mut bad_p = vec![WIRE_VERSION, 2, 64];
        bad_p.extend(vec![0u8; 8 + 4]);
        assert!(HllSketch::from_bytes(&bad_p).is_err());
        // Wrong register count (p=16 needs 65536 registers).
        let mut short_regs = vec![WIRE_VERSION, 16, 64];
        short_regs.extend(vec![0u8; 8 + 3]);
        assert!(HllSketch::from_bytes(&short_regs).is_err());
        // Register exceeding max rank.
        let mut bytes = vec![WIRE_VERSION, 4, 64];
        bytes.extend(vec![0u8; 8]); // seed
        bytes.extend(vec![0u8; 16]); // registers for p=4
        bytes[WIRE_HEADER_LEN] = 62; // max rank for p=4,H=64 is 61
        assert!(HllSketch::from_bytes(&bytes).is_err());
    }

    #[test]
    fn insert_hash_changed_reports_raised_register() {
        let mut s = HllSketch::new(cfg(16, HashKind::H64));
        // 0xABCD_0000_0000_0001 → idx 0xABCD, rank 48 (see the split test).
        let h = 0xABCD_0000_0000_0001u64;
        assert_eq!(s.insert_hash_changed(h), Some(0xABCD));
        // Re-inserting the same hash changes nothing.
        assert_eq!(s.insert_hash_changed(h), None);
        // A lower rank into the same bucket changes nothing either.
        assert_eq!(s.insert_hash_changed(0xABCD_8000_0000_0000), None);
        // A higher rank raises the same bucket again.
        assert_eq!(s.insert_hash_changed(0xABCD_0000_0000_0000), Some(0xABCD));
    }

    #[test]
    fn register_diff_roundtrip_and_apply() {
        let c = cfg(12, HashKind::H64).with_seed(0xFEED);
        let entries: Vec<(u32, u8)> = vec![(0, 3), (17, 1), (100, 49), (4095, 7)];
        let bytes = encode_register_diff(&c, &entries);
        assert_eq!(bytes.len(), diff_wire_len(entries.len()));
        let (got_cfg, got) = decode_register_diff(&bytes).unwrap();
        assert_eq!(got_cfg, c);
        assert_eq!(got, entries);

        // Applying the diff to an empty sketch sets exactly those
        // registers; applying twice is idempotent (max-merge).
        let mut s = HllSketch::new(c);
        s.apply_register_diff(&got);
        for &(idx, val) in &entries {
            assert_eq!(s.registers()[idx as usize], val);
        }
        assert_eq!(s.registers().iter().filter(|&&r| r != 0).count(), entries.len());
        let snap = s.clone();
        s.apply_register_diff(&got);
        assert_eq!(s, snap);

        // An empty diff is valid and does nothing.
        let empty = encode_register_diff(&c, &[]);
        let (_, none) = decode_register_diff(&empty).unwrap();
        assert!(none.is_empty());
    }

    #[test]
    fn register_diff_rejects_hostile_bytes() {
        let c = cfg(8, HashKind::H64);
        let good = encode_register_diff(&c, &[(1, 2), (9, 5)]);
        assert!(decode_register_diff(&good).is_ok());
        // Truncations anywhere are typed errors.
        for cut in [0usize, 5, WIRE_HEADER_LEN, WIRE_HEADER_LEN + 3, good.len() - 1] {
            assert!(decode_register_diff(&good[..cut]).is_err(), "cut at {cut}");
        }
        // Trailing bytes rejected.
        let mut padded = good.clone();
        padded.push(0);
        assert!(decode_register_diff(&padded).is_err());
        // A count the payload cannot carry is rejected before allocation.
        let mut huge = good.clone();
        huge[WIRE_HEADER_LEN..WIRE_HEADER_LEN + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_register_diff(&huge).is_err());
        // Bad version / hash width / precision.
        let mut bad = good.clone();
        bad[0] = 99;
        assert!(decode_register_diff(&bad).is_err());
        let mut bad = good.clone();
        bad[2] = 48;
        assert!(decode_register_diff(&bad).is_err());
        let mut bad = good.clone();
        bad[1] = 2;
        assert!(decode_register_diff(&bad).is_err());
        // Out-of-range index (m=256 at p=8).
        let mut bad = good.clone();
        let entry0 = WIRE_HEADER_LEN + 4;
        bad[entry0..entry0 + 4].copy_from_slice(&256u32.to_le_bytes());
        assert!(decode_register_diff(&bad).is_err());
        // Zero and over-max values rejected.
        let mut bad = good.clone();
        bad[entry0 + 4] = 0;
        assert!(decode_register_diff(&bad).is_err());
        let mut bad = good.clone();
        bad[entry0 + 4] = c.max_rank() + 1;
        assert!(decode_register_diff(&bad).is_err());
        // Duplicate / unsorted indices rejected (canonical form).
        let mut dup = good.clone();
        let entry1 = entry0 + 5;
        dup[entry1..entry1 + 4].copy_from_slice(&1u32.to_le_bytes());
        assert!(decode_register_diff(&dup).is_err());
    }

    #[test]
    fn estimate_rough_accuracy_mid_range() {
        // 100k distinct values at p=16 should estimate within ~3σ of
        // truth (σ = 0.41%); use a loose 2% bound to stay deterministic.
        let mut s = HllSketch::paper();
        let mut rng = Xoshiro256StarStar::seed_from_u64(77);
        let n = 100_000u32;
        let mut seen = std::collections::HashSet::new();
        while seen.len() < n as usize {
            seen.insert(rng.next_u32());
        }
        for &v in &seen {
            s.insert_u32(v);
        }
        let e = s.estimate();
        let err = (e - n as f64).abs() / n as f64;
        assert!(err < 0.02, "estimate {e} vs truth {n}: rel err {err}");
    }
}
