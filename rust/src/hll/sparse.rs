//! Sparse HLL representation (HyperLogLog++-style, Heule et al. [3] in
//! the paper's bibliography) — an extension beyond the paper's dense
//! hardware sketch.
//!
//! For small cardinalities the dense register file (64 KiB of registers at
//! p=16) is mostly zeros; the sparse mode stores (index, rank) pairs in a
//! compact sorted buffer and upgrades to the dense representation when the
//! buffer would exceed the dense footprint. This is the standard software
//! optimization used by production HLL implementations (BigQuery's
//! HLL++, Redis), and it matters for the coordinator when many per-
//! connection sketches are alive at once.

use super::config::HllConfig;
use super::sketch::{HllSketch, SketchError};

/// Encoded sparse entry: `idx << 8 | rank` (rank always fits in 8 bits —
/// max rank is ≤ 61 for every admissible config).
#[inline]
fn encode(idx: usize, rank: u8) -> u64 {
    ((idx as u64) << 8) | rank as u64
}

#[inline]
fn decode(e: u64) -> (usize, u8) {
    ((e >> 8) as usize, (e & 0xFF) as u8)
}

/// A cardinality sketch that starts sparse and upgrades to dense.
#[derive(Debug, Clone)]
pub enum AdaptiveSketch {
    Sparse(SparseHll),
    Dense(HllSketch),
}

/// What one [`AdaptiveSketch::insert_hash_traced`] call did to the
/// sketch — the per-write feed of the replication primary's
/// changed-register dirty tracking (see [`crate::registry`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InsertOutcome {
    /// The sketch is dense and the insert raised register `idx`.
    DenseChanged(u32),
    /// The sketch is dense and the insert changed nothing.
    Unchanged,
    /// The sketch took the sparse path (including an insert that
    /// triggered the sparse→dense upgrade): which registers moved is
    /// not tracked, so a delta capture must resend the whole sketch.
    Untracked,
}

/// Sparse HLL state: a hash-map-free sorted vec of encoded entries with a
/// small unsorted staging buffer (amortized O(1) inserts).
#[derive(Debug, Clone)]
pub struct SparseHll {
    cfg: HllConfig,
    /// Sorted by index, one entry per index, rank = max seen.
    sorted: Vec<u64>,
    /// Unsorted recent inserts, merged into `sorted` when full.
    staging: Vec<u64>,
    staging_cap: usize,
}

impl SparseHll {
    pub fn new(cfg: HllConfig) -> Self {
        Self { cfg, sorted: Vec::new(), staging: Vec::new(), staging_cap: 256 }
    }

    pub fn config(&self) -> &HllConfig {
        &self.cfg
    }

    /// Number of distinct indices currently tracked, counting across
    /// both the sorted run and the unsorted staging buffer without
    /// mutating either — so `len`, [`SparseHll::is_empty`] and
    /// [`SparseHll::memory_bytes`] are all consistent read-only views of
    /// the same state (previously `len` forced a compaction and needed
    /// `&mut self`, while the other accessors saw pre-compaction state).
    pub fn len(&self) -> usize {
        if self.staging.is_empty() {
            return self.sorted.len();
        }
        let mut staged: Vec<u64> = self.staging.iter().map(|e| e >> 8).collect();
        staged.sort_unstable();
        staged.dedup();
        let fresh = staged
            .iter()
            .filter(|&&idx| self.sorted.binary_search_by_key(&idx, |e| e >> 8).is_err())
            .count();
        self.sorted.len() + fresh
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty() && self.staging.is_empty()
    }

    /// Approximate heap bytes used — the upgrade policy input.
    pub fn memory_bytes(&self) -> usize {
        (self.sorted.capacity() + self.staging.capacity()) * std::mem::size_of::<u64>()
    }

    pub fn insert_hash(&mut self, hash: u64) {
        // Same split as the dense and concurrent paths, by construction.
        let (idx, rank) = self.cfg.split_hash(hash);
        self.staging.push(encode(idx, rank));
        if self.staging.len() >= self.staging_cap {
            self.compact();
        }
    }

    /// Visit every live (bucket index, max rank) entry after compacting —
    /// proportional to live entries, not to m. Used by the registry's
    /// bulk merge so sparse keys don't get densified just to be folded.
    pub fn for_each_entry<F: FnMut(usize, u8)>(&mut self, mut f: F) {
        self.compact();
        for &e in &self.sorted {
            let (idx, rank) = decode(e);
            f(idx, rank);
        }
    }

    /// Merge staging into the sorted run, keeping max rank per index.
    fn compact(&mut self) {
        if self.staging.is_empty() {
            return;
        }
        self.staging.sort_unstable_by_key(|&e| (e >> 8, std::cmp::Reverse(e & 0xFF)));
        let mut merged = Vec::with_capacity(self.sorted.len() + self.staging.len());
        let mut i = 0;
        let mut j = 0;
        let take_max = |merged: &mut Vec<u64>, e: u64| {
            match merged.last_mut() {
                Some(last) if *last >> 8 == e >> 8 => {
                    if (e & 0xFF) > (*last & 0xFF) {
                        *last = e;
                    }
                }
                _ => merged.push(e),
            }
        };
        while i < self.sorted.len() && j < self.staging.len() {
            if self.sorted[i] >> 8 <= self.staging[j] >> 8 {
                take_max(&mut merged, self.sorted[i]);
                i += 1;
            } else {
                take_max(&mut merged, self.staging[j]);
                j += 1;
            }
        }
        merged.extend(self.sorted[i..].iter().copied().map(|e| e));
        for &e in &self.staging[j..] {
            take_max(&mut merged, e);
        }
        // The tail extend above may have appended duplicates of the last
        // staging index; normalize with a final dedup pass by index.
        let mut out: Vec<u64> = Vec::with_capacity(merged.len());
        for e in merged {
            take_max(&mut out, e);
        }
        self.sorted = out;
        self.staging.clear();
    }

    /// Materialize the equivalent dense sketch.
    pub fn to_dense(&mut self) -> HllSketch {
        self.compact();
        let mut regs = vec![0u8; self.cfg.m()];
        for &e in &self.sorted {
            let (idx, rank) = decode(e);
            if rank > regs[idx] {
                regs[idx] = rank;
            }
        }
        HllSketch::from_registers(self.cfg, regs).expect("sparse entries are in range")
    }

    /// Exact LinearCounting-style estimate from the sparse state: with V =
    /// m − |distinct indices| empty buckets.
    pub fn estimate(&mut self) -> f64 {
        self.compact();
        let m = self.cfg.m();
        let v = m - self.sorted.len();
        if v == 0 {
            return self.to_dense().estimate();
        }
        super::estimate::linear_counting(m, v)
    }
}

impl AdaptiveSketch {
    pub fn new(cfg: HllConfig) -> Self {
        AdaptiveSketch::Sparse(SparseHll::new(cfg))
    }

    pub fn config(&self) -> &HllConfig {
        match self {
            AdaptiveSketch::Sparse(s) => s.config(),
            AdaptiveSketch::Dense(d) => d.config(),
        }
    }

    /// Dense footprint the sparse mode must stay under to pay off.
    fn upgrade_threshold(&self) -> usize {
        self.config().m() // bytes: one u8 register per bucket
    }

    pub fn insert_hash(&mut self, hash: u64) {
        match self {
            AdaptiveSketch::Dense(d) => d.insert_hash(hash),
            AdaptiveSketch::Sparse(s) => {
                s.insert_hash(hash);
                if s.memory_bytes() > self.upgrade_threshold() {
                    self.upgrade();
                }
            }
        }
    }

    /// As [`AdaptiveSketch::insert_hash`], reporting what the insert
    /// did (see [`InsertOutcome`]). Dense sketches report the raised
    /// register exactly; sparse ones report [`InsertOutcome::Untracked`]
    /// — their staging buffer cannot tell a fresh max from a duplicate
    /// without a compaction per insert, and a sparse key's full resend
    /// is cheap in the only place the distinction matters (replication
    /// delta capture).
    pub fn insert_hash_traced(&mut self, hash: u64) -> InsertOutcome {
        if let AdaptiveSketch::Dense(d) = self {
            return match d.insert_hash_changed(hash) {
                Some(idx) => InsertOutcome::DenseChanged(idx),
                None => InsertOutcome::Unchanged,
            };
        }
        // Sparse path (runs the upgrade check like a plain insert).
        self.insert_hash(hash);
        InsertOutcome::Untracked
    }

    /// Apply a decoded register diff (bucket-wise max) — the follower's
    /// per-key apply path for `RegisterDiff` delta entries. Diffs are
    /// only ever produced for dense sketches, so a sparse receiver
    /// upgrades first (mirroring the primary's in-memory state).
    pub fn apply_register_diff(&mut self, entries: &[(u32, u8)]) {
        self.upgrade_to_dense_in_place();
        match self {
            AdaptiveSketch::Dense(d) => d.apply_register_diff(entries),
            AdaptiveSketch::Sparse(_) => unreachable!(),
        }
    }

    pub fn insert_u32(&mut self, v: u32) {
        // Hash straight from the config — the sparse arm used to build a
        // throwaway dense HllSketch (a 2^p-byte allocation) per insert
        // just to call its hash method.
        let h = self.config().hash_word(v);
        self.insert_hash(h);
    }

    /// Approximate heap bytes held by this sketch — the registry's
    /// memory-accounting input. Dense sketches report their register
    /// file; sparse ones their buffers.
    pub fn memory_bytes(&self) -> usize {
        match self {
            AdaptiveSketch::Sparse(s) => s.memory_bytes(),
            AdaptiveSketch::Dense(d) => d.config().m(),
        }
    }

    fn upgrade(&mut self) {
        if let AdaptiveSketch::Sparse(s) = self {
            let dense = s.to_dense();
            *self = AdaptiveSketch::Dense(dense);
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, AdaptiveSketch::Sparse(_))
    }

    pub fn estimate(&mut self) -> f64 {
        match self {
            AdaptiveSketch::Sparse(s) => s.estimate(),
            AdaptiveSketch::Dense(d) => d.estimate(),
        }
    }

    /// Convert to dense unconditionally (needed before merging with a
    /// dense partner). Consumes in place: an already-dense sketch moves
    /// its register file out instead of cloning it.
    pub fn into_dense(self) -> HllSketch {
        match self {
            AdaptiveSketch::Sparse(mut s) => s.to_dense(),
            AdaptiveSketch::Dense(d) => d,
        }
    }

    pub fn merge_into(&mut self, other: AdaptiveSketch) -> Result<(), SketchError> {
        let other = other.into_dense();
        self.upgrade_to_dense_in_place();
        match self {
            AdaptiveSketch::Dense(d) => d.merge(&other),
            AdaptiveSketch::Sparse(_) => unreachable!(),
        }
    }

    fn upgrade_to_dense_in_place(&mut self) {
        if self.is_sparse() {
            self.upgrade();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::config::HashKind;
    use crate::util::Xoshiro256StarStar;

    fn cfg() -> HllConfig {
        HllConfig::new(16, HashKind::H64).unwrap()
    }

    #[test]
    fn sparse_matches_dense_exactly() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(1);
        let mut sparse = SparseHll::new(cfg());
        let mut dense = HllSketch::new(cfg());
        for _ in 0..3000 {
            let v = rng.next_u32();
            dense.insert_u32(v);
            sparse.insert_hash(dense.hash_u32(v));
        }
        assert_eq!(sparse.to_dense(), dense);
    }

    #[test]
    fn sparse_estimate_small_range_accurate() {
        let mut sparse = SparseHll::new(cfg());
        let dense_probe = HllSketch::new(cfg());
        for v in 0..1000u32 {
            sparse.insert_hash(dense_probe.hash_u32(v));
        }
        let e = sparse.estimate();
        assert!((e - 1000.0).abs() / 1000.0 < 0.05, "est {e}");
    }

    #[test]
    fn adaptive_upgrades_under_load() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(2);
        let mut a = AdaptiveSketch::new(cfg());
        assert!(a.is_sparse());
        for _ in 0..50_000 {
            a.insert_u32(rng.next_u32());
        }
        assert!(!a.is_sparse(), "should have upgraded to dense");
    }

    #[test]
    fn adaptive_equals_plain_dense() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(3);
        let mut a = AdaptiveSketch::new(cfg());
        let mut d = HllSketch::new(cfg());
        for _ in 0..30_000 {
            let v = rng.next_u32();
            a.insert_u32(v);
            d.insert_u32(v);
        }
        assert_eq!(a.into_dense(), d);
    }

    #[test]
    fn adaptive_merge() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(4);
        let mut a = AdaptiveSketch::new(cfg());
        let mut b = AdaptiveSketch::new(cfg());
        let mut all = HllSketch::new(cfg());
        for i in 0..10_000 {
            let v = rng.next_u32();
            if i % 2 == 0 {
                a.insert_u32(v);
            } else {
                b.insert_u32(v);
            }
            all.insert_u32(v);
        }
        a.merge_into(b).unwrap();
        assert_eq!(a.into_dense(), all);
    }

    #[test]
    fn traced_inserts_match_plain_inserts_and_report_outcomes() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(9);
        let mut traced = AdaptiveSketch::new(cfg());
        let mut plain = AdaptiveSketch::new(cfg());
        let c = *traced.config();
        let mut saw_untracked = false;
        let mut saw_dense = false;
        for _ in 0..60_000 {
            let h = c.hash_word(rng.next_u32());
            plain.insert_hash(h);
            match traced.insert_hash_traced(h) {
                InsertOutcome::Untracked => saw_untracked = true,
                InsertOutcome::DenseChanged(idx) => {
                    saw_dense = true;
                    // The reported register really holds this hash's rank
                    // (or better, later).
                    assert!((idx as usize) < c.m());
                }
                InsertOutcome::Unchanged => {}
            }
        }
        assert!(saw_untracked, "sparse phase must report Untracked");
        assert!(saw_dense, "dense phase must report changed registers");
        assert!(!traced.is_sparse());
        assert_eq!(traced.into_dense(), plain.into_dense());
    }

    #[test]
    fn adaptive_apply_register_diff_densifies_and_max_merges() {
        let mut a = AdaptiveSketch::new(cfg());
        assert!(a.is_sparse());
        a.apply_register_diff(&[(3, 7), (100, 2)]);
        assert!(!a.is_sparse(), "diff apply mirrors the primary's dense state");
        let d = a.into_dense();
        assert_eq!(d.registers()[3], 7);
        assert_eq!(d.registers()[100], 2);
        assert_eq!(d.registers().iter().filter(|&&r| r != 0).count(), 2);
    }

    #[test]
    fn len_is_read_only_and_compaction_invariant() {
        let mut sparse = SparseHll::new(cfg());
        let probe = HllSketch::new(cfg());
        for v in 0..300u32 {
            sparse.insert_hash(probe.hash_u32(v));
            sparse.insert_hash(probe.hash_u32(v)); // duplicate
        }
        // Read through a shared borrow: must not mutate.
        let shared: &SparseHll = &sparse;
        let before = shared.len();
        assert!(!shared.is_empty());
        let mem = shared.memory_bytes();
        assert!(mem > 0);
        // Forcing a compaction must not change the distinct-index count.
        let dense = sparse.to_dense();
        assert_eq!(sparse.len(), before);
        assert_eq!(
            dense.registers().iter().filter(|&&r| r != 0).count(),
            before,
            "len must equal the number of occupied dense buckets"
        );
    }

    #[test]
    fn compaction_dedups_staging_duplicates() {
        let mut sparse = SparseHll::new(cfg());
        let probe = HllSketch::new(cfg());
        // Insert the same few values repeatedly across compaction
        // boundaries.
        for _ in 0..10 {
            for v in 0..100u32 {
                sparse.insert_hash(probe.hash_u32(v));
            }
        }
        let n = sparse.len();
        assert!(n <= 100, "dedup failed: {n} entries for 100 values");
    }
}
