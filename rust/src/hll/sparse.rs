//! Sparse HLL representation (HyperLogLog++-style, Heule et al. [3] in
//! the paper's bibliography) and the three-tier [`AdaptiveSketch`] that
//! grows Sparse → Packed → Dense as a key accumulates distinct values.
//!
//! For small cardinalities the dense register file (64 KiB of registers at
//! p=16) is mostly zeros; the sparse mode stores (index, rank) pairs in a
//! compact sorted buffer. Once the pair buffer would exceed the *packed*
//! footprint (≈ 3m/8 bytes — see [`PackedHll`]), the sketch compresses
//! into base+delta+exception form, and only when the exception list
//! outgrows its budget does it fall back to the plain m-byte dense file.
//! This is the standard software optimization used by production HLL
//! implementations (BigQuery's HLL++, Redis) extended with the
//! HyperLogLogLog packed tier, and it matters for the registry when many
//! per-key sketches are resident at once.

use super::config::HllConfig;
use super::estimate::{ertl_estimate_from_histogram, EstimatorKind};
use super::packed::PackedHll;
use super::sketch::{HllSketch, SketchError};

/// Encoded sparse entry: `idx << 8 | rank` (rank always fits in 8 bits —
/// max rank is ≤ 61 for every admissible config).
#[inline]
fn encode(idx: usize, rank: u8) -> u64 {
    ((idx as u64) << 8) | rank as u64
}

#[inline]
fn decode(e: u64) -> (usize, u8) {
    ((e >> 8) as usize, (e & 0xFF) as u8)
}

/// Words the adaptive sparse phase bulk-appends between promotion
/// checks. Matches the sparse staging cap, so a batch can overshoot the
/// promotion threshold by at most one staging buffer — the same slack
/// the word-at-a-time path has between two compactions.
const SPARSE_BATCH_CHUNK: usize = 256;

/// A cardinality sketch that starts sparse, compresses to packed, and
/// upgrades to dense — promotions driven by measured bytes, never
/// demoting, with identical estimates at every tier.
#[derive(Debug, Clone)]
pub enum AdaptiveSketch {
    Sparse(SparseHll),
    Packed(PackedHll),
    Dense(HllSketch),
}

/// What one [`AdaptiveSketch::insert_hash_traced`] call did to the
/// sketch — the per-write feed of the replication primary's
/// changed-register dirty tracking (see [`crate::registry`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InsertOutcome {
    /// The sketch tracks per-register state (packed or dense) and the
    /// insert raised register `idx`.
    RegisterChanged(u32),
    /// The insert changed nothing (packed or dense).
    Unchanged,
    /// The sketch took the sparse path (including an insert that
    /// triggered the sparse→packed promotion): which registers moved is
    /// not tracked, so a delta capture must resend the whole sketch.
    Untracked,
}

/// What one [`AdaptiveSketch::insert_hashes_traced`] run did to the
/// sketch — the batch counterpart of [`InsertOutcome`], collapsed to the
/// only distinction the dirty-tracking caller needs per *run*: either
/// every word went through a register-tracking tier (raised registers
/// were pushed into the caller's capture vec), or at least one word took
/// the sparse path and the whole key must be resent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchOutcome {
    /// All inserts hit packed/dense state; `changed` holds every raised
    /// register index (possibly with duplicates — dedup once per batch).
    Tracked,
    /// Some prefix of the run was inserted sparse (untracked), so the
    /// caller must fall back to a full-sketch capture for this key.
    Untracked,
}

/// Sparse HLL state: a hash-map-free sorted vec of encoded entries with a
/// small unsorted staging buffer (amortized O(1) inserts).
#[derive(Debug, Clone)]
pub struct SparseHll {
    cfg: HllConfig,
    /// Sorted by index, one entry per index, rank = max seen.
    sorted: Vec<u64>,
    /// Unsorted recent inserts, merged into `sorted` when full.
    staging: Vec<u64>,
    staging_cap: usize,
}

impl SparseHll {
    pub fn new(cfg: HllConfig) -> Self {
        Self { cfg, sorted: Vec::new(), staging: Vec::new(), staging_cap: 256 }
    }

    /// Build sparse state straight from a dense register file (the
    /// registry's merge path re-compressing a small incoming sketch).
    pub fn from_dense(sketch: &HllSketch) -> Self {
        let sorted: Vec<u64> = sketch
            .registers()
            .iter()
            .enumerate()
            .filter(|(_, &r)| r != 0)
            .map(|(idx, &r)| encode(idx, r))
            .collect();
        Self { cfg: *sketch.config(), sorted, staging: Vec::new(), staging_cap: 256 }
    }

    pub fn config(&self) -> &HllConfig {
        &self.cfg
    }

    /// Number of distinct indices currently tracked, counting across
    /// both the sorted run and the unsorted staging buffer without
    /// mutating either — so `len`, [`SparseHll::is_empty`] and
    /// [`SparseHll::memory_bytes`] are all consistent read-only views of
    /// the same state (previously `len` forced a compaction and needed
    /// `&mut self`, while the other accessors saw pre-compaction state).
    pub fn len(&self) -> usize {
        if self.staging.is_empty() {
            return self.sorted.len();
        }
        let mut staged: Vec<u64> = self.staging.iter().map(|e| e >> 8).collect();
        staged.sort_unstable();
        staged.dedup();
        let fresh = staged
            .iter()
            .filter(|&&idx| self.sorted.binary_search_by_key(&idx, |e| e >> 8).is_err())
            .count();
        self.sorted.len() + fresh
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty() && self.staging.is_empty()
    }

    /// Approximate heap bytes used — the promotion policy input.
    pub fn memory_bytes(&self) -> usize {
        (self.sorted.capacity() + self.staging.capacity()) * std::mem::size_of::<u64>()
    }

    pub fn insert_hash(&mut self, hash: u64) {
        // Same split as the dense and concurrent paths, by construction.
        let (idx, rank) = self.cfg.split_hash(hash);
        self.staging.push(encode(idx, rank));
        if self.staging.len() >= self.staging_cap {
            self.compact();
        }
    }

    /// Insert a run of pre-computed hashes. State-identical to a loop of
    /// [`SparseHll::insert_hash`] (same staging/compaction cadence, so
    /// capacity-driven promotion decisions are unchanged), but the
    /// split/encode body is a tight loop with the config fields hoisted.
    pub fn insert_hashes(&mut self, hashes: &[u64]) {
        let w_bits = self.cfg.w_bits();
        let mask = (1u64 << w_bits) - 1;
        for &h in hashes {
            let idx = (h >> w_bits) as usize;
            let rank = crate::util::bits::rho(h & mask, w_bits);
            self.staging.push(encode(idx, rank));
            if self.staging.len() >= self.staging_cap {
                self.compact();
            }
        }
    }

    /// Visit every live (bucket index, max rank) entry after compacting —
    /// proportional to live entries, not to m. Used by the registry's
    /// bulk merge so sparse keys don't get densified just to be folded.
    pub fn for_each_entry<F: FnMut(usize, u8)>(&mut self, mut f: F) {
        self.compact();
        for &e in &self.sorted {
            let (idx, rank) = decode(e);
            f(idx, rank);
        }
    }

    /// Merge staging into the sorted run, keeping max rank per index.
    fn compact(&mut self) {
        if self.staging.is_empty() {
            return;
        }
        self.staging.sort_unstable_by_key(|&e| (e >> 8, std::cmp::Reverse(e & 0xFF)));
        let mut merged = Vec::with_capacity(self.sorted.len() + self.staging.len());
        let mut i = 0;
        let mut j = 0;
        let take_max = |merged: &mut Vec<u64>, e: u64| {
            match merged.last_mut() {
                Some(last) if *last >> 8 == e >> 8 => {
                    if (e & 0xFF) > (*last & 0xFF) {
                        *last = e;
                    }
                }
                _ => merged.push(e),
            }
        };
        while i < self.sorted.len() && j < self.staging.len() {
            if self.sorted[i] >> 8 <= self.staging[j] >> 8 {
                take_max(&mut merged, self.sorted[i]);
                i += 1;
            } else {
                take_max(&mut merged, self.staging[j]);
                j += 1;
            }
        }
        merged.extend(self.sorted[i..].iter().copied());
        for &e in &self.staging[j..] {
            take_max(&mut merged, e);
        }
        // The tail extend above may have appended duplicates of the last
        // staging index; normalize with a final dedup pass by index.
        let mut out: Vec<u64> = Vec::with_capacity(merged.len());
        for e in merged {
            take_max(&mut out, e);
        }
        self.sorted = out;
        self.staging.clear();
    }

    /// Materialize the equivalent dense sketch.
    pub fn to_dense(&mut self) -> HllSketch {
        self.compact();
        let mut regs = vec![0u8; self.cfg.m()];
        for &e in &self.sorted {
            let (idx, rank) = decode(e);
            if rank > regs[idx] {
                regs[idx] = rank;
            }
        }
        HllSketch::from_registers(self.cfg, regs).expect("sparse entries are in range")
    }

    /// Register-value histogram (the Ertl sufficient statistic) without
    /// densifying: the m − len untracked buckets are the zero bucket.
    pub fn register_histogram(&mut self) -> Vec<u32> {
        self.compact();
        let mut hist = vec![0u32; self.cfg.max_rank() as usize + 1];
        hist[0] = (self.cfg.m() - self.sorted.len()) as u32;
        for &e in &self.sorted {
            hist[(e & 0xFF) as usize] += 1;
        }
        hist
    }

    /// Cardinality estimate with the default estimator.
    pub fn estimate(&mut self) -> f64 {
        self.estimate_with(EstimatorKind::default())
    }

    /// Cardinality estimate with an explicit estimator. The Ertl path is
    /// a pure function of the histogram, so it is bit-identical to the
    /// dense and packed tiers' estimates of the same state; the legacy
    /// path keeps the historical exact-LinearCounting shortcut.
    pub fn estimate_with(&mut self, kind: EstimatorKind) -> f64 {
        match kind {
            EstimatorKind::Ertl => {
                let hist = self.register_histogram();
                ertl_estimate_from_histogram(&self.cfg, &hist)
            }
            EstimatorKind::Legacy => {
                self.compact();
                let m = self.cfg.m();
                let v = m - self.sorted.len();
                if v == 0 {
                    return self.to_dense().estimate_with(kind);
                }
                super::estimate::linear_counting(m, v)
            }
        }
    }
}

impl AdaptiveSketch {
    pub fn new(cfg: HllConfig) -> Self {
        AdaptiveSketch::Sparse(SparseHll::new(cfg))
    }

    /// Wrap an incoming dense register file in the most compact tier
    /// that holds it losslessly — the registry's path for sketches
    /// arriving by merge, snapshot restore or replication.
    pub fn from_dense(sketch: HllSketch) -> Self {
        let occupied = sketch.registers().iter().filter(|&&r| r != 0).count();
        if occupied * std::mem::size_of::<u64>() <= PackedHll::base_bytes(sketch.config()) {
            return AdaptiveSketch::Sparse(SparseHll::from_dense(&sketch));
        }
        let packed = PackedHll::from_dense(&sketch);
        if packed.exception_overflow() {
            AdaptiveSketch::Dense(sketch)
        } else {
            AdaptiveSketch::Packed(packed)
        }
    }

    pub fn config(&self) -> &HllConfig {
        match self {
            AdaptiveSketch::Sparse(s) => s.config(),
            AdaptiveSketch::Packed(p) => p.config(),
            AdaptiveSketch::Dense(d) => d.config(),
        }
    }

    /// Packed footprint the sparse mode must stay under to pay off.
    fn sparse_promotion_threshold(&self) -> usize {
        PackedHll::base_bytes(self.config())
    }

    pub fn insert_hash(&mut self, hash: u64) {
        match self {
            AdaptiveSketch::Dense(d) => d.insert_hash(hash),
            AdaptiveSketch::Packed(p) => {
                p.insert_hash_changed(hash);
                self.check_packed_overflow();
            }
            AdaptiveSketch::Sparse(s) => {
                s.insert_hash(hash);
                if s.memory_bytes() > self.sparse_promotion_threshold() {
                    self.promote_sparse();
                }
            }
        }
    }

    /// Insert a run of pre-computed hashes, promoting tiers mid-run
    /// exactly as a loop of [`AdaptiveSketch::insert_hash`] would: the
    /// sparse phase bulk-appends in chunks with a promotion check per
    /// chunk, the packed phase watches for exception overflow after
    /// every store (a length compare) and re-tiers on the spot, and the
    /// dense phase is one uninterruptible max-store loop. Lossless tier
    /// promotions make the final register state identical to the
    /// word-at-a-time path regardless of where inside the run a
    /// promotion lands.
    pub fn insert_hashes(&mut self, hashes: &[u64]) {
        let thr = self.sparse_promotion_threshold();
        let mut rest = hashes;
        while !rest.is_empty() {
            match self {
                AdaptiveSketch::Sparse(s) => {
                    let take = rest.len().min(SPARSE_BATCH_CHUNK);
                    s.insert_hashes(&rest[..take]);
                    rest = &rest[take..];
                    if s.memory_bytes() > thr {
                        self.promote_sparse();
                    }
                }
                AdaptiveSketch::Packed(p) => {
                    let mut consumed = 0;
                    for &h in rest {
                        p.insert_hash_changed(h);
                        consumed += 1;
                        if p.exception_overflow() {
                            break;
                        }
                    }
                    rest = &rest[consumed..];
                    self.check_packed_overflow();
                }
                AdaptiveSketch::Dense(d) => {
                    d.insert_hashes(rest);
                    return;
                }
            }
        }
    }

    /// As [`AdaptiveSketch::insert_hashes`], capturing raised register
    /// indices for dirty tracking: packed/dense stores push every raised
    /// index into `changed` (duplicates possible; the caller dedups once
    /// per batch), and the run reports [`BatchOutcome::Untracked`] if any
    /// word was inserted while the sketch was still sparse — the batch
    /// analogue of [`InsertOutcome::Untracked`], meaning the caller must
    /// capture the whole key. One call per key-run replaces one
    /// [`AdaptiveSketch::insert_hash_traced`] call per word.
    pub fn insert_hashes_traced(&mut self, hashes: &[u64], changed: &mut Vec<u32>) -> BatchOutcome {
        let thr = self.sparse_promotion_threshold();
        let mut rest = hashes;
        let mut sparse_seen = false;
        while !rest.is_empty() {
            match self {
                AdaptiveSketch::Sparse(s) => {
                    sparse_seen = true;
                    let take = rest.len().min(SPARSE_BATCH_CHUNK);
                    s.insert_hashes(&rest[..take]);
                    rest = &rest[take..];
                    if s.memory_bytes() > thr {
                        self.promote_sparse();
                    }
                }
                AdaptiveSketch::Packed(p) => {
                    let mut consumed = 0;
                    for &h in rest {
                        if let Some(idx) = p.insert_hash_changed(h) {
                            changed.push(idx);
                        }
                        consumed += 1;
                        if p.exception_overflow() {
                            break;
                        }
                    }
                    rest = &rest[consumed..];
                    self.check_packed_overflow();
                }
                AdaptiveSketch::Dense(d) => {
                    d.insert_hashes_changed(rest, changed);
                    break;
                }
            }
        }
        if sparse_seen {
            BatchOutcome::Untracked
        } else {
            BatchOutcome::Tracked
        }
    }

    /// As [`AdaptiveSketch::insert_hash`], reporting what the insert
    /// did (see [`InsertOutcome`]). Packed and dense sketches report the
    /// raised register exactly; sparse ones report
    /// [`InsertOutcome::Untracked`] — their staging buffer cannot tell a
    /// fresh max from a duplicate without a compaction per insert, and a
    /// sparse key's full resend is cheap in the only place the
    /// distinction matters (replication delta capture). A packed→dense
    /// promotion preserves every register value, so outcomes reported
    /// before the promotion stay valid.
    pub fn insert_hash_traced(&mut self, hash: u64) -> InsertOutcome {
        match self {
            AdaptiveSketch::Dense(d) => {
                return match d.insert_hash_changed(hash) {
                    Some(idx) => InsertOutcome::RegisterChanged(idx),
                    None => InsertOutcome::Unchanged,
                };
            }
            AdaptiveSketch::Packed(p) => {
                let outcome = match p.insert_hash_changed(hash) {
                    Some(idx) => InsertOutcome::RegisterChanged(idx),
                    None => InsertOutcome::Unchanged,
                };
                self.check_packed_overflow();
                return outcome;
            }
            AdaptiveSketch::Sparse(_) => {}
        }
        // Sparse path (runs the promotion check like a plain insert).
        self.insert_hash(hash);
        InsertOutcome::Untracked
    }

    /// Apply a decoded register diff (bucket-wise max) — the follower's
    /// per-key apply path for `RegisterDiff` delta entries. Diffs are
    /// only ever produced by register-tracking tiers (packed or dense),
    /// so a sparse receiver promotes to packed first (mirroring the
    /// primary's in-memory state).
    pub fn apply_register_diff(&mut self, entries: &[(u32, u8)]) {
        if self.is_sparse() {
            self.promote_sparse();
        }
        match self {
            AdaptiveSketch::Dense(d) => d.apply_register_diff(entries),
            AdaptiveSketch::Packed(p) => {
                for &(idx, val) in entries {
                    p.update_register(idx as usize, val);
                }
                self.check_packed_overflow();
            }
            AdaptiveSketch::Sparse(_) => unreachable!(),
        }
    }

    pub fn insert_u32(&mut self, v: u32) {
        // Hash straight from the config — the sparse arm used to build a
        // throwaway dense HllSketch (a 2^p-byte allocation) per insert
        // just to call its hash method.
        let h = self.config().hash_word(v);
        self.insert_hash(h);
    }

    /// Approximate heap bytes held by this sketch — the registry's
    /// memory-accounting input. Dense sketches report their register
    /// file; sparse and packed ones their buffers.
    pub fn memory_bytes(&self) -> usize {
        match self {
            AdaptiveSketch::Sparse(s) => s.memory_bytes(),
            AdaptiveSketch::Packed(p) => p.memory_bytes(),
            AdaptiveSketch::Dense(d) => d.config().m(),
        }
    }

    /// Sparse→Packed promotion (or straight to Dense for pathological
    /// register distributions no window covers).
    fn promote_sparse(&mut self) {
        if let AdaptiveSketch::Sparse(s) = self {
            let dense = s.to_dense();
            let packed = PackedHll::from_dense(&dense);
            *self = if packed.exception_overflow() {
                AdaptiveSketch::Dense(dense)
            } else {
                AdaptiveSketch::Packed(packed)
            };
        }
    }

    /// Packed→Dense promotion check: on exception overflow, first try
    /// re-centering the delta window (cheap, O(m)); only if the list
    /// stays oversized does the sketch densify. Register values are
    /// preserved exactly either way.
    fn check_packed_overflow(&mut self) {
        if let AdaptiveSketch::Packed(p) = self {
            if p.exception_overflow() {
                p.rebase();
                if p.exception_overflow() {
                    let dense = p.to_dense();
                    *self = AdaptiveSketch::Dense(dense);
                }
            }
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, AdaptiveSketch::Sparse(_))
    }

    pub fn is_packed(&self) -> bool {
        matches!(self, AdaptiveSketch::Packed(_))
    }

    /// Current value of one register, for tiers that track registers
    /// individually (`None` for sparse — the caller falls back to a full
    /// capture, exactly as with [`InsertOutcome::Untracked`]).
    pub fn register_value(&self, idx: usize) -> Option<u8> {
        match self {
            AdaptiveSketch::Sparse(_) => None,
            AdaptiveSketch::Packed(p) => Some(p.read_register(idx)),
            AdaptiveSketch::Dense(d) => Some(d.registers()[idx]),
        }
    }

    pub fn estimate(&mut self) -> f64 {
        self.estimate_with(EstimatorKind::default())
    }

    /// Estimate with an explicit estimator. Under [`EstimatorKind::Ertl`]
    /// the result is a pure function of the register histogram, so all
    /// three tiers agree bit-for-bit on equal state.
    pub fn estimate_with(&mut self, kind: EstimatorKind) -> f64 {
        match self {
            AdaptiveSketch::Sparse(s) => s.estimate_with(kind),
            AdaptiveSketch::Packed(p) => p.estimate_with(kind).estimate,
            AdaptiveSketch::Dense(d) => d.estimate_with(kind),
        }
    }

    /// Convert to dense unconditionally (needed before merging with a
    /// dense partner and for wire export). Consumes in place: an
    /// already-dense sketch moves its register file out instead of
    /// cloning it.
    pub fn into_dense(self) -> HllSketch {
        match self {
            AdaptiveSketch::Sparse(mut s) => s.to_dense(),
            AdaptiveSketch::Packed(p) => p.to_dense(),
            AdaptiveSketch::Dense(d) => d,
        }
    }

    pub fn merge_into(&mut self, other: AdaptiveSketch) -> Result<(), SketchError> {
        let other = other.into_dense();
        self.upgrade_to_dense_in_place();
        match self {
            AdaptiveSketch::Dense(d) => d.merge(&other),
            _ => unreachable!(),
        }
    }

    fn upgrade_to_dense_in_place(&mut self) {
        let dense = match self {
            AdaptiveSketch::Dense(_) => return,
            AdaptiveSketch::Sparse(s) => s.to_dense(),
            AdaptiveSketch::Packed(p) => p.to_dense(),
        };
        *self = AdaptiveSketch::Dense(dense);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::config::HashKind;
    use crate::util::Xoshiro256StarStar;

    fn cfg() -> HllConfig {
        HllConfig::new(16, HashKind::H64).unwrap()
    }

    #[test]
    fn sparse_matches_dense_exactly() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(1);
        let mut sparse = SparseHll::new(cfg());
        let mut dense = HllSketch::new(cfg());
        for _ in 0..3000 {
            let v = rng.next_u32();
            dense.insert_u32(v);
            sparse.insert_hash(dense.hash_u32(v));
        }
        assert_eq!(sparse.to_dense(), dense);
    }

    #[test]
    fn sparse_estimate_small_range_accurate() {
        let mut sparse = SparseHll::new(cfg());
        let dense_probe = HllSketch::new(cfg());
        for v in 0..1000u32 {
            sparse.insert_hash(dense_probe.hash_u32(v));
        }
        let e = sparse.estimate();
        assert!((e - 1000.0).abs() / 1000.0 < 0.05, "est {e}");
    }

    #[test]
    fn ertl_estimate_is_tier_invariant() {
        // The same logical state must estimate bit-identically from all
        // three representations (the estimate is a pure function of the
        // histogram).
        let mut rng = Xoshiro256StarStar::seed_from_u64(21);
        let mut sparse = SparseHll::new(cfg());
        let mut dense = HllSketch::new(cfg());
        for _ in 0..2_500 {
            let v = rng.next_u32();
            dense.insert_u32(v);
            sparse.insert_hash(dense.hash_u32(v));
        }
        let packed = PackedHll::from_dense(&dense);
        assert_eq!(sparse.estimate(), dense.estimate());
        assert_eq!(packed.estimate(), dense.estimate());
    }

    #[test]
    fn adaptive_promotes_sparse_to_packed_then_stays_packed() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(2);
        let mut a = AdaptiveSketch::new(cfg());
        assert!(a.is_sparse());
        for _ in 0..50_000 {
            a.insert_u32(rng.next_u32());
        }
        assert!(!a.is_sparse(), "should have been promoted");
        // At p=16 and 50k distinct, register values hug the window: the
        // packed tier holds with a fraction of the dense footprint.
        assert!(a.is_packed(), "50k keys at p=16 fit the packed tier");
        assert!(a.memory_bytes() * 2 < a.config().m());
    }

    #[test]
    fn adaptive_promotes_packed_to_dense_on_exception_overflow() {
        // A bimodal register file (half zeros, half high values) defeats
        // every 7-wide window; after rebase fails the sketch must land
        // dense, losslessly.
        let c = HllConfig::new(6, HashKind::H64).unwrap();
        let mut a = AdaptiveSketch::new(c);
        // Drive past the sparse threshold with alternating high ranks.
        for idx in 0..c.m() {
            let rank = if idx % 2 == 0 { 12u8 } else { 1 };
            // Craft a hash that lands in bucket `idx` with rank `rank`:
            // top p bits select the bucket, low bits set the rank.
            let w_bits = 64 - c.p() as u32;
            let w = 1u64 << (w_bits - rank as u32);
            let h = ((idx as u64) << w_bits) | w;
            for _ in 0..20 {
                a.insert_hash(h);
            }
        }
        assert!(!a.is_sparse() && !a.is_packed(), "bimodal file must densify");
        let d = a.into_dense();
        assert_eq!(d.registers().iter().filter(|&&r| r == 12).count(), c.m() / 2);
    }

    #[test]
    fn adaptive_equals_plain_dense() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(3);
        let mut a = AdaptiveSketch::new(cfg());
        let mut d = HllSketch::new(cfg());
        for _ in 0..30_000 {
            let v = rng.next_u32();
            a.insert_u32(v);
            d.insert_u32(v);
        }
        assert_eq!(a.into_dense(), d);
    }

    #[test]
    fn adaptive_merge() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(4);
        let mut a = AdaptiveSketch::new(cfg());
        let mut b = AdaptiveSketch::new(cfg());
        let mut all = HllSketch::new(cfg());
        for i in 0..10_000 {
            let v = rng.next_u32();
            if i % 2 == 0 {
                a.insert_u32(v);
            } else {
                b.insert_u32(v);
            }
            all.insert_u32(v);
        }
        a.merge_into(b).unwrap();
        assert_eq!(a.into_dense(), all);
    }

    #[test]
    fn from_dense_picks_the_most_compact_tier() {
        // Nearly empty → sparse.
        let mut small = HllSketch::new(cfg());
        for v in 0..50u32 {
            small.insert_u32(v);
        }
        let a = AdaptiveSketch::from_dense(small.clone());
        assert!(a.is_sparse());
        assert_eq!(a.into_dense(), small);
        // Well occupied → packed.
        let mut rng = Xoshiro256StarStar::seed_from_u64(6);
        let mut big = HllSketch::new(cfg());
        for _ in 0..60_000 {
            big.insert_u32(rng.next_u32());
        }
        let a = AdaptiveSketch::from_dense(big.clone());
        assert!(a.is_packed());
        assert_eq!(a.into_dense(), big);
    }

    #[test]
    fn traced_inserts_match_plain_inserts_and_report_outcomes() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(9);
        let mut traced = AdaptiveSketch::new(cfg());
        let mut plain = AdaptiveSketch::new(cfg());
        let c = *traced.config();
        let mut saw_untracked = false;
        let mut saw_tracked = false;
        for _ in 0..60_000 {
            let h = c.hash_word(rng.next_u32());
            plain.insert_hash(h);
            match traced.insert_hash_traced(h) {
                InsertOutcome::Untracked => saw_untracked = true,
                InsertOutcome::RegisterChanged(idx) => {
                    saw_tracked = true;
                    // The reported register really holds this hash's rank
                    // (or better, later).
                    assert!((idx as usize) < c.m());
                    let (_, rank) = c.split_hash(h);
                    assert!(traced.register_value(idx as usize).unwrap() >= rank);
                }
                InsertOutcome::Unchanged => {}
            }
        }
        assert!(saw_untracked, "sparse phase must report Untracked");
        assert!(saw_tracked, "packed/dense phase must report changed registers");
        assert!(!traced.is_sparse());
        assert_eq!(traced.into_dense(), plain.into_dense());
    }

    #[test]
    fn batch_insert_matches_scalar_across_all_tier_promotions() {
        // One batch large enough to drive Sparse → Packed (and, with the
        // crafted bimodal tail below, → Dense) must land bit-identical
        // to the word-at-a-time path.
        let mut rng = Xoshiro256StarStar::seed_from_u64(11);
        let c = cfg();
        let hashes: Vec<u64> = (0..60_000).map(|_| c.hash_word(rng.next_u32())).collect();
        let mut batched = AdaptiveSketch::new(c);
        let mut scalar = AdaptiveSketch::new(c);
        batched.insert_hashes(&hashes);
        for &h in &hashes {
            scalar.insert_hash(h);
        }
        assert!(!batched.is_sparse(), "60k distinct must promote");
        assert_eq!(batched.into_dense(), scalar.into_dense());
    }

    #[test]
    fn batch_traced_matches_scalar_traced_states_and_outcomes() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(12);
        let c = cfg();
        let mut batched = AdaptiveSketch::new(c);
        let mut scalar = AdaptiveSketch::new(c);
        let mut saw_untracked = false;
        let mut saw_tracked = false;
        // Feed in mid-sized runs so some batch straddles the sparse →
        // packed promotion.
        for round in 0..40 {
            let hashes: Vec<u64> =
                (0..1500).map(|_| c.hash_word(rng.next_u32())).collect();
            let mut batch_changed: Vec<u32> = Vec::new();
            let outcome = batched.insert_hashes_traced(&hashes, &mut batch_changed);
            let mut scalar_changed: Vec<u32> = Vec::new();
            let mut scalar_untracked = false;
            for &h in &hashes {
                match scalar.insert_hash_traced(h) {
                    InsertOutcome::RegisterChanged(idx) => scalar_changed.push(idx),
                    InsertOutcome::Untracked => scalar_untracked = true,
                    InsertOutcome::Unchanged => {}
                }
            }
            match outcome {
                BatchOutcome::Untracked => {
                    saw_untracked = true;
                    assert!(scalar_untracked, "round {round}: scalar path saw no sparse phase");
                }
                BatchOutcome::Tracked => {
                    saw_tracked = true;
                    assert!(!scalar_untracked, "round {round}: scalar path saw a sparse phase");
                    // Identical raised-register sets (order/duplicates
                    // aside — callers dedup per batch).
                    batch_changed.sort_unstable();
                    batch_changed.dedup();
                    scalar_changed.sort_unstable();
                    scalar_changed.dedup();
                    assert_eq!(batch_changed, scalar_changed, "round {round}");
                }
            }
        }
        assert!(saw_untracked && saw_tracked, "test must cover both outcome kinds");
        assert_eq!(batched.into_dense(), scalar.into_dense());
    }

    #[test]
    fn batch_insert_densifies_on_bimodal_overflow_like_scalar() {
        // Same crafted bimodal file as the scalar overflow test, driven
        // through the batch path in one call: must densify losslessly.
        let c = HllConfig::new(6, HashKind::H64).unwrap();
        let w_bits = 64 - c.p() as u32;
        let mut hashes = Vec::new();
        for idx in 0..c.m() {
            let rank = if idx % 2 == 0 { 12u8 } else { 1 };
            let w = 1u64 << (w_bits - rank as u32);
            let h = ((idx as u64) << w_bits) | w;
            for _ in 0..20 {
                hashes.push(h);
            }
        }
        let mut a = AdaptiveSketch::new(c);
        a.insert_hashes(&hashes);
        assert!(!a.is_sparse() && !a.is_packed(), "bimodal file must densify");
        let d = a.into_dense();
        assert_eq!(d.registers().iter().filter(|&&r| r == 12).count(), c.m() / 2);
    }

    #[test]
    fn sparse_batch_insert_matches_scalar_cadence() {
        let c = cfg();
        let probe = HllSketch::new(c);
        let hashes: Vec<u64> = (0..3000u32).map(|v| probe.hash_u32(v)).collect();
        let mut batched = SparseHll::new(c);
        let mut scalar = SparseHll::new(c);
        batched.insert_hashes(&hashes);
        for &h in &hashes {
            scalar.insert_hash(h);
        }
        // Identical compaction cadence ⇒ identical buffers and identical
        // capacity-driven memory accounting.
        assert_eq!(batched.to_dense(), scalar.to_dense());
        assert_eq!(batched.memory_bytes(), scalar.memory_bytes());
    }

    #[test]
    fn adaptive_apply_register_diff_promotes_and_max_merges() {
        let mut a = AdaptiveSketch::new(cfg());
        assert!(a.is_sparse());
        a.apply_register_diff(&[(3, 7), (100, 2)]);
        assert!(!a.is_sparse(), "diff apply mirrors the primary's register-tracking state");
        assert!(a.is_packed(), "a small diff lands in the packed tier");
        assert_eq!(a.register_value(3), Some(7));
        assert_eq!(a.register_value(100), Some(2));
        // Max semantics on a second diff.
        a.apply_register_diff(&[(3, 5), (100, 9)]);
        let d = a.into_dense();
        assert_eq!(d.registers()[3], 7);
        assert_eq!(d.registers()[100], 9);
        assert_eq!(d.registers().iter().filter(|&&r| r != 0).count(), 2);
    }

    #[test]
    fn len_is_read_only_and_compaction_invariant() {
        let mut sparse = SparseHll::new(cfg());
        let probe = HllSketch::new(cfg());
        for v in 0..300u32 {
            sparse.insert_hash(probe.hash_u32(v));
            sparse.insert_hash(probe.hash_u32(v)); // duplicate
        }
        // Read through a shared borrow: must not mutate.
        let shared: &SparseHll = &sparse;
        let before = shared.len();
        assert!(!shared.is_empty());
        let mem = shared.memory_bytes();
        assert!(mem > 0);
        // Forcing a compaction must not change the distinct-index count.
        let dense = sparse.to_dense();
        assert_eq!(sparse.len(), before);
        assert_eq!(
            dense.registers().iter().filter(|&&r| r != 0).count(),
            before,
            "len must equal the number of occupied dense buckets"
        );
    }

    #[test]
    fn compaction_dedups_staging_duplicates() {
        let mut sparse = SparseHll::new(cfg());
        let probe = HllSketch::new(cfg());
        // Insert the same few values repeatedly across compaction
        // boundaries.
        for _ in 0..10 {
            for v in 0..100u32 {
                sparse.insert_hash(probe.hash_u32(v));
            }
        }
        let n = sparse.len();
        assert!(n <= 100, "dedup failed: {n} entries for 100 values");
    }
}
