//! The HyperLogLog core library — Algorithm 1 of the paper, complete with
//! both hash widths, all correction branches, merge (Fig 3's fold),
//! memory-footprint analysis (Table II), a three-tier
//! sparse/packed/dense adaptive representation, and Ertl's improved
//! estimator alongside the paper's legacy range-split estimator.

pub mod concurrent;
pub mod config;
pub mod estimate;
pub mod murmur3;
pub mod packed;
pub mod setops;
pub mod sketch;
pub mod sparse;

pub use concurrent::ConcurrentHllSketch;
pub use config::{ConfigError, HashKind, HllConfig};
pub use estimate::{
    ertl_estimate_from_histogram, estimate, estimate_with, linear_counting, register_histogram,
    Correction, EstimateBreakdown, EstimatorKind,
};
pub use packed::PackedHll;
pub use setops::{intersection_cardinality, jaccard, union_cardinality};
pub use sketch::{
    decode_register_diff, diff_wire_len, encode_register_diff, HllSketch, SketchError,
    DIFF_WIRE_VERSION, WIRE_HEADER_LEN, WIRE_VERSION,
};
pub use sparse::{AdaptiveSketch, BatchOutcome, InsertOutcome, SparseHll};
