//! The HyperLogLog core library — Algorithm 1 of the paper, complete with
//! both hash widths, all correction branches, merge (Fig 3's fold),
//! memory-footprint analysis (Table II), and a sparse/adaptive extension.

pub mod concurrent;
pub mod config;
pub mod estimate;
pub mod murmur3;
pub mod setops;
pub mod sketch;
pub mod sparse;

pub use concurrent::ConcurrentHllSketch;
pub use config::{ConfigError, HashKind, HllConfig};
pub use estimate::{estimate, linear_counting, Correction, EstimateBreakdown};
pub use setops::{intersection_cardinality, jaccard, union_cardinality};
pub use sketch::{
    decode_register_diff, diff_wire_len, encode_register_diff, HllSketch, SketchError,
    DIFF_WIRE_VERSION, WIRE_HEADER_LEN, WIRE_VERSION,
};
pub use sparse::{AdaptiveSketch, InsertOutcome, SparseHll};
