//! PCIe / XDMA bridge model — the co-processor deployment's I/O bound
//! (Section VI): a Xilinx XDMA (PCIe 3.0 ×16) endpoint sustaining
//! 12.48 GByte/s of effective host→card bandwidth.
//!
//! The model is a rate limiter with per-transfer descriptor overhead:
//! enough to reproduce Fig 4(a)'s saturation behaviour (linear scaling
//! up to 10 pipelines, flat beyond) and to study DMA batch-size effects
//! in the ablation bench.

use crate::fpga::ClockDomain;

/// XDMA endpoint configuration.
#[derive(Debug, Clone, Copy)]
pub struct PcieLink {
    /// Effective payload bandwidth (bytes/s). The paper's measured
    /// envelope is 12.48 GByte/s for PCIe 3.0 ×16 via XDMA.
    pub bandwidth_bytes_per_s: f64,
    /// Fixed per-DMA-descriptor cost (doorbell + completion), seconds.
    pub descriptor_overhead_s: f64,
    /// The PCIe-side clock domain (250 MHz; Section VII).
    pub clock: ClockDomain,
}

impl Default for PcieLink {
    fn default() -> Self {
        Self::paper()
    }
}

impl PcieLink {
    /// The paper's link: PCIe 3.0 ×16, XDMA, 12.48 GByte/s effective.
    pub fn paper() -> Self {
        Self {
            bandwidth_bytes_per_s: 12.48e9,
            // ~1 µs per descriptor: doorbell write + completion interrupt
            // amortization, typical for XDMA polling mode.
            descriptor_overhead_s: 1e-6,
            clock: ClockDomain::PCIE,
        }
    }

    /// Time to move `bytes` in one DMA transfer.
    pub fn transfer_seconds(&self, bytes: u64) -> f64 {
        self.descriptor_overhead_s + bytes as f64 / self.bandwidth_bytes_per_s
    }

    /// Effective throughput moving a stream in `chunk`-byte DMA
    /// transfers (bytes/s) — the batching trade-off.
    pub fn effective_bandwidth(&self, chunk_bytes: u64) -> f64 {
        chunk_bytes as f64 / self.transfer_seconds(chunk_bytes)
    }
}

/// Co-processor deployment (Fig 4(a)): host streams the data set over
/// PCIe into the k-pipeline engine. End-to-end throughput is the min of
/// the link and compute rates, with the engine's drain epilogue.
#[derive(Debug, Clone, Copy)]
pub struct CoProcessorModel {
    pub link: PcieLink,
    /// DMA chunk size used by the host driver.
    pub chunk_bytes: u64,
}

impl Default for CoProcessorModel {
    fn default() -> Self {
        Self { link: PcieLink::paper(), chunk_bytes: 2 << 20 }
    }
}

/// Result of one modelled co-processor run.
#[derive(Debug, Clone, Copy)]
pub struct CoProcessorRun {
    pub bytes: u64,
    pub pcie_seconds: f64,
    pub compute_seconds: f64,
    pub drain_seconds: f64,
    pub total_seconds: f64,
}

impl CoProcessorRun {
    pub fn throughput_bytes_per_s(&self) -> f64 {
        self.bytes as f64 / self.total_seconds
    }
}

impl CoProcessorModel {
    /// Model streaming `bytes` of 32-bit words through k pipelines.
    /// PCIe transfers and pipeline processing are overlapped (the XDMA
    /// writes into the AXI4 stream while the engine consumes), so the
    /// steady-state rate is the min of the two; the drain epilogue is
    /// serialized after the last word.
    pub fn run(&self, cfg: &crate::hll::HllConfig, k: usize, bytes: u64) -> CoProcessorRun {
        let words = bytes / 4;
        let n_chunks = bytes.div_ceil(self.chunk_bytes.max(1));
        let pcie_seconds = bytes as f64 / self.link.bandwidth_bytes_per_s
            + n_chunks as f64 * self.link.descriptor_overhead_s;
        let compute_cycles = crate::fpga::timing_only_cycles(cfg, k, words);
        let drain_cycles = cfg.m() as u64 + 32;
        let clock = ClockDomain::NETWORK;
        let compute_seconds = clock.cycles_to_seconds(compute_cycles - drain_cycles);
        let drain_seconds = clock.cycles_to_seconds(drain_cycles);
        let total_seconds = pcie_seconds.max(compute_seconds) + drain_seconds;
        CoProcessorRun { bytes, pcie_seconds, compute_seconds, drain_seconds, total_seconds }
    }

    /// The pipeline count at which the engine saturates the link.
    pub fn saturation_pipelines(&self) -> usize {
        let per_pipe = crate::fpga::theoretical_throughput_bytes_per_s(1);
        (self.link.bandwidth_bytes_per_s / per_pipe).ceil() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::HllConfig;

    #[test]
    fn saturation_at_ten_pipelines() {
        // Section VI-A: 10 × 10.3 Gbit/s = 103 Gbit/s > 12.48 GByte/s.
        let m = CoProcessorModel::default();
        assert_eq!(m.saturation_pipelines(), 10);
    }

    #[test]
    fn throughput_scales_then_saturates() {
        let m = CoProcessorModel::default();
        let cfg = HllConfig::PAPER;
        let bytes = 1u64 << 30; // 1 GiB
        let mut prev = 0.0;
        for k in 1..=10 {
            let r = m.run(&cfg, k, bytes);
            let t = r.throughput_bytes_per_s();
            assert!(t > prev, "k={k} should improve: {t} vs {prev}");
            prev = t;
        }
        // Beyond saturation: no further gains (within 1%).
        let t10 = m.run(&cfg, 10, bytes).throughput_bytes_per_s();
        let t16 = m.run(&cfg, 16, bytes).throughput_bytes_per_s();
        assert!((t16 - t10).abs() / t10 < 0.01, "t10={t10} t16={t16}");
        // And the bound is the PCIe envelope.
        assert!(t16 <= 12.48e9);
        assert!(t16 > 0.95 * 12.48e9);
    }

    #[test]
    fn below_saturation_matches_theoretical() {
        let m = CoProcessorModel::default();
        let cfg = HllConfig::PAPER;
        let bytes = 1u64 << 30;
        for k in 1..=9 {
            let r = m.run(&cfg, k, bytes);
            let theory = crate::fpga::theoretical_throughput_bytes_per_s(k);
            let rel = (r.throughput_bytes_per_s() - theory).abs() / theory;
            assert!(rel < 0.01, "k={k}: {rel}");
        }
    }

    #[test]
    fn descriptor_overhead_penalizes_tiny_chunks() {
        let link = PcieLink::paper();
        assert!(link.effective_bandwidth(4 << 10) < 0.5 * link.bandwidth_bytes_per_s);
        assert!(link.effective_bandwidth(8 << 20) > 0.95 * link.bandwidth_bytes_per_s);
    }

    #[test]
    fn drain_is_constant_wrt_data_size() {
        let m = CoProcessorModel::default();
        let cfg = HllConfig::PAPER;
        let a = m.run(&cfg, 10, 1 << 20);
        let b = m.run(&cfg, 10, 1 << 30);
        assert_eq!(a.drain_seconds, b.drain_seconds);
        assert!((a.drain_seconds - 203e-6).abs() < 2e-6);
    }
}
