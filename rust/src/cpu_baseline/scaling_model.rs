//! Thread-scaling model for Fig 4(b).
//!
//! Substitution note (DESIGN.md §7): the paper measures on a dual-socket
//! Xeon E5-2630 v3 (16 cores / 32 hyper-threads); this container has a
//! single core, so the *shape* of the thread-scaling curve is modelled
//! analytically — linear speedup to the core count, a hyper-threading
//! bonus up to 2× threads, and a slight oversubscription penalty beyond
//! — and anchored either to the paper's own end points or to a measured
//! single-thread rate from this machine.
//!
//! The paper's numbers are mutually consistent and pin the model:
//! * 1 FPGA pipeline (1.288 GB/s) ≈ 2× one CPU thread  → r₁(32-bit) ≈ 0.64 GB/s;
//! * 64-bit hash runs at ≈ 60% of the 32-bit rate      → r₁(64-bit) ≈ 0.39 GB/s;
//! * 10 pipelines (12.48 GB/s PCIe-bound) ≈ 1.8× the 32-thread 64-bit
//!   CPU rate → R₆₄(32) ≈ 6.9 GB/s — which the model reproduces.

use crate::hll::HashKind;

/// Parameters of the analytic scaling curve.
#[derive(Debug, Clone, Copy)]
pub struct ScalingModel {
    /// Single-thread aggregation rate, bytes/s, for the 32-bit hash.
    pub r1_32: f64,
    /// Single-thread rate for the 64-bit hash.
    pub r1_64: f64,
    /// Physical cores.
    pub cores: usize,
    /// Hardware threads (2× cores with hyper-threading).
    pub hw_threads: usize,
    /// Aggregate speedup gained from hyper-threading (16→32 threads adds
    /// ~15% on this memory-light integer workload).
    pub ht_bonus: f64,
    /// Multiplicative throughput decay per doubling beyond hw_threads
    /// (the paper observes the curve "halts and even slightly reverses").
    pub oversub_decay: f64,
}

impl ScalingModel {
    /// The paper's machine: dual-socket Intel Xeon E5-2630 v3.
    pub fn paper_xeon() -> Self {
        Self {
            r1_32: 0.64e9,
            r1_64: 0.39e9,
            cores: 16,
            hw_threads: 32,
            ht_bonus: 0.15,
            oversub_decay: 0.97,
        }
    }

    /// Anchor the curve to a measured single-thread rate on the current
    /// machine (32-bit rate measured; 64-bit derived with the paper's
    /// 60% ratio unless measured too).
    pub fn calibrated(r1_32: f64, r1_64: f64, cores: usize) -> Self {
        Self {
            r1_32,
            r1_64,
            cores,
            hw_threads: cores * 2,
            ht_bonus: 0.15,
            oversub_decay: 0.97,
        }
    }

    /// Effective parallel speedup at `threads`.
    pub fn speedup(&self, threads: usize) -> f64 {
        let t = threads.max(1) as f64;
        let c = self.cores as f64;
        if threads <= self.cores {
            t
        } else if threads <= self.hw_threads {
            // Linear interpolation of the HT bonus across the second
            // hardware-thread set.
            let frac = (t - c) / (self.hw_threads as f64 - c);
            c * (1.0 + self.ht_bonus * frac)
        } else {
            // Oversubscription: context-switch overhead slowly erodes the
            // plateau.
            let doublings = (t / self.hw_threads as f64).log2();
            c * (1.0 + self.ht_bonus) * self.oversub_decay.powf(doublings)
        }
    }

    /// Modelled aggregation rate (bytes/s).
    pub fn rate(&self, hash: HashKind, threads: usize) -> f64 {
        let r1 = match hash {
            HashKind::H32 => self.r1_32,
            HashKind::H64 => self.r1_64,
        };
        r1 * self.speedup(threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_endpoints_reproduced() {
        let m = ScalingModel::paper_xeon();
        // 32 threads, 64-bit hash: the 1.8× claim against 12.48 GB/s.
        let r64 = m.rate(HashKind::H64, 32);
        let ratio = 12.48e9 / r64;
        assert!((ratio - 1.8).abs() < 0.1, "FPGA/CPU64 ratio {ratio}");
        // NIC claim: 9.35 GB/s ≈ 35% above the 16-core CPU rate.
        let nic_ratio = 9.35e9 / r64;
        assert!((nic_ratio - 1.35).abs() < 0.1, "NIC/CPU ratio {nic_ratio}");
        // Single pipeline ≈ 2× single thread (32-bit).
        let per_pipe = crate::fpga::theoretical_throughput_bytes_per_s(1);
        let r1_ratio = per_pipe / m.rate(HashKind::H32, 1);
        assert!((r1_ratio - 2.0).abs() < 0.1, "pipeline/thread ratio {r1_ratio}");
    }

    #[test]
    fn hash64_is_60pct_of_hash32() {
        let m = ScalingModel::paper_xeon();
        for t in [1usize, 8, 16, 32] {
            let ratio = m.rate(HashKind::H64, t) / m.rate(HashKind::H32, t);
            assert!((ratio - 0.6).abs() < 0.02, "t={t}: {ratio}");
        }
    }

    #[test]
    fn curve_shape_linear_plateau_dip() {
        let m = ScalingModel::paper_xeon();
        // Linear region.
        assert!((m.speedup(8) - 8.0).abs() < 1e-9);
        assert!((m.speedup(16) - 16.0).abs() < 1e-9);
        // HT plateau: 16→32 gains only the bonus.
        let s32 = m.speedup(32);
        assert!((s32 - 18.4).abs() < 0.01, "{s32}");
        // Oversubscription dips.
        assert!(m.speedup(64) < s32);
        assert!(m.speedup(64) > 0.9 * s32, "dip is slight");
    }

    #[test]
    fn monotone_up_to_hw_threads() {
        let m = ScalingModel::paper_xeon();
        for t in 1..32 {
            assert!(m.speedup(t + 1) > m.speedup(t), "t={t}");
        }
    }
}
