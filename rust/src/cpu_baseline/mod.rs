//! Optimized software HLL baseline — the paper's CPU comparison point
//! (Section VI-C): lane-batched (AVX2-analogue) Murmur3, thread-parallel
//! aggregation, and the Fig 4(b) thread-scaling model.

pub mod batched;
pub mod scaling_model;
pub mod threading;

pub use batched::{aggregate32_batched, aggregate64_batched, hash32_x8, hash64_x4};
pub use scaling_model::ScalingModel;
pub use threading::{aggregate_best, aggregate_parallel, measure_single_thread_rate};
