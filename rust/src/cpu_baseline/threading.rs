//! Thread-parallel aggregation — the paper's CPU baseline uses one
//! aggregation thread per core on a dual-socket Xeon (Section VI-C).
//!
//! Each thread owns a private sketch over its slice of the stream (HLL's
//! trivially parallel decomposition); partial sketches are merged at the
//! end — identical in structure to the FPGA's multi-pipeline + fold.

use crate::hll::{HashKind, HllConfig, HllSketch};

use super::batched::{aggregate32_batched, aggregate64_batched};

/// Aggregate `words` across `threads` OS threads; returns the merged
/// sketch and the wall time of the parallel section.
pub fn aggregate_parallel(
    cfg: HllConfig,
    words: &[u32],
    threads: usize,
) -> (HllSketch, std::time::Duration) {
    assert!(threads >= 1);
    let t0 = std::time::Instant::now();
    if threads == 1 {
        let mut s = HllSketch::new(cfg);
        aggregate_best(&mut s, words);
        return (s, t0.elapsed());
    }
    let chunk = words.len().div_ceil(threads);
    let mut parts: Vec<HllSketch> = std::thread::scope(|scope| {
        let handles: Vec<_> = words
            .chunks(chunk.max(1))
            .map(|slice| {
                scope.spawn(move || {
                    let mut s = HllSketch::new(cfg);
                    aggregate_best(&mut s, slice);
                    s
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    let mut merged = parts.pop().unwrap_or_else(|| HllSketch::new(cfg));
    for p in &parts {
        merged.merge(p).expect("same config");
    }
    (merged, t0.elapsed())
}

/// Pick the fastest single-thread path for the config (lane-batched).
pub fn aggregate_best(sketch: &mut HllSketch, words: &[u32]) {
    match sketch.config().hash() {
        HashKind::H32 => aggregate32_batched(words, sketch),
        HashKind::H64 => aggregate64_batched(words, sketch),
    }
}

/// Measure this machine's single-thread aggregation rate (bytes/s) for a
/// hash width — the calibration input for the Fig 4(b) scaling model.
pub fn measure_single_thread_rate(hash: HashKind, sample_words: usize) -> f64 {
    let cfg = HllConfig::new(16, hash).unwrap();
    let mut rng = crate::util::Xoshiro256StarStar::seed_from_u64(0x5EED);
    let words: Vec<u32> = (0..sample_words).map(|_| rng.next_u32()).collect();
    // Warm-up pass, then timed pass.
    let mut s = HllSketch::new(cfg);
    aggregate_best(&mut s, &words);
    let mut s = HllSketch::new(cfg);
    let t0 = std::time::Instant::now();
    aggregate_best(&mut s, &words);
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(s.estimate());
    (sample_words * 4) as f64 / dt
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256StarStar;

    #[test]
    fn parallel_equals_serial_any_thread_count() {
        let cfg = HllConfig::PAPER;
        let mut rng = Xoshiro256StarStar::seed_from_u64(4);
        let words: Vec<u32> = (0..40_000).map(|_| rng.next_u32()).collect();
        let mut serial = HllSketch::new(cfg);
        serial.insert_batch(&words);
        for threads in [1usize, 2, 3, 8] {
            let (merged, _) = aggregate_parallel(cfg, &words, threads);
            assert_eq!(merged, serial, "threads={threads}");
        }
    }

    #[test]
    fn parallel_handles_tiny_inputs() {
        let cfg = HllConfig::PAPER;
        let (s, _) = aggregate_parallel(cfg, &[], 4);
        assert_eq!(s.zero_registers(), cfg.m());
        let (s, _) = aggregate_parallel(cfg, &[42], 8);
        assert_eq!(s.zero_registers(), cfg.m() - 1);
    }

    #[test]
    fn measured_rate_is_positive() {
        let r = measure_single_thread_rate(HashKind::H64, 100_000);
        assert!(r > 1e6, "suspiciously slow: {r} B/s");
    }
}
