//! Lane-batched hashing — the software analogue of the paper's AVX2
//! implementation (Section VI-C).
//!
//! The paper vectorizes the 32-bit Murmur3 8-wide with AVX2; the 64-bit
//! hash gains nothing from 4-wide vectorization because AVX2 has no
//! native 64×64-bit vector multiply. Stable Rust without `std::simd`
//! expresses the same structure as fixed-width unrolled lanes, which the
//! compiler auto-vectorizes where profitable — and, as in the paper, the
//! 64-bit path stays effectively scalar, reproducing the ≈ 60% rate
//! ratio.

use crate::hll::murmur3::{murmur3_x64_64_u32, murmur3_x86_32_u32};
use crate::hll::{HashKind, HllSketch};
use crate::util::bits::rho;

/// 8-lane unrolled 32-bit Murmur3 (AVX2-style).
#[inline]
pub fn hash32_x8(keys: &[u32; 8], seed: u32) -> [u32; 8] {
    // Straight-line code over 8 independent lanes; LLVM vectorizes this
    // to AVX2 `vpmulld`/`vprold`-style sequences on x86.
    let mut out = [0u32; 8];
    for i in 0..8 {
        out[i] = murmur3_x86_32_u32(keys[i], seed);
    }
    out
}

/// 4-lane unrolled 64-bit Murmur3 (the paper found this not beneficial;
/// kept for the ablation bench that demonstrates exactly that).
#[inline]
pub fn hash64_x4(keys: &[u32; 4], seed: u64) -> [u64; 4] {
    let mut out = [0u64; 4];
    for i in 0..4 {
        out[i] = murmur3_x64_64_u32(keys[i], seed);
    }
    out
}

/// Aggregate a word stream with the 8-lane 32-bit path.
pub fn aggregate32_batched(words: &[u32], sketch: &mut HllSketch) {
    assert_eq!(sketch.config().hash(), HashKind::H32);
    let seed = sketch.config().seed() as u32;
    let p = sketch.config().p() as u32;
    let w_bits = 32 - p;
    let mask = (1u32 << w_bits) - 1;

    let mut chunks = words.chunks_exact(8);
    // Collect indices/ranks per lane group, then update registers — the
    // separation keeps the hash loop vectorizable.
    let mut pending = [(0usize, 0u8); 8];
    for chunk in &mut chunks {
        let keys: &[u32; 8] = chunk.try_into().unwrap();
        let hashes = hash32_x8(keys, seed);
        for (slot, &h) in pending.iter_mut().zip(&hashes) {
            let idx = (h >> w_bits) as usize;
            let w = h & mask;
            *slot = (idx, rho(w as u64, w_bits));
        }
        for &(idx, rank) in &pending {
            apply(sketch, idx, rank);
        }
    }
    for &w in chunks.remainder() {
        let h = murmur3_x86_32_u32(w, seed);
        let idx = (h >> w_bits) as usize;
        apply(sketch, idx, rho((h & mask) as u64, w_bits));
    }
}

/// Aggregate with the 4-lane 64-bit path.
pub fn aggregate64_batched(words: &[u32], sketch: &mut HllSketch) {
    assert_eq!(sketch.config().hash(), HashKind::H64);
    let seed = sketch.config().seed();
    let p = sketch.config().p() as u32;
    let w_bits = 64 - p;
    let mask = (1u64 << w_bits) - 1;

    let mut chunks = words.chunks_exact(4);
    for chunk in &mut chunks {
        let keys: &[u32; 4] = chunk.try_into().unwrap();
        let hashes = hash64_x4(keys, seed);
        for &h in &hashes {
            let idx = (h >> w_bits) as usize;
            apply(sketch, idx, rho(h & mask, w_bits));
        }
    }
    for &w in chunks.remainder() {
        let h = murmur3_x64_64_u32(w, seed);
        let idx = (h >> w_bits) as usize;
        apply(sketch, idx, rho(h & mask, w_bits));
    }
}

#[inline(always)]
fn apply(sketch: &mut HllSketch, idx: usize, rank: u8) {
    // Registers are private to the sketch; go through the public
    // insert-by-hash API equivalently. To avoid re-hashing we poke the
    // register file directly via the merge-free update helper.
    sketch.update_register(idx, rank);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::HllConfig;
    use crate::util::Xoshiro256StarStar;

    #[test]
    fn batched32_equals_scalar() {
        let cfg = HllConfig::new(16, HashKind::H32).unwrap();
        let mut rng = Xoshiro256StarStar::seed_from_u64(2);
        let words: Vec<u32> = (0..10_003).map(|_| rng.next_u32()).collect(); // odd len
        let mut a = HllSketch::new(cfg);
        let mut b = HllSketch::new(cfg);
        aggregate32_batched(&words, &mut a);
        b.insert_batch(&words);
        assert_eq!(a, b);
    }

    #[test]
    fn batched64_equals_scalar() {
        let cfg = HllConfig::new(16, HashKind::H64).unwrap();
        let mut rng = Xoshiro256StarStar::seed_from_u64(3);
        let words: Vec<u32> = (0..9_999).map(|_| rng.next_u32()).collect();
        let mut a = HllSketch::new(cfg);
        let mut b = HllSketch::new(cfg);
        aggregate64_batched(&words, &mut a);
        b.insert_batch(&words);
        assert_eq!(a, b);
    }

    #[test]
    fn lane_functions_match_scalar_hash() {
        let keys = [1u32, 2, 0xdeadbeef, u32::MAX, 5, 6, 7, 8];
        let h8 = hash32_x8(&keys, 0);
        for (k, h) in keys.iter().zip(&h8) {
            assert_eq!(*h, murmur3_x86_32_u32(*k, 0));
        }
        let k4 = [9u32, 10, 11, 12];
        let h4 = hash64_x4(&k4, 0);
        for (k, h) in k4.iter().zip(&h4) {
            assert_eq!(*h, murmur3_x64_64_u32(*k, 0));
        }
    }
}
