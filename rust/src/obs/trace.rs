//! Request-scoped tracing primitives: pipeline stages, trace IDs, the
//! compact binary [`TraceEvent`] the flight recorder stores, the
//! 16-byte wire trace context, and the [`Span`] RAII guard that stitches
//! them together.
//!
//! A trace follows one request through the serving pipeline: the client
//! stamps a nonzero 64-bit trace ID on the wire ([`encode_trace_ctx`]),
//! every stage the request crosses records a begin/end event pair into
//! the process [`recorder`](super::recorder) under that ID, and the
//! replication seal carries the ID to the follower so the same trace
//! covers primary *and* replica work. Span ends also feed per-stage
//! [`LatencyHistogram`]s, so aggregate stage timings appear in the
//! `MetricsDump` exposition as `stage_latency_ns{stage=...}` even when
//! the event ring is disabled.
//!
//! Everything here is allocation-free on the hot path: a [`Span`] is a
//! stack value holding copies of five words, and recording it costs one
//! monotonic clock read per edge plus the recorder's gated ring store.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use super::hist::LatencyHistogram;
use super::recorder;
use super::registry::MetricsRegistry;

/// Pipeline stages a span can cover. The discriminants are the wire
/// encoding (one byte in [`TraceEvent`]); new stages append, never
/// renumber.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Stage {
    /// Client-side: frame encoded and written to the socket.
    ClientSend = 0,
    /// Server: wire bytes to a typed `Request`.
    Decode = 1,
    /// Server: request dispatched against the registry (whole handler).
    Dispatch = 2,
    /// Server: the shard-striped registry ingest inside dispatch.
    ShardIngest = 3,
    /// Primary: dirty state drained and sealed into a replication batch.
    Seal = 4,
    /// Follower: a sealed batch applied into the replica registry.
    FollowerApply = 5,
    /// Keyed coordinator: one routed batch ingested by a worker.
    WorkerIngest = 6,
}

impl Stage {
    /// Every stage, in discriminant order (discriminants are indices).
    pub const ALL: [Stage; 7] = [
        Stage::ClientSend,
        Stage::Decode,
        Stage::Dispatch,
        Stage::ShardIngest,
        Stage::Seal,
        Stage::FollowerApply,
        Stage::WorkerIngest,
    ];

    /// Stable snake_case name used as the `stage` label value in the
    /// metrics exposition and the trace text renderer.
    pub fn name(self) -> &'static str {
        match self {
            Stage::ClientSend => "client_send",
            Stage::Decode => "decode",
            Stage::Dispatch => "dispatch",
            Stage::ShardIngest => "shard_ingest",
            Stage::Seal => "seal",
            Stage::FollowerApply => "follower_apply",
            Stage::WorkerIngest => "worker_ingest",
        }
    }

    /// Decode a wire byte. Unknown bytes return `None` (events from a
    /// newer peer render numerically instead of failing the dump).
    pub fn from_u8(v: u8) -> Option<Stage> {
        Stage::ALL.get(v as usize).copied()
    }
}

/// What a [`TraceEvent`] marks. One byte on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum EventKind {
    /// A span opened.
    Begin = 0,
    /// A span closed; the event payload is the span's payload word.
    End = 1,
    /// A point event with no duration (anomaly markers).
    Instant = 2,
}

impl EventKind {
    /// Renderer label.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Begin => "begin",
            EventKind::End => "end",
            EventKind::Instant => "instant",
        }
    }

    /// Decode a wire byte.
    pub fn from_u8(v: u8) -> Option<EventKind> {
        match v {
            0 => Some(EventKind::Begin),
            1 => Some(EventKind::End),
            2 => Some(EventKind::Instant),
            _ => None,
        }
    }
}

/// One flight-recorder event: 26 bytes on the `TRACE_EVENTS` wire
/// (`ns`, `trace_id`, `payload` as LE u64, then `stage`, `kind` raw
/// bytes). `stage`/`kind` stay raw `u8` in memory so a dump decoded
/// from a newer peer never fails on an unknown enum value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Monotonic nanoseconds (process-local epoch, [`monotonic_ns`]).
    pub ns: u64,
    /// The trace this event belongs to; 0 = untraced background work.
    pub trace_id: u64,
    /// One stage-defined word (word count, batch seq, opcode, ...).
    pub payload: u64,
    /// [`Stage`] discriminant.
    pub stage: u8,
    /// [`EventKind`] discriminant.
    pub kind: u8,
}

/// Encoded size of one [`TraceEvent`] in a `TRACE_EVENTS` frame.
pub const TRACE_EVENT_WIRE_LEN: usize = 26;

/// Size of the optional trailing trace context on request frames:
/// trace_id (LE u64) + flags (LE u64).
pub const TRACE_CTX_LEN: usize = 16;

/// Flags bit 0: the request is sampled. The only defined bit; a
/// trailer without it is not a trace context.
pub const TRACE_FLAG_SAMPLED: u64 = 1;

/// Encode the 16-byte wire trace context for `trace_id`.
pub fn encode_trace_ctx(trace_id: u64) -> [u8; TRACE_CTX_LEN] {
    let mut b = [0u8; TRACE_CTX_LEN];
    b[..8].copy_from_slice(&trace_id.to_le_bytes());
    b[8..].copy_from_slice(&TRACE_FLAG_SAMPLED.to_le_bytes());
    b
}

/// Decode a candidate 16-byte trailer into a trace ID. Returns `None`
/// unless the length is exact, the sampled flag is set, and the ID is
/// nonzero — so arbitrary trailing garbage keeps failing decode as it
/// did before trace contexts existed.
pub fn decode_trace_ctx(bytes: &[u8]) -> Option<u64> {
    if bytes.len() != TRACE_CTX_LEN {
        return None;
    }
    let id = u64::from_le_bytes(bytes[..8].try_into().ok()?);
    let flags = u64::from_le_bytes(bytes[8..].try_into().ok()?);
    if id == 0 || flags & TRACE_FLAG_SAMPLED == 0 {
        return None;
    }
    Some(id)
}

/// Monotonic nanoseconds since a process-local epoch (first call).
/// Every [`TraceEvent`] timestamp comes from this clock, so events from
/// different threads of one process order correctly; timestamps do
/// *not* compare across processes.
pub fn monotonic_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos().min(u64::MAX as u128) as u64
}

/// A fresh nonzero trace ID: a process-random seed mixed with a
/// sequence counter through an odd multiplier, so IDs are unique within
/// a process and collide across processes only by 2^-64 chance.
pub fn next_trace_id() -> u64 {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    static SEED: OnceLock<u64> = OnceLock::new();
    let seed =
        *SEED.get_or_init(|| super::unix_time_ns() ^ (std::process::id() as u64).rotate_left(32));
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    let id = (seed ^ n).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    if id == 0 {
        0x9E37_79B9_7F4A_7C15
    } else {
        id
    }
}

/// Record a point event (no duration) under `trace_id`.
pub fn instant(stage: Stage, trace_id: u64, payload: u64) {
    recorder::record(TraceEvent {
        ns: monotonic_ns(),
        trace_id,
        payload,
        stage: stage as u8,
        kind: EventKind::Instant as u8,
    });
}

/// RAII span guard: records a `Begin` event on construction and an
/// `End` event (plus an optional histogram sample of the elapsed
/// nanoseconds) when dropped. Stack-only; cheap enough for per-frame
/// use.
#[must_use = "a span records its end when dropped"]
pub struct Span {
    stage: Stage,
    trace_id: u64,
    payload: u64,
    begin_ns: u64,
    hist: Option<Arc<LatencyHistogram>>,
}

impl Span {
    /// Open a ring-only span (no histogram) under `trace_id` (0 for
    /// untraced background work).
    pub fn enter(stage: Stage, trace_id: u64) -> Span {
        Span::build(stage, trace_id, None)
    }

    /// Open a span that also records its elapsed nanoseconds into
    /// `hist` on drop. The histogram is fed unconditionally — stage
    /// timings keep flowing to the metrics exposition even while the
    /// event ring is disabled.
    pub fn enter_timed(stage: Stage, trace_id: u64, hist: &Arc<LatencyHistogram>) -> Span {
        Span::build(stage, trace_id, Some(hist.clone()))
    }

    fn build(stage: Stage, trace_id: u64, hist: Option<Arc<LatencyHistogram>>) -> Span {
        let begin_ns = monotonic_ns();
        recorder::record(TraceEvent {
            ns: begin_ns,
            trace_id,
            payload: 0,
            stage: stage as u8,
            kind: EventKind::Begin as u8,
        });
        Span { stage, trace_id, payload: 0, begin_ns, hist }
    }

    /// Attach the stage-defined payload word carried by the `End` event
    /// (word count, batch seq, ...).
    pub fn with_payload(mut self, payload: u64) -> Span {
        self.payload = payload;
        self
    }

    /// Set the payload word after construction (for values only known
    /// mid-span).
    pub fn set_payload(&mut self, payload: u64) {
        self.payload = payload;
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let end_ns = monotonic_ns();
        if let Some(h) = &self.hist {
            h.record(end_ns.saturating_sub(self.begin_ns));
        }
        recorder::record(TraceEvent {
            ns: end_ns,
            trace_id: self.trace_id,
            payload: self.payload,
            stage: self.stage as u8,
            kind: EventKind::End as u8,
        });
    }
}

/// Per-stage `stage_latency_ns{stage=...}` histograms registered into a
/// [`MetricsRegistry`]. Registering pre-declares every stage (empty
/// stages render as zero series — a stable scrape schema); handles are
/// indexed by stage discriminant, so lookup is an array read.
#[derive(Clone, Debug)]
pub struct StageTimers {
    timers: [Arc<LatencyHistogram>; Stage::ALL.len()],
}

impl StageTimers {
    /// Register (or re-attach to) the per-stage histograms in
    /// `metrics`. Same registry returns handles to the same cells.
    pub fn register(metrics: &MetricsRegistry) -> StageTimers {
        StageTimers {
            timers: Stage::ALL.map(|s| {
                metrics.histogram("stage_latency_ns", Some(("stage", s.name().to_string())))
            }),
        }
    }

    /// The histogram for `stage`.
    pub fn timer(&self, stage: Stage) -> &Arc<LatencyHistogram> {
        &self.timers[stage as usize]
    }
}

/// Render recorder events as human-readable text, sorted by timestamp.
/// Unknown stage/kind bytes (a newer peer's dump) render numerically.
pub fn render_events(events: &[TraceEvent]) -> String {
    let mut sorted: Vec<&TraceEvent> = events.iter().collect();
    sorted.sort_by_key(|e| (e.ns, e.trace_id, e.kind));
    let mut out = String::with_capacity(32 + sorted.len() * 80);
    out.push_str(&format!("{} trace events\n", sorted.len()));
    for e in sorted {
        let stage = match Stage::from_u8(e.stage) {
            Some(s) => s.name().to_string(),
            None => format!("stage#{}", e.stage),
        };
        let kind = match EventKind::from_u8(e.kind) {
            Some(k) => k.name().to_string(),
            None => format!("kind#{}", e.kind),
        };
        out.push_str(&format!(
            "{:>16} ns  trace={:016x}  {:<7} {:<14} payload={}\n",
            e.ns, e.trace_id, kind, stage, e.payload
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_and_kind_bytes_round_trip() {
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(*s as usize, i, "discriminants must be indices");
            assert_eq!(Stage::from_u8(*s as u8), Some(*s));
            assert!(!s.name().is_empty());
        }
        assert_eq!(Stage::from_u8(Stage::ALL.len() as u8), None);
        for k in [EventKind::Begin, EventKind::End, EventKind::Instant] {
            assert_eq!(EventKind::from_u8(k as u8), Some(k));
        }
        assert_eq!(EventKind::from_u8(3), None);
    }

    #[test]
    fn trace_ids_are_nonzero_and_distinct() {
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10_000 {
            let id = next_trace_id();
            assert_ne!(id, 0);
            assert!(seen.insert(id), "trace ids must not repeat");
        }
    }

    #[test]
    fn trace_ctx_round_trips_and_rejects_garbage() {
        let id = 0xDEAD_BEEF_CAFE_F00Du64;
        let bytes = encode_trace_ctx(id);
        assert_eq!(bytes.len(), TRACE_CTX_LEN);
        assert_eq!(decode_trace_ctx(&bytes), Some(id));
        // Wrong length.
        assert_eq!(decode_trace_ctx(&bytes[..15]), None);
        assert_eq!(decode_trace_ctx(&[0u8; 17]), None);
        // Zero trace id.
        assert_eq!(decode_trace_ctx(&encode_trace_ctx(0)), None);
        // Sampled flag clear.
        let mut unsampled = bytes;
        unsampled[8..].copy_from_slice(&0u64.to_le_bytes());
        assert_eq!(decode_trace_ctx(&unsampled), None);
        // All zeros (the classic padding trailer).
        assert_eq!(decode_trace_ctx(&[0u8; TRACE_CTX_LEN]), None);
    }

    #[test]
    fn monotonic_ns_never_goes_backwards() {
        let mut last = monotonic_ns();
        for _ in 0..1_000 {
            let now = monotonic_ns();
            assert!(now >= last);
            last = now;
        }
    }

    #[test]
    fn timed_span_feeds_its_histogram() {
        let h = Arc::new(LatencyHistogram::default());
        {
            let _s = Span::enter_timed(Stage::Dispatch, 7, &h).with_payload(42);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1, "span drop must record exactly one sample");
        assert!(s.max >= 1_000_000, "slept 1ms; recorded {} ns", s.max);
    }

    #[test]
    fn stage_timers_share_cells_with_the_registry() {
        let reg = MetricsRegistry::shared();
        let timers = StageTimers::register(&reg);
        timers.timer(Stage::Decode).record(123);
        let again = StageTimers::register(&reg);
        assert_eq!(again.timer(Stage::Decode).snapshot().count, 1, "same cell");
        let text = reg.render();
        assert!(text.contains("stage_latency_ns_count{stage=\"decode\"} 1\n"));
        // Every stage pre-declares a series, even untouched ones.
        assert!(text.contains("stage_latency_ns_count{stage=\"follower_apply\"} 0\n"));
    }

    #[test]
    fn renderer_orders_by_time_and_names_stages() {
        let events = vec![
            TraceEvent { ns: 200, trace_id: 5, payload: 9, stage: 2, kind: 1 },
            TraceEvent { ns: 100, trace_id: 5, payload: 0, stage: 2, kind: 0 },
            TraceEvent { ns: 300, trace_id: 5, payload: 1, stage: 250, kind: 9 },
        ];
        let text = render_events(&events);
        assert!(text.starts_with("3 trace events\n"));
        let begin = text.find("begin").unwrap();
        let end = text.find("end ").unwrap();
        assert!(begin < end, "events must render in time order");
        assert!(text.contains("dispatch"));
        assert!(text.contains("stage#250"));
        assert!(text.contains("kind#9"));
    }
}
