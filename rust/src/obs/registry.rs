//! Process metrics registry: named counters, gauges, and histograms
//! with cheap handles and a versioned text exposition.
//!
//! Instruments are registered once (at subsystem construction) and
//! then updated through lock-free handles — the registry mutex guards
//! only registration and scrape, never the hot path. Names are static
//! strings; an optional single `key="value"` label distinguishes
//! instances (per-opcode, per-tier, per-loop).
//!
//! [`MetricsRegistry::render`] produces the exposition text: a
//! `# hll-metrics v1` header followed by sorted
//! `name{label="v"} value` lines (Prometheus-compatible), histograms
//! expanded into `quantile` series plus `_count` / `_sum` / `_max`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::hist::LatencyHistogram;

/// First line of every exposition dump; bump the version when the
/// format changes shape.
pub const EXPOSITION_HEADER: &str = "# hll-metrics v1";

/// A monotonically increasing counter handle. Clones share the cell.
/// Derefs to [`AtomicU64`] so call sites can use `fetch_add`/`load`
/// directly.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add `n` (relaxed).
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Add 1 (relaxed).
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value (relaxed).
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl std::ops::Deref for Counter {
    type Target = AtomicU64;
    fn deref(&self) -> &AtomicU64 {
        &self.0
    }
}

/// A settable gauge handle. Clones share the cell.
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Set the value (relaxed).
    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Current value (relaxed).
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl std::ops::Deref for Gauge {
    type Target = AtomicU64;
    fn deref(&self) -> &AtomicU64 {
        &self.0
    }
}

enum Instrument {
    Counter(Counter),
    Gauge(Gauge),
    /// Computed at scrape time — bridges subsystems that already keep
    /// their own stats (registry tiers, replication log) without
    /// double-accounting.
    GaugeFn(Box<dyn Fn() -> f64 + Send + Sync>),
    Histogram(Arc<LatencyHistogram>),
}

struct Entry {
    name: &'static str,
    /// `Some((key, value))` renders as `name{key="value"}`.
    label: Option<(&'static str, String)>,
    instrument: Instrument,
}

impl Entry {
    fn series_key(&self) -> (String, String) {
        match &self.label {
            Some((k, v)) => (self.name.to_string(), format!("{k}={v}")),
            None => (self.name.to_string(), String::new()),
        }
    }
}

/// The process-wide instrument registry. Cheap to share (`Arc`); each
/// `SketchServer` owns one, standalone coordinators create their own.
#[derive(Default)]
pub struct MetricsRegistry {
    entries: Mutex<Vec<Entry>>,
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let n = self.entries.lock().map(|e| e.len()).unwrap_or(0);
        f.debug_struct("MetricsRegistry").field("instruments", &n).finish()
    }
}

impl MetricsRegistry {
    /// Fresh empty registry behind an `Arc`.
    pub fn shared() -> Arc<Self> {
        Arc::new(Self::default())
    }

    fn find_or_insert<T: Clone>(
        &self,
        name: &'static str,
        label: Option<(&'static str, String)>,
        matches: impl Fn(&Instrument) -> Option<T>,
        build: impl FnOnce() -> (T, Instrument),
    ) -> T {
        let mut entries = self.entries.lock().expect("metrics registry poisoned");
        for e in entries.iter() {
            if e.name == name && e.label == label {
                if let Some(handle) = matches(&e.instrument) {
                    return handle;
                }
            }
        }
        let (handle, instrument) = build();
        entries.push(Entry { name, label, instrument });
        handle
    }

    /// Register (or look up) a counter. Same `(name, label)` returns a
    /// handle to the same cell.
    pub fn counter(&self, name: &'static str, label: Option<(&'static str, String)>) -> Counter {
        self.find_or_insert(
            name,
            label,
            |i| match i {
                Instrument::Counter(c) => Some(c.clone()),
                _ => None,
            },
            || {
                let c = Counter::default();
                (c.clone(), Instrument::Counter(c))
            },
        )
    }

    /// Register (or look up) a gauge.
    pub fn gauge(&self, name: &'static str, label: Option<(&'static str, String)>) -> Gauge {
        self.find_or_insert(
            name,
            label,
            |i| match i {
                Instrument::Gauge(g) => Some(g.clone()),
                _ => None,
            },
            || {
                let g = Gauge::default();
                (g.clone(), Instrument::Gauge(g))
            },
        )
    }

    /// Register (or look up) a histogram.
    pub fn histogram(
        &self,
        name: &'static str,
        label: Option<(&'static str, String)>,
    ) -> Arc<LatencyHistogram> {
        self.find_or_insert(
            name,
            label,
            |i| match i {
                Instrument::Histogram(h) => Some(h.clone()),
                _ => None,
            },
            || {
                let h = Arc::new(LatencyHistogram::default());
                (h.clone(), Instrument::Histogram(h))
            },
        )
    }

    /// Register a scrape-time computed gauge. Re-registering the same
    /// `(name, label)` replaces the previous closure.
    pub fn gauge_fn(
        &self,
        name: &'static str,
        label: Option<(&'static str, String)>,
        f: impl Fn() -> f64 + Send + Sync + 'static,
    ) {
        let mut entries = self.entries.lock().expect("metrics registry poisoned");
        if let Some(e) = entries
            .iter_mut()
            .find(|e| e.name == name && e.label == label && matches!(e.instrument, Instrument::GaugeFn(_)))
        {
            e.instrument = Instrument::GaugeFn(Box::new(f));
            return;
        }
        entries.push(Entry { name, label, instrument: Instrument::GaugeFn(Box::new(f)) });
    }

    /// Render the exposition text: versioned header + sorted series.
    pub fn render(&self) -> String {
        let entries = self.entries.lock().expect("metrics registry poisoned");
        let mut lines: Vec<(String, String, String)> = Vec::new();
        for e in entries.iter() {
            let (name, label) = e.series_key();
            match &e.instrument {
                Instrument::Counter(c) => {
                    lines.push((name, label, c.get().to_string()));
                }
                Instrument::Gauge(g) => {
                    lines.push((name, label, g.get().to_string()));
                }
                Instrument::GaugeFn(f) => {
                    lines.push((name, label, format_f64(f())));
                }
                Instrument::Histogram(h) => {
                    let s = h.snapshot();
                    for (q, qs) in
                        [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99"), (0.999, "0.999")]
                    {
                        let label = if label.is_empty() {
                            format!("quantile={qs}")
                        } else {
                            format!("{label},quantile={qs}")
                        };
                        lines.push((name.clone(), label, s.quantile(q).to_string()));
                    }
                    lines.push((format!("{name}_count"), label.clone(), s.count.to_string()));
                    lines.push((format!("{name}_sum"), label.clone(), s.sum.to_string()));
                    lines.push((format!("{name}_max"), label, s.max.to_string()));
                }
            }
        }
        drop(entries);
        lines.sort();
        let mut out = String::with_capacity(64 + lines.len() * 48);
        out.push_str(EXPOSITION_HEADER);
        out.push('\n');
        for (name, label, value) in lines {
            out.push_str(&name);
            if !label.is_empty() {
                out.push('{');
                for (i, pair) in label.split(',').enumerate() {
                    let (k, v) = pair.split_once('=').expect("label built as k=v");
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(k);
                    out.push_str("=\"");
                    out.push_str(v);
                    out.push('"');
                }
                out.push('}');
            }
            out.push(' ');
            out.push_str(&value);
            out.push('\n');
        }
        out
    }
}

/// Render a float without scientific notation and without trailing
/// noise: integers print bare, fractions keep up to 3 decimals.
fn format_f64(v: f64) -> String {
    if !v.is_finite() {
        return "0".to_string();
    }
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.3}")
    }
}

/// Parse one exposition line back into `(name, labels, value)`.
/// Used by tests and the smoke scraper to validate the format; strict
/// enough to reject truncated or mangled lines.
pub fn parse_line(line: &str) -> Option<(&str, Vec<(&str, &str)>, f64)> {
    let (series, value) = line.rsplit_once(' ')?;
    let value: f64 = value.parse().ok()?;
    let (name, labels) = match series.split_once('{') {
        None => (series, Vec::new()),
        Some((name, rest)) => {
            let body = rest.strip_suffix('}')?;
            let mut labels = Vec::new();
            for pair in body.split(',') {
                let (k, v) = pair.split_once("=\"")?;
                labels.push((k, v.strip_suffix('"')?));
            }
            (name, labels)
        }
    };
    if name.is_empty() || name.contains(|c: char| c.is_whitespace() || c == '{' || c == '}') {
        return None;
    }
    Some((name, labels, value))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_share_cells_and_dedupe() {
        let reg = MetricsRegistry::shared();
        let a = reg.counter("frames_total", None);
        let b = reg.counter("frames_total", None);
        a.add(3);
        b.inc();
        assert_eq!(a.get(), 4, "same (name,label) shares one cell");
        let g = reg.gauge("conns_open", None);
        g.set(7);
        assert_eq!(reg.gauge("conns_open", None).get(), 7);
        // Different labels are distinct series.
        let ping = reg.counter("rpc_total", Some(("op", "ping".into())));
        let stats = reg.counter("rpc_total", Some(("op", "stats".into())));
        ping.inc();
        assert_eq!(stats.get(), 0);
    }

    #[test]
    fn render_is_versioned_sorted_and_parseable() {
        let reg = MetricsRegistry::shared();
        reg.counter("zz_last", None).add(9);
        reg.counter("aa_first", Some(("op", "ping".into()))).add(2);
        reg.gauge("gauge_plain", None).set(5);
        reg.gauge_fn("bridged", Some(("tier", "dense".into())), || 12.5);
        let h = reg.histogram("lat_ns", Some(("op", "ping".into())));
        h.record(100);
        h.record(200);
        let text = reg.render();
        let mut lines = text.lines();
        assert_eq!(lines.next(), Some(EXPOSITION_HEADER));
        let body: Vec<&str> = lines.collect();
        let mut sorted = body.clone();
        sorted.sort();
        assert_eq!(body, sorted, "series must render sorted");
        for line in &body {
            assert!(parse_line(line).is_some(), "unparseable line: {line}");
        }
        assert!(text.contains("aa_first{op=\"ping\"} 2\n"));
        assert!(text.contains("bridged{tier=\"dense\"} 12.5\n"));
        assert!(text.contains("lat_ns_count{op=\"ping\"} 2\n"));
        assert!(text.contains("lat_ns_sum{op=\"ping\"} 300\n"));
        assert!(text.contains("lat_ns_max{op=\"ping\"} 200\n"));
        assert!(text.contains("lat_ns{op=\"ping\",quantile=\"0.5\"} 100\n"));
        assert!(text.contains("lat_ns{op=\"ping\",quantile=\"0.999\"} 200\n"));
    }

    #[test]
    fn gauge_fn_reregistration_replaces() {
        let reg = MetricsRegistry::shared();
        reg.gauge_fn("lag", None, || 1.0);
        reg.gauge_fn("lag", None, || 2.0);
        let text = reg.render();
        assert_eq!(text.matches("lag ").count(), 1, "one series, not two");
        assert!(text.contains("lag 2\n"));
    }

    #[test]
    fn all_empty_registry_renders_and_parses_round_trip() {
        // Instruments registered but never touched: the scrape a
        // monitor takes in the first instant of a process's life.
        let reg = MetricsRegistry::shared();
        reg.counter("c_total", None);
        reg.gauge("g_now", Some(("loop", "0".into())));
        reg.histogram("h_ns", Some(("op", "ping".into())));
        reg.gauge_fn("bridge", None, || 0.0);
        let text = reg.render();
        let mut lines = text.lines();
        assert_eq!(lines.next(), Some(EXPOSITION_HEADER));
        let mut body = 0;
        for line in lines {
            let (_, _, value) =
                parse_line(line).unwrap_or_else(|| panic!("unparseable line: {line:?}"));
            assert_eq!(value, 0.0, "untouched instrument must scrape as 0: {line:?}");
            body += 1;
        }
        // counter + gauge + gauge_fn + histogram (4 quantiles,
        // _count, _sum, _max).
        assert_eq!(body, 3 + 7, "every registered series must render");
        // The empty histogram's derived series are 0, not NaN/garbage.
        assert!(text.contains("h_ns{op=\"ping\",quantile=\"0.5\"} 0\n"));
        assert!(text.contains("h_ns{op=\"ping\",quantile=\"0.999\"} 0\n"));
        assert!(text.contains("h_ns_count{op=\"ping\"} 0\n"));
        assert!(text.contains("h_ns_sum{op=\"ping\"} 0\n"));
        assert!(text.contains("h_ns_max{op=\"ping\"} 0\n"));
    }

    #[test]
    fn restarted_follower_reregistration_yields_one_fresh_series() {
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;

        let reg = MetricsRegistry::shared();
        // Generation 1 of a follower bridges its cursor at registration.
        let gen1 = Arc::new(AtomicU64::new(7));
        {
            let c = gen1.clone();
            reg.gauge_fn("replica_cursor", Some(("role", "follower".into())), move || {
                c.load(Ordering::Relaxed) as f64
            });
        }
        assert!(reg.render().contains("replica_cursor{role=\"follower\"} 7\n"));

        // The follower restarts and re-registers the same (name, label)
        // over a fresh state cell; the old generation's cell is gone.
        let gen2 = Arc::new(AtomicU64::new(42));
        {
            let c = gen2.clone();
            reg.gauge_fn("replica_cursor", Some(("role", "follower".into())), move || {
                c.load(Ordering::Relaxed) as f64
            });
        }
        drop(gen1);
        let text = reg.render();
        assert_eq!(
            text.matches("replica_cursor").count(),
            1,
            "re-registration must replace, not duplicate:\n{text}"
        );
        assert!(text.contains("replica_cursor{role=\"follower\"} 42\n"));
        // Scrape-time evaluation follows the new generation live.
        gen2.store(43, Ordering::Relaxed);
        assert!(reg.render().contains("replica_cursor{role=\"follower\"} 43\n"));
    }

    #[test]
    fn parse_line_rejects_hostile_input() {
        for bad in [
            "",
            "no_value",
            "name{unterminated 3",
            "name{k=\"v\" 3",
            "name{k=v\"} 3",
            "name not_a_number",
            "{} 3",
            "na me 3",
        ] {
            assert!(parse_line(bad).is_none(), "accepted hostile line: {bad:?}");
        }
        let (name, labels, v) = parse_line("rpc_ns{op=\"ping\",quantile=\"0.99\"} 1500").unwrap();
        assert_eq!(name, "rpc_ns");
        assert_eq!(labels, vec![("op", "ping"), ("quantile", "0.99")]);
        assert_eq!(v, 1500.0);
    }
}
