//! Lock-free log-bucketed latency histogram (HDR-style).
//!
//! Values (nanoseconds) map to a fixed array of `AtomicU64` buckets:
//! each power-of-two range is split into `1 << SUB_BITS` linear
//! sub-buckets, so relative error is bounded by `2^-SUB_BITS` (~3%)
//! across the full `u64` range. [`LatencyHistogram::record`] is a few
//! relaxed atomic RMWs — no locks, no allocation, no branching on
//! contended state — and is safe to call from any number of threads.
//!
//! Readout ([`LatencyHistogram::snapshot`]) walks the bucket array once
//! and answers count / sum / max / quantiles from the copy, so a
//! scraper never perturbs recorders beyond cache traffic.

use std::sync::atomic::{AtomicU64, Ordering};

/// Linear sub-buckets per power-of-two range (as a shift).
const SUB_BITS: u32 = 5;
/// Sub-buckets per power-of-two range.
const SUB: usize = 1 << SUB_BITS;
/// Total bucket count: values below `SUB` get exact buckets, every
/// `u64` power-of-two range above that gets `SUB` sub-buckets.
const BUCKETS: usize = (64 - SUB_BITS as usize + 1) * SUB;

/// Index of the bucket holding `v`. Exact for `v < SUB`, otherwise
/// log-bucketed: the top `SUB_BITS + 1` significant bits select the
/// bucket, bounding relative error by `2^-SUB_BITS`.
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let exp = 63 - v.leading_zeros(); // >= SUB_BITS
    let block = (exp - SUB_BITS + 1) as usize;
    let offset = ((v >> (exp - SUB_BITS)) as usize) - SUB;
    block * SUB + offset
}

/// Inclusive upper bound of bucket `i` — the value reported for any
/// sample that landed in it, so quantiles never under-report.
fn bucket_upper_bound(i: usize) -> u64 {
    if i < SUB {
        return i as u64;
    }
    let block = (i / SUB) as u32;
    let offset = (i % SUB) as u64;
    let exp = block + SUB_BITS - 1;
    let scale = exp - SUB_BITS;
    let lower = (SUB as u64 + offset) << scale;
    lower + ((1u64 << scale) - 1)
}

/// A concurrent latency histogram. Construct via [`Default`], share
/// behind an `Arc`, record from any thread.
pub struct LatencyHistogram {
    buckets: Box<[AtomicU64; BUCKETS]>,
    /// Exact sum of recorded values (for mean / `_sum` exposition).
    sum: AtomicU64,
    /// Exact maximum recorded value.
    max: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // `AtomicU64` is zero-initializable; build the boxed array
        // without a large stack temporary.
        let buckets: Box<[AtomicU64]> =
            (0..BUCKETS).map(|_| AtomicU64::new(0)).collect();
        let buckets = buckets.try_into().expect("BUCKETS-sized array");
        Self { buckets, sum: AtomicU64::new(0), max: AtomicU64::new(0) }
    }
}

impl std::fmt::Debug for LatencyHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        f.debug_struct("LatencyHistogram")
            .field("count", &s.count)
            .field("p50", &s.quantile(0.5))
            .field("p99", &s.quantile(0.99))
            .field("max", &s.max)
            .finish()
    }
}

impl LatencyHistogram {
    /// Record one value. Lock-free: three relaxed atomic RMWs (bucket
    /// increment, sum accumulate, max raise), no allocation.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Fold another histogram's counts into this one (bucket-wise).
    pub fn merge(&self, other: &LatencyHistogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = theirs.load(Ordering::Relaxed);
            if n != 0 {
                mine.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Total recorded samples (derived from the bucket array, so it is
    /// consistent with whatever quantile readout would see).
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Copy the live buckets into an immutable snapshot for readout.
    pub fn snapshot(&self) -> HistSnapshot {
        let buckets: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count = buckets.iter().sum();
        HistSnapshot {
            buckets,
            count,
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

/// An immutable point-in-time copy of a [`LatencyHistogram`].
#[derive(Debug, Clone)]
pub struct HistSnapshot {
    buckets: Vec<u64>,
    /// Total recorded samples.
    pub count: u64,
    /// Sum of all recorded values.
    pub sum: u64,
    /// Largest recorded value (exact).
    pub max: u64,
}

impl HistSnapshot {
    /// Value at quantile `q` in `[0, 1]`: the upper bound of the bucket
    /// containing the `ceil(q * count)`-th sample, clamped to the exact
    /// recorded max. Returns 0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return bucket_upper_bound(i).min(self.max);
            }
        }
        self.max
    }

    /// Mean of recorded values (exact, from the running sum).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn bucket_index_monotone_and_bounded() {
        let probes: Vec<u64> = (0..2048)
            .chain((1..54).map(|e| (1u64 << e) - 1))
            .chain((1..54).map(|e| 1u64 << e))
            .chain((1..54).map(|e| (1u64 << e) + 1))
            .chain([u64::MAX - 1, u64::MAX])
            .collect();
        let mut sorted = probes.clone();
        sorted.sort_unstable();
        let mut prev = 0usize;
        for v in sorted {
            let i = bucket_index(v);
            assert!(i < BUCKETS, "index {i} out of range for {v}");
            assert!(i >= prev, "index must be monotone in value ({v})");
            prev = i;
            let ub = bucket_upper_bound(i);
            assert!(ub >= v, "upper bound {ub} below value {v}");
            // Bounded relative error: the bucket never overstates by
            // more than one sub-bucket width.
            if v >= SUB as u64 {
                assert!((ub - v) as f64 / v as f64 <= 1.0 / SUB as f64 + 1e-9);
            } else {
                assert_eq!(ub, v, "small values are exact");
            }
        }
    }

    #[test]
    fn exact_small_values_and_quantiles() {
        let h = LatencyHistogram::default();
        for v in 1..=10u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 10);
        assert_eq!(s.sum, 55);
        assert_eq!(s.max, 10);
        assert_eq!(s.quantile(0.5), 5);
        assert_eq!(s.quantile(1.0), 10);
        assert_eq!(s.quantile(0.0), 1);
        assert!((s.mean() - 5.5).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let s = LatencyHistogram::default().snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.quantile(0.99), 0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn degenerate_quantile_inputs_never_panic_or_lie() {
        // Empty snapshot: every q, including hostile ones, reads 0.
        let empty = LatencyHistogram::default().snapshot();
        for q in [0.0, 0.5, 1.0, -5.0, 7.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(empty.quantile(q), 0, "empty histogram must read 0 at q={q}");
        }
        assert_eq!(empty.max, 0);
        assert_eq!(empty.sum, 0);
        assert_eq!(empty.mean(), 0.0);

        // One sample: every quantile is that sample, out-of-range q
        // clamps instead of indexing past the distribution.
        let h = LatencyHistogram::default();
        h.record(1234);
        let one = h.snapshot();
        for q in [0.0, 0.5, 1.0, -1.0, 2.0, f64::NAN] {
            assert_eq!(one.quantile(q), 1234, "single-sample quantile at q={q}");
        }
        assert_eq!(one.mean(), 1234.0);

        // Boundary values record without panicking and max stays exact.
        let h = LatencyHistogram::default();
        h.record(0);
        h.record(u64::MAX);
        let s = h.snapshot();
        assert_eq!(s.count, 2);
        assert_eq!(s.max, u64::MAX);
        assert_eq!(s.quantile(0.0), 0);
        assert_eq!(s.quantile(1.0), u64::MAX);
    }

    #[test]
    fn quantiles_within_relative_error() {
        let h = LatencyHistogram::default();
        // A deterministic spread over five decades.
        for i in 1..=10_000u64 {
            h.record(i * 997);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 10_000);
        for (q, exact) in [(0.5, 5_000 * 997), (0.9, 9_000 * 997), (0.99, 9_900 * 997)] {
            let got = s.quantile(q);
            assert!(got >= exact, "quantile {q} must not under-report: {got} < {exact}");
            let err = (got - exact) as f64 / exact as f64;
            assert!(err <= 2.0 / SUB as f64, "quantile {q} error {err} too large");
        }
        assert_eq!(s.quantile(1.0), 10_000 * 997, "max is exact");
    }

    #[test]
    fn concurrent_recorders_exact_count() {
        // N threads x M records each: total count must be exact and
        // quantiles must sit within bucket bounds of the recorded set.
        const THREADS: usize = 8;
        const PER: u64 = 20_000;
        let h = Arc::new(LatencyHistogram::default());
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..PER {
                        // Values span several orders of magnitude.
                        h.record((i % 1_000) * 1_000 + t as u64 + 1);
                    }
                })
            })
            .collect();
        for j in handles {
            j.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, THREADS as u64 * PER, "no record may be lost");
        assert_eq!(h.count(), s.count);
        let max_possible = 999 * 1_000 + THREADS as u64;
        assert!(s.max <= max_possible && s.max >= 999 * 1_000);
        for q in [0.5, 0.9, 0.99, 0.999] {
            let v = s.quantile(q);
            assert!(v <= s.max, "quantile {q} exceeds max");
            assert!(v > 0, "quantile {q} must be nonzero for nonzero data");
        }
    }

    #[test]
    fn merge_sums_counts() {
        let a = LatencyHistogram::default();
        let b = LatencyHistogram::default();
        for i in 1..=100 {
            a.record(i);
            b.record(i * 1_000);
        }
        a.merge(&b);
        let s = a.snapshot();
        assert_eq!(s.count, 200);
        assert_eq!(s.max, 100_000);
        assert!(s.quantile(0.999) >= 99_000);
    }
}
