//! Observability: lock-free latency histograms and a process metrics
//! registry with a Prometheus-style text exposition.
//!
//! The subsystem is dependency-free and allocation-free on the hot
//! path: recording a latency is a handful of relaxed atomic ops into a
//! log-bucketed histogram ([`LatencyHistogram`]), and counters/gauges
//! are plain `AtomicU64`s behind cheap cloneable handles. All readout
//! cost (bucket walks, quantile interpolation, text rendering) is paid
//! by the scraper, never by the recording thread.
//!
//! Every subsystem registers its instruments into a shared
//! [`MetricsRegistry`]; [`MetricsRegistry::render`] emits a versioned
//! `name{label="v"} value` text format served over the `MetricsDump`
//! RPC and the `SketchServer::metrics_text` side channel.

pub mod hist;
pub mod registry;

pub use hist::{HistSnapshot, LatencyHistogram};
pub use registry::{Counter, Gauge, MetricsRegistry, EXPOSITION_HEADER};

/// Wall-clock nanoseconds since the UNIX epoch. Used to stamp sealed
/// replication batches so the follower can measure seal-to-apply
/// latency across processes (monotonic clocks don't travel).
pub fn unix_time_ns() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos().min(u64::MAX as u128) as u64)
        .unwrap_or(0)
}
