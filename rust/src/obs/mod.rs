//! Observability: lock-free latency histograms, a process metrics
//! registry with a Prometheus-style text exposition, and a
//! request-scoped tracing layer backed by an in-memory flight recorder.
//!
//! The subsystem is dependency-free and allocation-free on the hot
//! path: recording a latency is a handful of relaxed atomic ops into a
//! log-bucketed histogram ([`LatencyHistogram`]), counters/gauges are
//! plain `AtomicU64`s behind cheap cloneable handles, and a trace
//! [`Span`] is a stack guard writing fixed-size events into a
//! per-thread overwrite-oldest ring ([`recorder`]) — one relaxed load
//! when tracing is off. All readout cost (bucket walks, quantile
//! interpolation, text rendering, ring merges) is paid by the scraper,
//! never by the recording thread.
//!
//! Every subsystem registers its instruments into a shared
//! [`MetricsRegistry`]; [`MetricsRegistry::render`] emits a versioned
//! `name{label="v"} value` text format served over the `MetricsDump`
//! RPC and the `SketchServer::metrics_text` side channel. Trace events
//! are served over the `TraceDump` RPC and frozen into the recorder's
//! bounded black box on anomalies ([`recorder::note_anomaly`]).

pub mod hist;
pub mod recorder;
pub mod registry;
pub mod trace;

pub use hist::{HistSnapshot, LatencyHistogram};
pub use registry::{Counter, Gauge, MetricsRegistry, EXPOSITION_HEADER};
pub use trace::{
    decode_trace_ctx, encode_trace_ctx, monotonic_ns, next_trace_id, render_events, EventKind,
    Span, Stage, StageTimers, TraceEvent, TRACE_CTX_LEN, TRACE_EVENT_WIRE_LEN, TRACE_FLAG_SAMPLED,
};

/// Wall-clock nanoseconds since the UNIX epoch. Used to stamp sealed
/// replication batches so the follower can measure seal-to-apply
/// latency across processes (monotonic clocks don't travel).
pub fn unix_time_ns() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos().min(u64::MAX as u128) as u64)
        .unwrap_or(0)
}
