//! The in-memory flight recorder: per-thread overwrite-oldest rings of
//! [`TraceEvent`]s plus a bounded anomaly "black box".
//!
//! Design constraints, in order:
//!
//! * **Disabled is free.** Recording starts with one relaxed
//!   [`AtomicBool`] load; when the recorder is off (the default — the
//!   server enables it at startup) that branch is the *entire* cost, and
//!   no thread-local ring is ever allocated.
//! * **Enabled is lock-free and allocation-free.** Each recording
//!   thread owns a fixed [`RING_CAPACITY`]-slot ring (leased from a
//!   global free-list on first record, returned at thread exit so
//!   short-lived threads reuse rings and a dead thread's events stay
//!   readable). A push is four relaxed/release atomic stores into a
//!   pre-allocated slot — no locks, no heap, overwrite-oldest.
//! * **Readers never stall writers.** [`snapshot`] walks the rings
//!   without stopping them; a slot being overwritten mid-read is
//!   skipped via its validity word rather than torn. The dump is
//!   best-effort by design — it is a flight recorder, not a log.
//!
//! The black box ([`note_anomaly`]) freezes the most recent ring
//! contents when something goes wrong — slow-request warnings, typed
//! error replies, follower halts — into a bounded deque retrievable
//! after the fact via [`anomalies`], so the events leading up to an
//! incident survive the ring overwriting them.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use super::trace::TraceEvent;

/// Events each recording thread retains (per-thread ring slots).
pub const RING_CAPACITY: usize = 1024;

/// Anomaly snapshots retained before the oldest is dropped.
pub const BLACK_BOX_CAPACITY: usize = 8;

/// Most-recent events frozen into each anomaly snapshot.
pub const ANOMALY_EVENT_CAPACITY: usize = 256;

/// Global gate. Off by default so library users pay one relaxed load;
/// `SketchServer::start` turns it on.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is the recorder capturing ring events?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Enable or disable ring capture. Cheap and safe at any time; events
/// recorded while disabled are dropped before touching any ring.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Slot validity flag in the packed meta word (bit 63; stage and kind
/// live in bits 15..8 and 7..0).
const SLOT_VALID: u64 = 1 << 63;

/// One ring slot: the event fields plus a packed meta word written last
/// (release) so readers accept only fully written slots.
struct Slot {
    ns: AtomicU64,
    trace_id: AtomicU64,
    payload: AtomicU64,
    meta: AtomicU64,
}

/// A single thread's event ring. Exactly one thread writes (the lease
/// holder); any thread may read via [`snapshot`].
struct Ring {
    slots: Box<[Slot]>,
    head: AtomicU64,
    /// Lease flag: set while a live thread owns this ring, cleared at
    /// thread exit so the next new thread reuses it. Contents persist
    /// across leases — a dead thread's tail stays dumpable.
    in_use: AtomicBool,
}

impl Ring {
    fn new() -> Ring {
        let slots = (0..RING_CAPACITY)
            .map(|_| Slot {
                ns: AtomicU64::new(0),
                trace_id: AtomicU64::new(0),
                payload: AtomicU64::new(0),
                meta: AtomicU64::new(0),
            })
            .collect();
        Ring { slots, head: AtomicU64::new(0), in_use: AtomicBool::new(true) }
    }

    /// Overwrite-oldest push. Single-writer: only the leasing thread
    /// calls this, so the head bump and field stores never race another
    /// writer; the meta word is cleared first and re-armed last so a
    /// concurrent reader skips the slot instead of stitching halves of
    /// two events together.
    fn push(&self, e: TraceEvent) {
        let idx = (self.head.fetch_add(1, Ordering::Relaxed) as usize) % RING_CAPACITY;
        let slot = &self.slots[idx];
        slot.meta.store(0, Ordering::Release);
        slot.ns.store(e.ns, Ordering::Relaxed);
        slot.trace_id.store(e.trace_id, Ordering::Relaxed);
        slot.payload.store(e.payload, Ordering::Relaxed);
        slot.meta.store(
            SLOT_VALID | ((e.stage as u64) << 8) | e.kind as u64,
            Ordering::Release,
        );
    }

    /// Append every valid slot's event to `out` (unordered; the caller
    /// sorts the merged set by timestamp).
    fn events_into(&self, out: &mut Vec<TraceEvent>) {
        for slot in self.slots.iter() {
            let meta = slot.meta.load(Ordering::Acquire);
            if meta & SLOT_VALID == 0 {
                continue;
            }
            out.push(TraceEvent {
                ns: slot.ns.load(Ordering::Relaxed),
                trace_id: slot.trace_id.load(Ordering::Relaxed),
                payload: slot.payload.load(Ordering::Relaxed),
                stage: ((meta >> 8) & 0xFF) as u8,
                kind: (meta & 0xFF) as u8,
            });
        }
    }
}

/// All rings ever created, live or leased-out. The mutex guards only
/// registration and snapshot — never a record.
fn rings() -> &'static Mutex<Vec<Arc<Ring>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<Ring>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Reuse a free ring or register a fresh one for this thread.
fn acquire_ring() -> Arc<Ring> {
    let mut rings = lock_unpoisoned(rings());
    for ring in rings.iter() {
        if ring
            .in_use
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
        {
            return ring.clone();
        }
    }
    let ring = Arc::new(Ring::new());
    rings.push(ring.clone());
    ring
}

/// Returns the ring to the free-list when the owning thread exits.
struct RingLease(Arc<Ring>);

impl Drop for RingLease {
    fn drop(&mut self) {
        self.0.in_use.store(false, Ordering::Release);
    }
}

thread_local! {
    static TL_RING: RingLease = RingLease(acquire_ring());
}

/// Record one event into this thread's ring. When the recorder is
/// disabled this is a single relaxed load and branch — no thread-local
/// access, no ring allocation, nothing else.
#[inline]
pub fn record(event: TraceEvent) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    // `try_with`: a destructor-phase record (thread teardown) is
    // silently dropped rather than panicking.
    let _ = TL_RING.try_with(|lease| lease.0.push(event));
}

/// Merge every ring's current contents, sorted by timestamp, keeping at
/// most the `max` most recent events. Best-effort: slots mid-overwrite
/// are skipped, not torn.
pub fn snapshot(max: usize) -> Vec<TraceEvent> {
    let rings: Vec<Arc<Ring>> = lock_unpoisoned(rings()).clone();
    let mut events = Vec::new();
    for ring in rings {
        ring.events_into(&mut events);
    }
    events.sort_by_key(|e| (e.ns, e.trace_id, e.kind));
    if events.len() > max {
        events.drain(..events.len() - max);
    }
    events
}

/// Number of per-thread rings ever registered. A disabled-mode record
/// must never grow this (the overhead test's structural assertion).
pub fn ring_count() -> usize {
    lock_unpoisoned(rings()).len()
}

/// One frozen black-box entry: what the rings held when an anomaly was
/// noted.
#[derive(Debug, Clone)]
pub struct AnomalySnapshot {
    /// Short human label ("slow request: ...", "follower halt: ...").
    pub label: String,
    /// Wall-clock nanoseconds when the snapshot was taken.
    pub unix_ns: u64,
    /// The most recent [`ANOMALY_EVENT_CAPACITY`] ring events.
    pub events: Vec<TraceEvent>,
}

fn black_box() -> &'static Mutex<VecDeque<AnomalySnapshot>> {
    static BB: OnceLock<Mutex<VecDeque<AnomalySnapshot>>> = OnceLock::new();
    BB.get_or_init(|| Mutex::new(VecDeque::new()))
}

/// Freeze the current ring contents into the black box under `label`.
/// Bounded: the oldest snapshot is dropped past
/// [`BLACK_BOX_CAPACITY`]. Called on anomalies only — it allocates.
pub fn note_anomaly(label: &str) {
    let events = snapshot(ANOMALY_EVENT_CAPACITY);
    let mut bb = lock_unpoisoned(black_box());
    if bb.len() >= BLACK_BOX_CAPACITY {
        bb.pop_front();
    }
    bb.push_back(AnomalySnapshot {
        label: label.to_string(),
        unix_ns: super::unix_time_ns(),
        events,
    });
}

/// Retrieve the retained anomaly snapshots, oldest first.
pub fn anomalies() -> Vec<AnomalySnapshot> {
    lock_unpoisoned(black_box()).iter().cloned().collect()
}

/// Drop every retained anomaly snapshot.
pub fn clear_anomalies() {
    lock_unpoisoned(black_box()).clear();
}

/// Serializes tests that flip the global enable flag or inspect global
/// ring/black-box state (the library test binary runs tests in
/// parallel).
#[cfg(test)]
pub(crate) fn test_guard() -> MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    lock_unpoisoned(&GUARD)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    fn ev(trace_id: u64, payload: u64) -> TraceEvent {
        TraceEvent { ns: super::super::trace::monotonic_ns(), trace_id, payload, stage: 3, kind: 0 }
    }

    #[test]
    fn disabled_record_is_one_branch_and_touches_nothing() {
        let _g = test_guard();
        set_enabled(false);
        let marker = 0xD15A_B1ED_0000_0001u64;
        let rings_before = ring_count();
        // Structural half: a disabled record returns before the
        // thread-local, so no ring is created even on a fresh thread.
        std::thread::spawn(move || {
            for i in 0..64 {
                record(ev(marker, i));
            }
        })
        .join()
        .unwrap();
        assert_eq!(ring_count(), rings_before, "disabled record must not allocate a ring");
        assert!(
            snapshot(usize::MAX).iter().all(|e| e.trace_id != marker),
            "disabled record must not store events"
        );
        // Timing half: the gate adds one relaxed load + branch to a
        // histogram-record loop. Bound is deliberately loose (CI noise).
        let h = crate::obs::LatencyHistogram::default();
        const N: u64 = 200_000;
        let t0 = Instant::now();
        for i in 0..N {
            h.record(i & 0xFFF);
        }
        let bare = t0.elapsed();
        let t1 = Instant::now();
        for i in 0..N {
            record(ev(marker, i));
            h.record(i & 0xFFF);
        }
        let gated = t1.elapsed();
        assert!(
            gated < bare * 3 + Duration::from_millis(20),
            "disabled recorder must be noise: bare={bare:?} gated={gated:?}"
        );
    }

    #[test]
    fn enabled_ring_captures_and_overwrites_oldest() {
        let _g = test_guard();
        set_enabled(true);
        let marker = 0xD15A_B1ED_0000_0002u64;
        let total = RING_CAPACITY as u64 + 17;
        for i in 0..total {
            record(ev(marker, i));
        }
        set_enabled(false);
        let mine: Vec<TraceEvent> =
            snapshot(usize::MAX).into_iter().filter(|e| e.trace_id == marker).collect();
        assert_eq!(mine.len(), RING_CAPACITY, "ring holds exactly its capacity");
        let payloads: std::collections::HashSet<u64> =
            mine.iter().map(|e| e.payload).collect();
        for old in 0..17 {
            assert!(!payloads.contains(&old), "oldest events must be overwritten");
        }
        for recent in 17..total {
            assert!(payloads.contains(&recent), "recent event {recent} missing");
        }
    }

    #[test]
    fn exited_threads_rings_are_reused_and_stay_readable() {
        // Pushes straight into the thread-local ring (no global enable)
        // so no concurrently running test can race the lease free-list.
        let _g = test_guard();
        let marker = 0xD15A_B1ED_0000_0003u64;
        let first = std::thread::spawn(move || {
            TL_RING.with(|l| {
                l.0.push(ev(marker, 1));
                Arc::as_ptr(&l.0) as usize
            })
        })
        .join()
        .unwrap();
        // The dead thread's event is still dumpable.
        assert!(
            snapshot(usize::MAX).iter().any(|e| e.trace_id == marker && e.payload == 1),
            "events must survive their thread"
        );
        // A new thread leases a freed ring instead of growing the list.
        let rings_between = ring_count();
        let second = std::thread::spawn(move || {
            TL_RING.with(|l| {
                l.0.push(ev(marker, 2));
                Arc::as_ptr(&l.0) as usize
            })
        })
        .join()
        .unwrap();
        assert_eq!(first, second, "a freed ring must be reused");
        assert_eq!(ring_count(), rings_between, "no new ring for a reused lease");
    }

    #[test]
    fn black_box_freezes_events_and_stays_bounded() {
        let _g = test_guard();
        clear_anomalies();
        set_enabled(true);
        let marker = 0xD15A_B1ED_0000_0004u64;
        record(ev(marker, 99));
        note_anomaly("test anomaly");
        set_enabled(false);
        let got = anomalies();
        let last = got.last().expect("snapshot retained");
        assert_eq!(last.label, "test anomaly");
        assert!(last.unix_ns > 0);
        assert!(
            last.events.iter().any(|e| e.trace_id == marker && e.payload == 99),
            "black box must contain the ring's events"
        );
        for i in 0..(BLACK_BOX_CAPACITY + 3) {
            note_anomaly(&format!("overflow {i}"));
        }
        let got = anomalies();
        assert_eq!(got.len(), BLACK_BOX_CAPACITY, "black box must stay bounded");
        assert_eq!(got.last().unwrap().label, format!("overflow {}", BLACK_BOX_CAPACITY + 2));
        clear_anomalies();
    }

    #[test]
    fn snapshot_caps_to_most_recent() {
        let _g = test_guard();
        let marker = 0xD15A_B1ED_0000_0005u64;
        TL_RING.with(|l| {
            for i in 0..32 {
                l.0.push(ev(marker, i));
            }
        });
        let capped = snapshot(8);
        assert!(capped.len() <= 8);
        // The kept tail is the newest slice of the merged timeline.
        let all = snapshot(usize::MAX);
        assert_eq!(&all[all.len() - capped.len()..], &capped[..]);
    }
}
