//! Input batching: accumulate the 32-bit word stream into fixed-size
//! batches (the burst granularity handed to pipeline workers).

/// Accumulates words and emits full batches.
#[derive(Debug)]
pub struct Batcher {
    batch_size: usize,
    buf: Vec<u32>,
}

impl Batcher {
    pub fn new(batch_size: usize) -> Self {
        assert!(batch_size > 0);
        Self { batch_size, buf: Vec::with_capacity(batch_size) }
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Push a slice; invoke `emit` for every completed batch.
    pub fn push<E: FnMut(Vec<u32>)>(&mut self, mut words: &[u32], mut emit: E) {
        while !words.is_empty() {
            let room = self.batch_size - self.buf.len();
            let take = room.min(words.len());
            self.buf.extend_from_slice(&words[..take]);
            words = &words[take..];
            if self.buf.len() == self.batch_size {
                let full = std::mem::replace(&mut self.buf, Vec::with_capacity(self.batch_size));
                emit(full);
            }
        }
    }

    /// Emit whatever remains (the final partial batch).
    pub fn flush<E: FnMut(Vec<u32>)>(&mut self, mut emit: E) {
        if !self.buf.is_empty() {
            let partial = std::mem::take(&mut self.buf);
            emit(partial);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect(batch_size: usize, pushes: &[&[u32]]) -> Vec<Vec<u32>> {
        let mut b = Batcher::new(batch_size);
        let mut out = Vec::new();
        for p in pushes {
            b.push(p, |v| out.push(v));
        }
        b.flush(|v| out.push(v));
        out
    }

    #[test]
    fn exact_multiples() {
        let out = collect(4, &[&[1, 2, 3, 4, 5, 6, 7, 8]]);
        assert_eq!(out, vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]]);
    }

    #[test]
    fn partial_tail_flushes() {
        let out = collect(4, &[&[1, 2, 3, 4, 5]]);
        assert_eq!(out, vec![vec![1, 2, 3, 4], vec![5]]);
    }

    #[test]
    fn fragmented_pushes_reassemble() {
        let out = collect(4, &[&[1], &[2, 3], &[4, 5, 6, 7, 8, 9]]);
        assert_eq!(out, vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8], vec![9]]);
    }

    #[test]
    fn empty_flush_is_silent() {
        let out = collect(4, &[&[]]);
        assert!(out.is_empty());
    }

    #[test]
    fn preserves_order_and_multiset() {
        let words: Vec<u32> = (0..1000).collect();
        let mut b = Batcher::new(7);
        let mut all = Vec::new();
        b.push(&words, |v| all.extend(v));
        b.flush(|v| all.extend(v));
        assert_eq!(all, words);
    }
}
