//! The streaming coordinator — the paper's system contribution mapped to
//! software: a leader thread slices the incoming 32-bit word stream
//! across k pipeline workers (Fig 3), each aggregating into a private
//! sketch through a pluggable [`crate::runtime::Engine`] (pure Rust, or
//! the PJRT-executed JAX/Pallas artifacts); partial sketches are folded
//! by bucket-wise max and the computation phase produces the estimate.
//!
//! Backpressure is structural: bounded queues between leader and workers
//! block the feeder exactly like AXI-stream backpressure toward the
//! DMA/NIC in the hardware design.

pub mod batch;
pub mod config;
pub mod keyed;
pub mod metrics;
pub mod worker;

pub use config::CoordinatorConfig;
pub use keyed::{
    run_keyed_stream, run_keyed_stream_with_engine, KeyedCoordinator, KeyedRunSummary,
    KeyedWorkerReport,
};
pub use metrics::{Metrics, MetricsSnapshot, WorkerReport};

use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::hll::HllSketch;
use crate::runtime::{EstimateOut, NativeEngine, Result, RuntimeError, XlaHandle};

use batch::Batcher;

/// Summary of a completed run.
#[derive(Debug)]
pub struct RunSummary {
    /// The merged sketch (bucket-wise max over worker partials).
    pub sketch: HllSketch,
    /// Computation-phase output over the merged sketch.
    pub estimate: EstimateOut,
    pub metrics: MetricsSnapshot,
    pub workers: Vec<WorkerReport>,
    /// Wall time from `start` to merge completion.
    pub elapsed: std::time::Duration,
}

impl RunSummary {
    /// Feeder-side throughput in bytes/s.
    pub fn throughput_bytes_per_s(&self) -> f64 {
        (self.metrics.words_in * 4) as f64 / self.elapsed.as_secs_f64()
    }
}

type WorkerResult = Result<(HllSketch, WorkerReport)>;

/// A running coordinator.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    txs: Vec<SyncSender<Vec<u32>>>,
    handles: Vec<JoinHandle<WorkerResult>>,
    metrics: Arc<Metrics>,
    batcher: Batcher,
    next_worker: usize,
    started: Instant,
    /// Kept for the final merge/estimate when running on the XLA engine.
    xla: Option<XlaHandle>,
}

impl Coordinator {
    /// Spawn workers. `xla` is required when `cfg.engine == Xla`.
    pub fn start(cfg: CoordinatorConfig, xla: Option<XlaHandle>) -> Result<Self> {
        cfg.validate().map_err(RuntimeError::Shape)?;
        let metrics = Arc::new(Metrics::default());
        let mut txs = Vec::with_capacity(cfg.pipelines);
        let mut handles = Vec::with_capacity(cfg.pipelines);
        for w in 0..cfg.pipelines {
            let (tx, rx) = sync_channel::<Vec<u32>>(cfg.queue_depth);
            let engine = cfg.engine.build(cfg.hll, xla.clone(), cfg.batch_size)?;
            let m = metrics.clone();
            let hll = cfg.hll;
            let handle = std::thread::Builder::new()
                .name(format!("pipeline-{w}"))
                .spawn(move || worker::run_worker(w, hll, engine, rx, m))
                .expect("spawn worker");
            txs.push(tx);
            handles.push(handle);
        }
        crate::log_info!(
            "coordinator",
            "started {} pipeline workers (engine={:?}, batch={}, depth={})",
            cfg.pipelines,
            cfg.engine,
            cfg.batch_size,
            cfg.queue_depth
        );
        Ok(Self {
            cfg,
            txs,
            handles,
            metrics,
            batcher: Batcher::new(cfg.batch_size),
            next_worker: 0,
            started: Instant::now(),
            xla,
        })
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    fn route(
        txs: &[SyncSender<Vec<u32>>],
        metrics: &Metrics,
        next_worker: &mut usize,
        batch: Vec<u32>,
    ) {
        // Round-robin slicing ("inputs are processed where they arrive",
        // Section V-B) with blocking backpressure on a full queue.
        let w = *next_worker;
        *next_worker = (w + 1) % txs.len();
        metrics
            .batches_routed
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        match txs[w].try_send(batch) {
            Ok(()) => {}
            Err(TrySendError::Full(batch)) => {
                metrics
                    .backpressure_stalls
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                // Block until the worker catches up — lossless, exactly
                // like stream backpressure in fabric.
                txs[w].send(batch).expect("worker hung up early");
            }
            Err(TrySendError::Disconnected(_)) => panic!("worker hung up early"),
        }
    }

    /// Feed a slice of the stream.
    pub fn feed(&mut self, words: &[u32]) {
        self.metrics
            .words_in
            .fetch_add(words.len() as u64, std::sync::atomic::Ordering::Relaxed);
        let txs = &self.txs;
        let metrics = &self.metrics;
        let next = &mut self.next_worker;
        self.batcher
            .push(words, |batch| Self::route(txs, metrics, next, batch));
    }

    /// Close the stream: flush the partial batch, join workers, fold the
    /// partial sketches (merge phase), and run the computation phase.
    pub fn finish(mut self) -> Result<RunSummary> {
        let txs = std::mem::take(&mut self.txs);
        {
            let metrics = &self.metrics;
            let next = &mut self.next_worker;
            self.batcher
                .flush(|batch| Self::route(&txs, metrics, next, batch));
        }
        drop(txs); // close queues; workers drain and exit

        let mut partials = Vec::with_capacity(self.handles.len());
        let mut reports = Vec::with_capacity(self.handles.len());
        for handle in self.handles.drain(..) {
            let (sketch, report) = handle.join().expect("worker panicked")?;
            partials.push(sketch);
            reports.push(report);
        }

        // Merge fold (Fig 3 "Merge buckets") + computation phase, on the
        // same engine kind the workers used.
        let engine = self
            .cfg
            .engine
            .build(self.cfg.hll, self.xla.clone(), self.cfg.batch_size)?;
        let mut merged = partials.pop().unwrap_or_else(|| HllSketch::new(self.cfg.hll));
        for p in &partials {
            engine.merge(&mut merged, p)?;
        }
        let estimate = engine.estimate(&merged)?;
        let elapsed = self.started.elapsed();
        Ok(RunSummary {
            sketch: merged,
            estimate,
            metrics: self.metrics.snapshot(),
            workers: reports,
            elapsed,
        })
    }
}

/// Convenience: one-shot run over a whole in-memory stream.
pub fn run_stream(
    cfg: CoordinatorConfig,
    xla: Option<XlaHandle>,
    words: &[u32],
) -> Result<RunSummary> {
    let mut c = Coordinator::start(cfg, xla)?;
    c.feed(words);
    c.finish()
}

/// Single-threaded reference run (no workers) — the ground truth the
/// coordinator must match bit-exactly.
pub fn run_serial(cfg: &CoordinatorConfig, words: &[u32]) -> (HllSketch, EstimateOut) {
    use crate::runtime::Engine as _;
    let mut s = HllSketch::new(cfg.hll);
    s.insert_batch(words);
    let e = NativeEngine.estimate(&s).expect("native estimate");
    (s, e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Engine as _, EngineKind};
    use crate::util::Xoshiro256StarStar;

    fn words(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
        (0..n).map(|_| rng.next_u32()).collect()
    }

    #[test]
    fn coordinator_matches_serial_across_shapes() {
        for (pipelines, batch, n) in
            [(1usize, 100usize, 5000usize), (4, 256, 10_000), (10, 8192, 100_000), (3, 7, 1000)]
        {
            let cfg = CoordinatorConfig {
                pipelines,
                batch_size: batch,
                ..CoordinatorConfig::default()
            };
            let data = words(n, 42);
            let summary = run_stream(cfg, None, &data).unwrap();
            let (serial, serial_est) = run_serial(&cfg, &data);
            assert_eq!(summary.sketch, serial, "k={pipelines} batch={batch} n={n}");
            assert_eq!(summary.estimate.estimate, serial_est.estimate);
            assert_eq!(summary.metrics.words_in, n as u64);
        }
    }

    #[test]
    fn empty_stream() {
        let cfg = CoordinatorConfig::default();
        let summary = run_stream(cfg, None, &[]).unwrap();
        assert_eq!(summary.estimate.estimate, 0.0);
        assert_eq!(summary.metrics.batches_routed, 0);
    }

    #[test]
    fn incremental_feeding_equals_bulk() {
        let cfg = CoordinatorConfig {
            pipelines: 4,
            batch_size: 64,
            ..CoordinatorConfig::default()
        };
        let data = words(10_000, 7);
        let mut c = Coordinator::start(cfg, None).unwrap();
        for chunk in data.chunks(33) {
            c.feed(chunk);
        }
        let a = c.finish().unwrap();
        let b = run_stream(cfg, None, &data).unwrap();
        assert_eq!(a.sketch, b.sketch);
    }

    #[test]
    fn backpressure_is_lossless() {
        // Tiny queues + many batches: stalls must not lose data.
        let cfg = CoordinatorConfig {
            pipelines: 2,
            batch_size: 16,
            queue_depth: 1,
            ..CoordinatorConfig::default()
        };
        let data = words(50_000, 9);
        let summary = run_stream(cfg, None, &data).unwrap();
        let (serial, _) = run_serial(&cfg, &data);
        assert_eq!(summary.sketch, serial);
        assert_eq!(
            summary.metrics.batches_done,
            summary.metrics.batches_routed,
            "all routed batches processed"
        );
    }

    #[test]
    fn worker_reports_cover_all_words() {
        let cfg = CoordinatorConfig {
            pipelines: 5,
            batch_size: 100,
            ..CoordinatorConfig::default()
        };
        let data = words(12_345, 11);
        let summary = run_stream(cfg, None, &data).unwrap();
        let total: u64 = summary.workers.iter().map(|w| w.words).sum();
        assert_eq!(total, 12_345);
        assert_eq!(summary.workers.len(), 5);
    }

    #[test]
    fn estimate_accuracy_through_coordinator() {
        let cfg = CoordinatorConfig { pipelines: 8, ..CoordinatorConfig::default() };
        let n = 200_000;
        let data: Vec<u32> = crate::stats::DistinctStream::new(n, 5).collect();
        let summary = run_stream(cfg, None, &data).unwrap();
        let rel = (summary.estimate.estimate - n as f64).abs() / n as f64;
        assert!(rel < 0.02, "estimate {} vs {n}", summary.estimate.estimate);
    }

    #[test]
    fn engine_kind_native_builds_without_runtime() {
        let engine = EngineKind::Native.build(crate::hll::HllConfig::PAPER, None, 128).unwrap();
        assert_eq!(engine.name(), "native");
    }
}
