//! Pipeline worker: one thread per pipeline (Fig 3's aggregation
//! pipelines), each owning a private sketch and an `Engine` backend.

use std::sync::mpsc::Receiver;
use std::sync::Arc;

use super::metrics::{Metrics, WorkerReport};
use crate::hll::{HllConfig, HllSketch};
use crate::runtime::{Engine, Result};

/// Run one worker to queue exhaustion; returns its partial sketch and
/// report. Executed on a dedicated thread by the coordinator.
pub fn run_worker(
    worker: usize,
    cfg: HllConfig,
    engine: Box<dyn Engine>,
    rx: Receiver<Vec<u32>>,
    metrics: Arc<Metrics>,
) -> Result<(HllSketch, WorkerReport)> {
    let mut sketch = HllSketch::new(cfg);
    let mut batches = 0u64;
    let mut words = 0u64;
    let mut busy = std::time::Duration::ZERO;
    while let Ok(batch) = rx.recv() {
        let t0 = std::time::Instant::now();
        engine.aggregate(&batch, &mut sketch)?;
        busy += t0.elapsed();
        batches += 1;
        words += batch.len() as u64;
        metrics
            .batches_done
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
    crate::log_debug!(
        "worker",
        "worker {worker} done: {batches} batches, {words} words, busy {:?}",
        busy
    );
    Ok((sketch, WorkerReport { worker, batches, words, busy }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeEngine;
    use std::sync::mpsc::sync_channel;

    #[test]
    fn worker_aggregates_all_batches() {
        let cfg = HllConfig::PAPER;
        let (tx, rx) = sync_channel::<Vec<u32>>(4);
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let handle =
            std::thread::spawn(move || run_worker(0, cfg, Box::new(NativeEngine), rx, m2));
        let mut expect = HllSketch::new(cfg);
        for i in 0..10u32 {
            let batch: Vec<u32> = (i * 100..(i + 1) * 100).collect();
            expect.insert_batch(&batch);
            tx.send(batch).unwrap();
        }
        drop(tx);
        let (sketch, report) = handle.join().unwrap().unwrap();
        assert_eq!(sketch, expect);
        assert_eq!(report.batches, 10);
        assert_eq!(report.words, 1000);
        assert_eq!(metrics.snapshot().batches_done, 10);
    }
}
