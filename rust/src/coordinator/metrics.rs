//! Coordinator metrics: lock-free counters shared between the feeder and
//! workers, snapshotted into reports.

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared counters (one instance per coordinator run).
#[derive(Debug, Default)]
pub struct Metrics {
    pub words_in: AtomicU64,
    pub batches_routed: AtomicU64,
    /// Times the feeder blocked on a full worker queue (backpressure).
    pub backpressure_stalls: AtomicU64,
    /// Batches processed, summed over workers.
    pub batches_done: AtomicU64,
}

impl Metrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            words_in: self.words_in.load(Ordering::Relaxed),
            batches_routed: self.batches_routed.load(Ordering::Relaxed),
            backpressure_stalls: self.backpressure_stalls.load(Ordering::Relaxed),
            batches_done: self.batches_done.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of the counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub words_in: u64,
    pub batches_routed: u64,
    pub backpressure_stalls: u64,
    pub batches_done: u64,
}

/// Per-worker report returned at join time.
#[derive(Debug, Clone, Copy)]
pub struct WorkerReport {
    pub worker: usize,
    pub batches: u64,
    pub words: u64,
    /// Time spent inside `Engine::aggregate`.
    pub busy: std::time::Duration,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::default();
        m.words_in.fetch_add(100, Ordering::Relaxed);
        m.batches_routed.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.words_in, 100);
        assert_eq!(s.batches_routed, 2);
        assert_eq!(s.backpressure_stalls, 0);
    }
}
