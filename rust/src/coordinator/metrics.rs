//! Coordinator metrics: lock-free counters shared between the feeder and
//! workers, snapshotted into reports.
//!
//! The counters are [`crate::obs::Counter`] handles, so a coordinator
//! constructed with [`Metrics::registered`] exposes them through a
//! [`MetricsRegistry`] exposition with zero double-accounting — the
//! same cells back both the registry scrape and [`Metrics::snapshot`].
//! `Metrics::default()` keeps working for standalone runs (the handles
//! just aren't registered anywhere).

use std::sync::atomic::Ordering;

use crate::obs::{Counter, MetricsRegistry};

/// Shared counters (one instance per coordinator run). The fields
/// deref to `AtomicU64`, so hot-path sites `fetch_add` directly.
#[derive(Debug, Default)]
pub struct Metrics {
    pub words_in: Counter,
    pub batches_routed: Counter,
    /// Times the feeder blocked on a full worker queue (backpressure).
    pub backpressure_stalls: Counter,
    /// Batches processed, summed over workers.
    pub batches_done: Counter,
}

impl Metrics {
    /// Counters registered into `m` under `coordinator_*` names, so a
    /// host process's exposition carries them.
    pub fn registered(m: &MetricsRegistry) -> Self {
        Self {
            words_in: m.counter("coordinator_words_in_total", None),
            batches_routed: m.counter("coordinator_batches_routed_total", None),
            backpressure_stalls: m.counter("coordinator_backpressure_stalls_total", None),
            batches_done: m.counter("coordinator_batches_done_total", None),
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            words_in: self.words_in.load(Ordering::Relaxed),
            batches_routed: self.batches_routed.load(Ordering::Relaxed),
            backpressure_stalls: self.backpressure_stalls.load(Ordering::Relaxed),
            batches_done: self.batches_done.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of the counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub words_in: u64,
    pub batches_routed: u64,
    pub backpressure_stalls: u64,
    pub batches_done: u64,
}

/// Per-worker report returned at join time.
#[derive(Debug, Clone, Copy)]
pub struct WorkerReport {
    pub worker: usize,
    pub batches: u64,
    pub words: u64,
    /// Time spent inside `Engine::aggregate`.
    pub busy: std::time::Duration,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::default();
        m.words_in.fetch_add(100, Ordering::Relaxed);
        m.batches_routed.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.words_in, 100);
        assert_eq!(s.batches_routed, 2);
        assert_eq!(s.backpressure_stalls, 0);
    }

    #[test]
    fn registered_counters_feed_the_exposition() {
        let reg = MetricsRegistry::shared();
        let m = Metrics::registered(&reg);
        m.words_in.fetch_add(42, Ordering::Relaxed);
        m.batches_done.fetch_add(3, Ordering::Relaxed);
        assert_eq!(m.snapshot().words_in, 42);
        let text = reg.render();
        assert!(text.contains("coordinator_words_in_total 42\n"));
        assert!(text.contains("coordinator_batches_done_total 3\n"));
        assert!(text.contains("coordinator_backpressure_stalls_total 0\n"));
    }
}
