//! Keyed-ingest mode: the coordinator front-end for the multi-tenant
//! [`crate::registry::SketchRegistry`].
//!
//! The single-stream coordinator slices one word stream round-robin over
//! k pipeline workers. Keyed mode dispatches `(key, word)` batches *by
//! shard* instead: every registry shard is owned by exactly one worker
//! (`worker = shard % pipelines`), so shard mutexes are never contended
//! — the same "inputs are processed where they arrive" discipline the
//! paper uses for its input slicer (Section V-B), applied to lock
//! stripes instead of wires. Backpressure is identical to the unkeyed
//! path: bounded queues block the feeder when a worker falls behind.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::config::CoordinatorConfig;
use super::metrics::{Metrics, MetricsSnapshot};
use crate::obs::{Span, Stage};
use crate::registry::SketchRegistry;

/// Per-worker report for a keyed run.
#[derive(Debug, Clone, Copy)]
pub struct KeyedWorkerReport {
    pub worker: usize,
    pub batches: u64,
    pub words: u64,
    /// Time spent inside registry ingest.
    pub busy: std::time::Duration,
}

/// Summary of a completed keyed run.
#[derive(Debug)]
pub struct KeyedRunSummary {
    /// Live keys in the registry after the run.
    pub keys: usize,
    /// Distinct count across all keys, if the registry tracks it.
    pub global_estimate: Option<f64>,
    pub metrics: MetricsSnapshot,
    pub workers: Vec<KeyedWorkerReport>,
    pub elapsed: std::time::Duration,
}

impl KeyedRunSummary {
    /// Feeder-side throughput in (key, word) pairs per second.
    pub fn pairs_per_s(&self) -> f64 {
        self.metrics.words_in as f64 / self.elapsed.as_secs_f64()
    }
}

/// One routed pair: (shard, key, word). The feeder computes the shard
/// once; workers never re-hash the key.
type RoutedPair = (usize, u64, u32);

/// A running keyed coordinator over a shared registry.
pub struct KeyedCoordinator {
    registry: Arc<SketchRegistry<u64>>,
    txs: Vec<SyncSender<Vec<RoutedPair>>>,
    handles: Vec<JoinHandle<KeyedWorkerReport>>,
    metrics: Arc<Metrics>,
    /// Per-worker accumulation buffers (flushed at `batch_size`).
    buffers: Vec<Vec<RoutedPair>>,
    batch_size: usize,
    started: Instant,
}

fn run_keyed_worker(
    worker: usize,
    registry: Arc<SketchRegistry<u64>>,
    rx: Receiver<Vec<RoutedPair>>,
    metrics: Arc<Metrics>,
) -> KeyedWorkerReport {
    let mut batches = 0u64;
    let mut words = 0u64;
    let mut busy = std::time::Duration::ZERO;
    while let Ok(mut batch) = rx.recv() {
        let t0 = Instant::now();
        // Untraced span (keyed batches carry no wire trace context):
        // with the flight recorder armed, per-batch worker_ingest
        // begin/end pairs still land in this thread's ring.
        let _span = Span::enter(Stage::WorkerIngest, 0).with_payload(batch.len() as u64);
        // Group by the precomputed shard (register updates commute, so
        // the unstable sort's reordering cannot change any sketch) and
        // ingest each run under one shard-lock acquisition.
        batch.sort_unstable_by_key(|&(shard, _, _)| shard);
        let mut rest: &[RoutedPair] = &batch;
        while let Some(&(shard, _, _)) = rest.first() {
            let run = rest.iter().take_while(|&&(s, _, _)| s == shard).count();
            registry.ingest_routed_run(&rest[..run]);
            rest = &rest[run..];
        }
        busy += t0.elapsed();
        batches += 1;
        words += batch.len() as u64;
        metrics
            .batches_done
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
    crate::log_debug!(
        "keyed-worker",
        "worker {worker} done: {batches} batches, {words} pairs, busy {:?}",
        busy
    );
    KeyedWorkerReport { worker, batches, words, busy }
}

impl KeyedCoordinator {
    /// Spawn keyed pipeline workers over `registry`. Uses `pipelines`,
    /// `batch_size` and `queue_depth` from `cfg`; `cfg.hll` must match
    /// the registry's sketch config.
    pub fn start(
        cfg: &CoordinatorConfig,
        registry: Arc<SketchRegistry<u64>>,
    ) -> Result<Self, String> {
        cfg.validate()?;
        if cfg.hll != registry.config().hll {
            return Err(format!(
                "coordinator hll config {:?} does not match registry {:?}",
                cfg.hll,
                registry.config().hll
            ));
        }
        let metrics = Arc::new(Metrics::default());
        let mut txs = Vec::with_capacity(cfg.pipelines);
        let mut handles = Vec::with_capacity(cfg.pipelines);
        for w in 0..cfg.pipelines {
            let (tx, rx) = sync_channel::<Vec<RoutedPair>>(cfg.queue_depth);
            let reg = registry.clone();
            let m = metrics.clone();
            let handle = std::thread::Builder::new()
                .name(format!("keyed-pipeline-{w}"))
                .spawn(move || run_keyed_worker(w, reg, rx, m))
                .expect("spawn keyed worker");
            txs.push(tx);
            handles.push(handle);
        }
        crate::log_info!(
            "coordinator",
            "keyed mode: {} workers over {} shards (batch={}, depth={})",
            cfg.pipelines,
            registry.config().shards,
            cfg.batch_size,
            cfg.queue_depth
        );
        Ok(Self {
            buffers: vec![Vec::with_capacity(cfg.batch_size); cfg.pipelines],
            batch_size: cfg.batch_size,
            registry,
            txs,
            handles,
            metrics,
            started: Instant::now(),
        })
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    fn route(
        txs: &[SyncSender<Vec<RoutedPair>>],
        metrics: &Metrics,
        worker: usize,
        batch: Vec<RoutedPair>,
    ) {
        metrics
            .batches_routed
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        match txs[worker].try_send(batch) {
            Ok(()) => {}
            Err(TrySendError::Full(batch)) => {
                metrics
                    .backpressure_stalls
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                txs[worker].send(batch).expect("keyed worker hung up early");
            }
            Err(TrySendError::Disconnected(_)) => panic!("keyed worker hung up early"),
        }
    }

    /// Feed a slice of keyed pairs; full per-worker batches are shipped
    /// as they fill.
    pub fn feed(&mut self, pairs: &[(u64, u32)]) {
        self.metrics
            .words_in
            .fetch_add(pairs.len() as u64, std::sync::atomic::Ordering::Relaxed);
        let workers = self.txs.len();
        for &(key, word) in pairs {
            let shard = self.registry.shard_of(&key);
            let w = shard % workers;
            self.buffers[w].push((shard, key, word));
            if self.buffers[w].len() >= self.batch_size {
                let full =
                    std::mem::replace(&mut self.buffers[w], Vec::with_capacity(self.batch_size));
                Self::route(&self.txs, &self.metrics, w, full);
            }
        }
    }

    /// Close the stream: flush partial batches, join workers, snapshot.
    pub fn finish(mut self) -> KeyedRunSummary {
        for (w, buf) in self.buffers.iter_mut().enumerate() {
            if !buf.is_empty() {
                let batch = std::mem::take(buf);
                Self::route(&self.txs, &self.metrics, w, batch);
            }
        }
        let txs = std::mem::take(&mut self.txs);
        drop(txs); // close queues; workers drain and exit

        let mut workers = Vec::with_capacity(self.handles.len());
        for handle in self.handles.drain(..) {
            workers.push(handle.join().expect("keyed worker panicked"));
        }
        KeyedRunSummary {
            keys: self.registry.len(),
            global_estimate: self.registry.global_estimate(),
            metrics: self.metrics.snapshot(),
            workers,
            elapsed: self.started.elapsed(),
        }
    }
}

/// Convenience: one-shot keyed run over an in-memory pair stream.
pub fn run_keyed_stream(
    cfg: &CoordinatorConfig,
    registry: Arc<SketchRegistry<u64>>,
    pairs: &[(u64, u32)],
) -> Result<KeyedRunSummary, String> {
    let mut c = KeyedCoordinator::start(cfg, registry)?;
    c.feed(pairs);
    Ok(c.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::{AdaptiveSketch, HllConfig, HllSketch};
    use crate::registry::RegistryConfig;
    use crate::util::Xoshiro256StarStar;

    fn pairs(n: usize, keys: u64, seed: u64) -> Vec<(u64, u32)> {
        let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
        (0..n).map(|_| (rng.next_u64_below(keys), rng.next_u32())).collect()
    }

    #[test]
    fn keyed_run_matches_per_key_serial_reference() {
        let registry = SketchRegistry::shared(RegistryConfig {
            shards: 16,
            ..RegistryConfig::default()
        })
        .unwrap();
        let cfg = CoordinatorConfig { pipelines: 4, batch_size: 256, ..Default::default() };
        let data = pairs(30_000, 200, 1);
        let summary = run_keyed_stream(&cfg, registry.clone(), &data).unwrap();
        assert_eq!(summary.metrics.words_in, 30_000);
        assert_eq!(summary.keys, 200);

        // Each key's estimate equals a serially built reference sketch.
        let mut refs: std::collections::HashMap<u64, AdaptiveSketch> =
            std::collections::HashMap::new();
        let mut all = HllSketch::new(HllConfig::PAPER);
        for &(k, w) in &data {
            refs.entry(k)
                .or_insert_with(|| AdaptiveSketch::new(HllConfig::PAPER))
                .insert_u32(w);
            all.insert_u32(w);
        }
        for (key, reference) in refs.iter_mut() {
            assert_eq!(registry.estimate(key), Some(reference.estimate()), "key {key}");
        }
        // Global union is bit-identical to the serial whole-stream sketch.
        assert_eq!(registry.merge_all(), all);
        assert_eq!(summary.global_estimate, Some(all.estimate()));
    }

    #[test]
    fn worker_reports_cover_all_pairs() {
        let registry = SketchRegistry::shared(RegistryConfig {
            shards: 8,
            ..RegistryConfig::default()
        })
        .unwrap();
        let cfg = CoordinatorConfig { pipelines: 3, batch_size: 100, ..Default::default() };
        let data = pairs(12_345, 50, 2);
        let summary = run_keyed_stream(&cfg, registry, &data).unwrap();
        let total: u64 = summary.workers.iter().map(|w| w.words).sum();
        assert_eq!(total, 12_345);
        assert_eq!(summary.workers.len(), 3);
        assert_eq!(summary.metrics.batches_done, summary.metrics.batches_routed);
    }

    #[test]
    fn incremental_feeding_equals_bulk() {
        let mk = || {
            SketchRegistry::shared(RegistryConfig { shards: 8, ..RegistryConfig::default() })
                .unwrap()
        };
        let cfg = CoordinatorConfig { pipelines: 2, batch_size: 64, ..Default::default() };
        let data = pairs(10_000, 100, 3);

        let bulk_reg = mk();
        run_keyed_stream(&cfg, bulk_reg.clone(), &data).unwrap();

        let inc_reg = mk();
        let mut c = KeyedCoordinator::start(&cfg, inc_reg.clone()).unwrap();
        for chunk in data.chunks(33) {
            c.feed(chunk);
        }
        c.finish();

        assert_eq!(bulk_reg.merge_all(), inc_reg.merge_all());
        assert_eq!(bulk_reg.len(), inc_reg.len());
    }

    #[test]
    fn config_mismatch_rejected() {
        let registry = SketchRegistry::shared(RegistryConfig {
            hll: crate::hll::HllConfig::new(12, crate::hll::HashKind::H64).unwrap(),
            ..RegistryConfig::default()
        })
        .unwrap();
        let cfg = CoordinatorConfig::default(); // PAPER hll
        assert!(KeyedCoordinator::start(&cfg, registry).is_err());
    }
}
