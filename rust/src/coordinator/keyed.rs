//! Keyed-ingest mode: the coordinator front-end for the multi-tenant
//! [`crate::registry::SketchRegistry`].
//!
//! The single-stream coordinator slices one word stream round-robin over
//! k pipeline workers. Keyed mode dispatches `(key, word)` batches *by
//! shard* instead: every registry shard is owned by exactly one worker
//! (`worker = shard % pipelines`), so shard mutexes are never contended
//! — the same "inputs are processed where they arrive" discipline the
//! paper uses for its input slicer (Section V-B), applied to lock
//! stripes instead of wires. Backpressure is identical to the unkeyed
//! path: bounded queues block the feeder when a worker falls behind.
//!
//! Two worker backends fold a routed batch into the registry:
//!
//! * **Registry** (the default, [`KeyedCoordinator::start`]) — whole
//!   shard runs go through [`SketchRegistry::ingest_routed_run`]: one
//!   batched hash pass, one lock acquisition per shard run, adaptive
//!   sparse/packed/dense tiers per key.
//! * **Engine** ([`KeyedCoordinator::start_with_engine`]) — each
//!   same-key run is aggregated by a [`crate::runtime::Engine`]
//!   (native or the XLA/Pallas pipeline) into a dense sketch and
//!   bucket-wise max-merged in. Merge commutes with insertion, so the
//!   final register files are bit-identical to the registry backend's.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::config::CoordinatorConfig;
use super::metrics::{Metrics, MetricsSnapshot};
use crate::hll::HllSketch;
use crate::obs::{Span, Stage};
use crate::registry::SketchRegistry;
use crate::runtime::{Engine, XlaHandle};

/// Per-worker report for a keyed run.
#[derive(Debug, Clone, Copy)]
pub struct KeyedWorkerReport {
    pub worker: usize,
    pub batches: u64,
    pub words: u64,
    /// Time spent inside registry ingest.
    pub busy: std::time::Duration,
}

/// Summary of a completed keyed run.
#[derive(Debug)]
pub struct KeyedRunSummary {
    /// Live keys in the registry after the run.
    pub keys: usize,
    /// Distinct count across all keys, if the registry tracks it.
    pub global_estimate: Option<f64>,
    pub metrics: MetricsSnapshot,
    pub workers: Vec<KeyedWorkerReport>,
    pub elapsed: std::time::Duration,
}

impl KeyedRunSummary {
    /// Feeder-side throughput in (key, word) pairs per second.
    pub fn pairs_per_s(&self) -> f64 {
        self.metrics.words_in as f64 / self.elapsed.as_secs_f64()
    }
}

/// One routed pair: (shard, key, word). The feeder computes the shard
/// once; workers never re-hash the key.
type RoutedPair = (usize, u64, u32);

/// Adaptive batch sizing: a batch is worth growing only while sorting
/// it still lengthens same-key runs — the ingest fold amortizes one map
/// lookup and one dirty resolution per key *run*, so the flush target
/// is the observed run length × this factor (≈ this many runs per
/// batch), clamped to `[ADAPTIVE_BATCH_FLOOR, cfg.batch_size]`.
/// High-dispersion streams (runs ≈ 1, which a bigger sort cannot
/// improve) flush small low-latency batches; hot-keyed streams grow to
/// the configured ceiling, where one lock acquisition folds thousands
/// of pairs.
const RUN_AMORTIZATION: usize = 64;

/// Floor of the adaptive flush target: channel/sort fixed costs stay
/// amortized even when every run has length 1.
const ADAPTIVE_BATCH_FLOOR: usize = 256;

/// Fold one batch's observed mean run length into the per-worker EMA
/// (fixed-point, ×256; 0 = no observation yet). Quarter-weight
/// exponential decay: a workload shift re-targets within a few batches
/// without any single skewed batch yanking the threshold around.
fn fold_run_ema(prev: u64, batch_len: usize, distinct_keys: usize) -> u64 {
    let obs = ((batch_len as u64) << 8) / distinct_keys.max(1) as u64;
    if prev == 0 {
        obs
    } else {
        prev - prev / 4 + obs / 4
    }
}

/// The feeder's flush threshold for a worker given its run-length EMA:
/// `run_length × RUN_AMORTIZATION`, clamped. An untouched EMA (no batch
/// folded yet) targets the ceiling — the configured batch size.
fn flush_target_for(ema: u64, ceiling: usize) -> usize {
    if ema == 0 {
        return ceiling;
    }
    let target = (ema as usize).saturating_mul(RUN_AMORTIZATION) >> 8;
    target.clamp(ADAPTIVE_BATCH_FLOOR.min(ceiling), ceiling)
}

/// How a keyed worker folds its sorted batch into the registry.
enum KeyedBackend {
    /// Direct path: whole shard runs through
    /// [`SketchRegistry::ingest_routed_run`] (adaptive tiers, batched
    /// hashing, one lock acquisition per shard run).
    Registry,
    /// Compute-engine path: each same-key run is aggregated into a
    /// dense sketch by the engine (native loop or the XLA/Pallas
    /// artifacts) and max-merged into the key. Exact under merge
    /// commutativity; dirty tracking records the merge as a full
    /// resend.
    Engine(Box<dyn Engine>),
}

/// A running keyed coordinator over a shared registry.
pub struct KeyedCoordinator {
    registry: Arc<SketchRegistry<u64>>,
    txs: Vec<SyncSender<Vec<RoutedPair>>>,
    handles: Vec<JoinHandle<KeyedWorkerReport>>,
    metrics: Arc<Metrics>,
    /// Per-worker accumulation buffers, flushed at that worker's
    /// adaptive target (≤ `batch_size`).
    buffers: Vec<Vec<RoutedPair>>,
    /// The configured batch size — now the *ceiling* of the adaptive
    /// flush target.
    batch_size: usize,
    /// Per-worker observed run-length EMA (fixed-point ×256), written
    /// by the worker after each sort, read by the feeder to size the
    /// next flush.
    run_ema: Vec<Arc<AtomicU64>>,
    started: Instant,
}

fn run_keyed_worker(
    worker: usize,
    registry: Arc<SketchRegistry<u64>>,
    backend: KeyedBackend,
    rx: Receiver<Vec<RoutedPair>>,
    metrics: Arc<Metrics>,
    run_ema: Arc<AtomicU64>,
) -> KeyedWorkerReport {
    let hll = registry.config().hll;
    let mut batches = 0u64;
    let mut words = 0u64;
    let mut busy = std::time::Duration::ZERO;
    // Engine-backend word buffer, reused across runs and batches.
    let mut run_words: Vec<u32> = Vec::new();
    while let Ok(mut batch) = rx.recv() {
        let t0 = Instant::now();
        // Untraced span (keyed batches carry no wire trace context):
        // with the flight recorder armed, per-batch worker_ingest
        // begin/end pairs still land in this thread's ring. One span
        // per routed batch, not per word or per run.
        let _span = Span::enter(Stage::WorkerIngest, 0).with_payload(batch.len() as u64);
        // Sort by (shard, key): shards group so each shard run is one
        // lock acquisition, and equal keys within a shard become one
        // maximal run — one map lookup and one dirty resolution per key
        // per batch downstream. Register updates commute, so the
        // unstable sort's reordering cannot change any sketch.
        batch.sort_unstable_by_key(|&(shard, key, _)| (shard, key));
        // Feed the adaptive batch sizer: mean same-key run length in
        // this sorted batch (a key lives on exactly one shard, so key
        // transitions alone count the runs).
        let distinct = 1 + batch.windows(2).filter(|pair| pair[0].1 != pair[1].1).count();
        let prev = run_ema.load(Ordering::Relaxed);
        run_ema.store(fold_run_ema(prev, batch.len(), distinct), Ordering::Relaxed);
        match &backend {
            KeyedBackend::Registry => {
                let mut rest: &[RoutedPair] = &batch;
                while let Some(&(shard, _, _)) = rest.first() {
                    let run = rest.iter().take_while(|&&(s, _, _)| s == shard).count();
                    registry.ingest_routed_run(&rest[..run]);
                    rest = &rest[run..];
                }
            }
            KeyedBackend::Engine(engine) => {
                let mut rest: &[RoutedPair] = &batch;
                while let Some(&(_, key, _)) = rest.first() {
                    let run = rest.iter().take_while(|&&(_, k, _)| k == key).count();
                    run_words.clear();
                    run_words.extend(rest[..run].iter().map(|&(_, _, w)| w));
                    let mut sketch = HllSketch::new(hll);
                    engine
                        .aggregate(&run_words, &mut sketch)
                        .expect("keyed engine aggregate failed");
                    registry
                        .merge_sketch(key, sketch)
                        .expect("engine sketch config matches registry");
                    rest = &rest[run..];
                }
            }
        }
        busy += t0.elapsed();
        batches += 1;
        words += batch.len() as u64;
        metrics
            .batches_done
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
    crate::log_debug!(
        "keyed-worker",
        "worker {worker} done: {batches} batches, {words} pairs, busy {:?}",
        busy
    );
    KeyedWorkerReport { worker, batches, words, busy }
}

impl KeyedCoordinator {
    /// Spawn keyed pipeline workers over `registry` using the direct
    /// registry backend. Uses `pipelines`, `batch_size` and
    /// `queue_depth` from `cfg`; `cfg.hll` must match the registry's
    /// sketch config. (`cfg.engine` selects the backend of
    /// [`Self::start_with_engine`] only; this path always ingests
    /// through the registry's adaptive tiers.)
    pub fn start(
        cfg: &CoordinatorConfig,
        registry: Arc<SketchRegistry<u64>>,
    ) -> Result<Self, String> {
        let backends = (0..cfg.pipelines).map(|_| KeyedBackend::Registry).collect();
        Self::start_with_backends(cfg, registry, backends)
    }

    /// Spawn keyed pipeline workers that aggregate each key run through
    /// a compute engine built from `cfg.engine` (one engine instance
    /// per worker, mirroring the unkeyed coordinator) and max-merge the
    /// result into the registry. `xla` is required when `cfg.engine` is
    /// [`crate::runtime::EngineKind::Xla`].
    pub fn start_with_engine(
        cfg: &CoordinatorConfig,
        registry: Arc<SketchRegistry<u64>>,
        xla: Option<XlaHandle>,
    ) -> Result<Self, String> {
        cfg.validate()?;
        let mut backends = Vec::with_capacity(cfg.pipelines);
        for _ in 0..cfg.pipelines {
            let engine = cfg
                .engine
                .build(cfg.hll, xla.clone(), cfg.batch_size)
                .map_err(|e| format!("keyed engine backend: {e}"))?;
            backends.push(KeyedBackend::Engine(engine));
        }
        Self::start_with_backends(cfg, registry, backends)
    }

    fn start_with_backends(
        cfg: &CoordinatorConfig,
        registry: Arc<SketchRegistry<u64>>,
        backends: Vec<KeyedBackend>,
    ) -> Result<Self, String> {
        cfg.validate()?;
        if cfg.hll != registry.config().hll {
            return Err(format!(
                "coordinator hll config {:?} does not match registry {:?}",
                cfg.hll,
                registry.config().hll
            ));
        }
        let metrics = Arc::new(Metrics::default());
        let mut txs = Vec::with_capacity(cfg.pipelines);
        let mut handles = Vec::with_capacity(cfg.pipelines);
        let mut run_ema = Vec::with_capacity(cfg.pipelines);
        for (w, backend) in backends.into_iter().enumerate() {
            let (tx, rx) = sync_channel::<Vec<RoutedPair>>(cfg.queue_depth);
            let reg = registry.clone();
            let m = metrics.clone();
            let ema = Arc::new(AtomicU64::new(0));
            let worker_ema = ema.clone();
            let handle = std::thread::Builder::new()
                .name(format!("keyed-pipeline-{w}"))
                .spawn(move || run_keyed_worker(w, reg, backend, rx, m, worker_ema))
                .expect("spawn keyed worker");
            txs.push(tx);
            handles.push(handle);
            run_ema.push(ema);
        }
        crate::log_info!(
            "coordinator",
            "keyed mode: {} workers over {} shards (batch≤{} adaptive, depth={})",
            cfg.pipelines,
            registry.config().shards,
            cfg.batch_size,
            cfg.queue_depth
        );
        Ok(Self {
            buffers: vec![Vec::with_capacity(cfg.batch_size); cfg.pipelines],
            batch_size: cfg.batch_size,
            run_ema,
            registry,
            txs,
            handles,
            metrics,
            started: Instant::now(),
        })
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    fn route(
        txs: &[SyncSender<Vec<RoutedPair>>],
        metrics: &Metrics,
        worker: usize,
        batch: Vec<RoutedPair>,
    ) {
        metrics
            .batches_routed
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        match txs[worker].try_send(batch) {
            Ok(()) => {}
            Err(TrySendError::Full(batch)) => {
                metrics
                    .backpressure_stalls
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                txs[worker].send(batch).expect("keyed worker hung up early");
            }
            Err(TrySendError::Disconnected(_)) => panic!("keyed worker hung up early"),
        }
    }

    /// Feed a slice of keyed pairs; per-worker batches are shipped when
    /// they reach that worker's adaptive flush target (observed run
    /// length × [`RUN_AMORTIZATION`], clamped to
    /// `[ADAPTIVE_BATCH_FLOOR, batch_size]`).
    pub fn feed(&mut self, pairs: &[(u64, u32)]) {
        self.metrics
            .words_in
            .fetch_add(pairs.len() as u64, std::sync::atomic::Ordering::Relaxed);
        let workers = self.txs.len();
        for &(key, word) in pairs {
            let shard = self.registry.shard_of(&key);
            let w = shard % workers;
            self.buffers[w].push((shard, key, word));
            let target = flush_target_for(self.run_ema[w].load(Ordering::Relaxed), self.batch_size);
            if self.buffers[w].len() >= target {
                let full = std::mem::replace(&mut self.buffers[w], Vec::with_capacity(target));
                Self::route(&self.txs, &self.metrics, w, full);
            }
        }
    }

    /// Close the stream: flush partial batches, join workers, snapshot.
    pub fn finish(mut self) -> KeyedRunSummary {
        for (w, buf) in self.buffers.iter_mut().enumerate() {
            if !buf.is_empty() {
                let batch = std::mem::take(buf);
                Self::route(&self.txs, &self.metrics, w, batch);
            }
        }
        let txs = std::mem::take(&mut self.txs);
        drop(txs); // close queues; workers drain and exit

        let mut workers = Vec::with_capacity(self.handles.len());
        for handle in self.handles.drain(..) {
            workers.push(handle.join().expect("keyed worker panicked"));
        }
        KeyedRunSummary {
            keys: self.registry.len(),
            global_estimate: self.registry.global_estimate(),
            metrics: self.metrics.snapshot(),
            workers,
            elapsed: self.started.elapsed(),
        }
    }
}

/// Convenience: one-shot keyed run over an in-memory pair stream.
pub fn run_keyed_stream(
    cfg: &CoordinatorConfig,
    registry: Arc<SketchRegistry<u64>>,
    pairs: &[(u64, u32)],
) -> Result<KeyedRunSummary, String> {
    let mut c = KeyedCoordinator::start(cfg, registry)?;
    c.feed(pairs);
    Ok(c.finish())
}

/// As [`run_keyed_stream`], through the engine backend selected by
/// `cfg.engine` (see [`KeyedCoordinator::start_with_engine`]).
pub fn run_keyed_stream_with_engine(
    cfg: &CoordinatorConfig,
    registry: Arc<SketchRegistry<u64>>,
    xla: Option<XlaHandle>,
    pairs: &[(u64, u32)],
) -> Result<KeyedRunSummary, String> {
    let mut c = KeyedCoordinator::start_with_engine(cfg, registry, xla)?;
    c.feed(pairs);
    Ok(c.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::{AdaptiveSketch, HllConfig, HllSketch};
    use crate::registry::RegistryConfig;
    use crate::util::Xoshiro256StarStar;

    fn pairs(n: usize, keys: u64, seed: u64) -> Vec<(u64, u32)> {
        let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
        (0..n).map(|_| (rng.next_u64_below(keys), rng.next_u32())).collect()
    }

    #[test]
    fn keyed_run_matches_per_key_serial_reference() {
        let registry = SketchRegistry::shared(RegistryConfig {
            shards: 16,
            ..RegistryConfig::default()
        })
        .unwrap();
        let cfg = CoordinatorConfig { pipelines: 4, batch_size: 256, ..Default::default() };
        let data = pairs(30_000, 200, 1);
        let summary = run_keyed_stream(&cfg, registry.clone(), &data).unwrap();
        assert_eq!(summary.metrics.words_in, 30_000);
        assert_eq!(summary.keys, 200);

        // Each key's estimate equals a serially built reference sketch.
        let mut refs: std::collections::HashMap<u64, AdaptiveSketch> =
            std::collections::HashMap::new();
        let mut all = HllSketch::new(HllConfig::PAPER);
        for &(k, w) in &data {
            refs.entry(k)
                .or_insert_with(|| AdaptiveSketch::new(HllConfig::PAPER))
                .insert_u32(w);
            all.insert_u32(w);
        }
        for (key, reference) in refs.iter_mut() {
            assert_eq!(registry.estimate(key), Some(reference.estimate()), "key {key}");
        }
        // Global union is bit-identical to the serial whole-stream sketch.
        assert_eq!(registry.merge_all(), all);
        assert_eq!(summary.global_estimate, Some(all.estimate()));
    }

    #[test]
    fn worker_reports_cover_all_pairs() {
        let registry = SketchRegistry::shared(RegistryConfig {
            shards: 8,
            ..RegistryConfig::default()
        })
        .unwrap();
        let cfg = CoordinatorConfig { pipelines: 3, batch_size: 100, ..Default::default() };
        let data = pairs(12_345, 50, 2);
        let summary = run_keyed_stream(&cfg, registry, &data).unwrap();
        let total: u64 = summary.workers.iter().map(|w| w.words).sum();
        assert_eq!(total, 12_345);
        assert_eq!(summary.workers.len(), 3);
        assert_eq!(summary.metrics.batches_done, summary.metrics.batches_routed);
    }

    #[test]
    fn incremental_feeding_equals_bulk() {
        let mk = || {
            SketchRegistry::shared(RegistryConfig { shards: 8, ..RegistryConfig::default() })
                .unwrap()
        };
        let cfg = CoordinatorConfig { pipelines: 2, batch_size: 64, ..Default::default() };
        let data = pairs(10_000, 100, 3);

        let bulk_reg = mk();
        run_keyed_stream(&cfg, bulk_reg.clone(), &data).unwrap();

        let inc_reg = mk();
        let mut c = KeyedCoordinator::start(&cfg, inc_reg.clone()).unwrap();
        for chunk in data.chunks(33) {
            c.feed(chunk);
        }
        c.finish();

        assert_eq!(bulk_reg.merge_all(), inc_reg.merge_all());
        assert_eq!(bulk_reg.len(), inc_reg.len());
    }

    #[test]
    fn engine_backend_matches_registry_backend() {
        let mk = || {
            SketchRegistry::shared(RegistryConfig { shards: 16, ..RegistryConfig::default() })
                .unwrap()
        };
        let cfg = CoordinatorConfig { pipelines: 4, batch_size: 512, ..Default::default() };
        let data = pairs(25_000, 150, 9);

        let direct = mk();
        run_keyed_stream(&cfg, direct.clone(), &data).unwrap();

        // Native engine backend: each key run aggregates through
        // Engine::aggregate and max-merges in. Merge commutes with
        // insertion, so the union and — because the Ertl estimator is a
        // pure function of the register file — every per-key estimate
        // must match the direct path exactly.
        let engined = mk();
        let summary = run_keyed_stream_with_engine(&cfg, engined.clone(), None, &data).unwrap();
        assert_eq!(summary.metrics.words_in, 25_000);
        assert_eq!(engined.len(), direct.len());
        assert_eq!(engined.merge_all(), direct.merge_all());
        assert_eq!(engined.global_estimate(), direct.global_estimate());
        for (key, est) in direct.estimates() {
            assert_eq!(engined.estimate(&key), Some(est), "key {key}");
        }
    }

    #[test]
    fn engine_backend_without_handle_rejects_xla() {
        let registry = SketchRegistry::shared(RegistryConfig::default()).unwrap();
        let cfg = CoordinatorConfig {
            engine: crate::runtime::EngineKind::Xla,
            ..Default::default()
        };
        assert!(KeyedCoordinator::start_with_engine(&cfg, registry, None).is_err());
    }

    #[test]
    fn adaptive_targets_move_with_run_length() {
        // No observation yet: flush at the configured ceiling.
        assert_eq!(flush_target_for(0, 8192), 8192);

        // Hot stream: 8192-pair batches covering only 2 distinct keys
        // (mean run 4096). run × 64 saturates far above the ceiling, so
        // the target clamps to the configured batch size.
        let mut ema = 0u64;
        for _ in 0..32 {
            ema = fold_run_ema(ema, 8192, 2);
        }
        assert_eq!(flush_target_for(ema, 8192), 8192);

        // Dispersed stream: every pair a distinct key (mean run 1).
        // 1 × 64 = 64 is below the floor, so the target clamps to
        // ADAPTIVE_BATCH_FLOOR — small, low-latency flushes.
        for _ in 0..32 {
            ema = fold_run_ema(ema, 8192, 8192);
        }
        assert_eq!(flush_target_for(ema, 8192), ADAPTIVE_BATCH_FLOOR);

        // Mid-range workload: mean run 8 → target 8 × 64 = 512, inside
        // the clamp window (quarter-weight EMA converges to ~run×256
        // fixed-point; allow the ±1 integer-fixpoint wobble).
        let mut mid = 0u64;
        for _ in 0..64 {
            mid = fold_run_ema(mid, 8192, 1024);
        }
        let target = flush_target_for(mid, 8192);
        assert!((448..=576).contains(&target), "mid target {target}");

        // A tiny ceiling wins over the floor.
        assert_eq!(flush_target_for(1 << 8, 128), 128);
    }

    #[test]
    fn adaptive_flush_preserves_results() {
        // End-to-end: a hot-keyed stream (long runs → large targets)
        // and a dispersed stream (floor-sized flushes) both produce
        // registries identical to the fixed-batch serial reference.
        let mk = || {
            SketchRegistry::shared(RegistryConfig { shards: 8, ..RegistryConfig::default() })
                .unwrap()
        };
        let cfg = CoordinatorConfig { pipelines: 2, batch_size: 4096, ..Default::default() };

        // Hot: 4 keys over 40k pairs — runs are long, EMA drives the
        // target toward the ceiling after the first flush.
        let hot = pairs(40_000, 4, 7);
        let adaptive_reg = mk();
        run_keyed_stream(&cfg, adaptive_reg.clone(), &hot).unwrap();
        let reference_reg = mk();
        let small = CoordinatorConfig { pipelines: 1, batch_size: 64, ..Default::default() };
        run_keyed_stream(&small, reference_reg.clone(), &hot).unwrap();
        assert_eq!(adaptive_reg.merge_all(), reference_reg.merge_all());

        // Dispersed: ~20k distinct keys — the EMA collapses to run≈1
        // and flushes drop to the floor without changing any sketch.
        let dispersed = pairs(20_000, 1 << 20, 11);
        let a = mk();
        run_keyed_stream(&cfg, a.clone(), &dispersed).unwrap();
        let b = mk();
        run_keyed_stream(&small, b.clone(), &dispersed).unwrap();
        assert_eq!(a.merge_all(), b.merge_all());
        assert_eq!(a.len(), b.len());
    }

    #[test]
    fn config_mismatch_rejected() {
        let registry = SketchRegistry::shared(RegistryConfig {
            hll: crate::hll::HllConfig::new(12, crate::hll::HashKind::H64).unwrap(),
            ..RegistryConfig::default()
        })
        .unwrap();
        let cfg = CoordinatorConfig::default(); // PAPER hll
        assert!(KeyedCoordinator::start(&cfg, registry).is_err());
    }
}
