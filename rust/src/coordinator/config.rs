//! Coordinator configuration.

use crate::hll::HllConfig;
use crate::runtime::EngineKind;

/// Configuration of the streaming coordinator — the software analogue of
/// the paper's multi-pipelined architecture (Fig 3): k workers, each the
/// counterpart of one aggregation pipeline, fed by slicing the input.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorConfig {
    pub hll: HllConfig,
    /// Number of pipeline workers (the paper's k).
    pub pipelines: usize,
    /// Words per batch handed to a worker (the DMA/burst granularity).
    pub batch_size: usize,
    /// Bounded queue depth per worker, in batches — the backpressure
    /// knob (queue-full blocks the feeder, like AXI-stream back-pressure
    /// toward the NIC/DMA).
    pub queue_depth: usize,
    /// Which compute backend each worker uses.
    pub engine: EngineKind,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            hll: HllConfig::PAPER,
            pipelines: 4,
            batch_size: 8192,
            queue_depth: 4,
            engine: EngineKind::Native,
        }
    }
}

impl CoordinatorConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.pipelines == 0 {
            return Err("pipelines must be >= 1".into());
        }
        if self.batch_size == 0 {
            return Err("batch_size must be >= 1".into());
        }
        if self.queue_depth == 0 {
            return Err("queue_depth must be >= 1".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        assert!(CoordinatorConfig::default().validate().is_ok());
    }

    #[test]
    fn zero_fields_rejected() {
        let mut c = CoordinatorConfig::default();
        c.pipelines = 0;
        assert!(c.validate().is_err());
        let mut c = CoordinatorConfig::default();
        c.batch_size = 0;
        assert!(c.validate().is_err());
        let mut c = CoordinatorConfig::default();
        c.queue_depth = 0;
        assert!(c.validate().is_err());
    }
}
