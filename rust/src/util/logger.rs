//! A minimal leveled logger (the offline crate set has no `log`/`env_logger`).
//!
//! Controlled by `HLL_LOG` (error|warn|info|debug|trace, default `info`).
//! The coordinator, network simulator and runtime use this for progress
//! and diagnostics; it writes to stderr so report tables on stdout stay
//! machine-parsable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn from_env(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = "uninitialized"

fn max_level() -> u8 {
    let v = MAX_LEVEL.load(Ordering::Relaxed);
    if v != u8::MAX {
        return v;
    }
    let lvl = std::env::var("HLL_LOG")
        .ok()
        .and_then(|s| Level::from_env(&s))
        .unwrap_or(Level::Info) as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Override the level programmatically (tests, `--verbose`).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= max_level()
}

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    eprintln!("[{:>10.4}s {} {}] {}", t, level.as_str(), target, msg);
}

#[macro_export]
macro_rules! log_error { ($target:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, $target, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($target:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, $target, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($target:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, $target, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($target:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, $target, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_trace { ($target:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Trace, $target, format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_env("debug"), Some(Level::Debug));
        assert_eq!(Level::from_env("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_env("nope"), None);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
