//! Deterministic pseudo-random number generation.
//!
//! The offline crate set does not include `rand`, so this module provides
//! the generators the repository needs: [`SplitMix64`] for seeding and
//! [`Xoshiro256StarStar`] as the general-purpose engine (the same pairing
//! `rand_xoshiro` uses). Both are tested against the reference outputs of
//! their published C implementations.

/// SplitMix64 — Steele, Lea & Flood (2014). Used to expand a single `u64`
/// seed into the 256-bit state of [`Xoshiro256StarStar`].
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — Blackman & Vigna (2018). Fast, high-quality, 256-bit
/// state; the workhorse generator for dataset synthesis and simulation.
#[derive(Debug, Clone)]
pub struct Xoshiro256StarStar {
    s: [u64; 4],
}

impl Xoshiro256StarStar {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection method.
    #[inline]
    pub fn next_u64_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[0, bound)` for `u32` bounds.
    #[inline]
    pub fn next_u32_below(&mut self, bound: u32) -> u32 {
        self.next_u64_below(bound as u64) as u32
    }

    /// Uniform float in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_u64_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// A Zipf(α) sampler over `[1, n]` via an exact precomputed CDF with
/// binary-search inversion. Used by the access-log workload generator in
/// the end-to-end example; domains there are ≤ a few million, so the
/// O(n) table is cheap and the sampling is exact.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: u64, alpha: f64) -> Self {
        assert!(n >= 1, "Zipf domain must be non-empty");
        assert!(alpha > 0.0, "alpha must be positive");
        let mut cdf = Vec::with_capacity(n as usize);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += (k as f64).powf(-alpha);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Self { cdf }
    }

    /// Draw a rank in `[1, n]`; rank 1 is the most frequent item.
    pub fn sample(&self, rng: &mut Xoshiro256StarStar) -> u64 {
        let u = rng.next_f64();
        // First index whose cdf >= u.
        let idx = self.cdf.partition_point(|&c| c < u);
        (idx.min(self.cdf.len() - 1) + 1) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix64_reference_sequence() {
        // Reference values from the published C implementation
        // (seed = 1234567).
        let mut sm = SplitMix64::new(1234567);
        let expect = [
            6457827717110365317u64,
            3203168211198807973,
            9817491932198370423,
            4593380528125082431,
            16408922859458223821,
        ];
        for e in expect {
            assert_eq!(sm.next_u64(), e);
        }
    }

    #[test]
    fn xoshiro_is_deterministic_and_distinct() {
        let mut a = Xoshiro256StarStar::seed_from_u64(42);
        let mut b = Xoshiro256StarStar::seed_from_u64(42);
        let mut c = Xoshiro256StarStar::seed_from_u64(43);
        let sa: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let sb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let sc: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(sa, sb);
        assert_ne!(sa, sc);
    }

    #[test]
    fn bounded_sampling_is_in_range() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(7);
        for bound in [1u64, 2, 3, 10, 1000, u32::MAX as u64] {
            for _ in 0..200 {
                assert!(rng.next_u64_below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(9);
        for _ in 0..1000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bounded_sampling_covers_small_ranges() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(11);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[rng.next_u64_below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_samples_in_domain_and_skewed() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(21);
        let z = Zipf::new(1000, 1.2);
        let mut head = 0usize;
        for _ in 0..2000 {
            let k = z.sample(&mut rng);
            assert!((1..=1000).contains(&k));
            if k <= 10 {
                head += 1;
            }
        }
        // With alpha=1.2 the top-10 mass is large; loose sanity bound.
        assert!(head > 400, "zipf head mass too small: {head}");
    }
}
