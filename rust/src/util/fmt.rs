//! Human-readable formatting helpers for benchmark tables and reports.

/// Format a byte-per-second rate the way the paper does (GByte/s with two
/// decimals, Gbit/s when asked).
pub fn gbytes_per_s(bytes_per_s: f64) -> String {
    format!("{:.2} GB/s", bytes_per_s / 1e9)
}

pub fn gbits_per_s(bytes_per_s: f64) -> String {
    format!("{:.2} Gbit/s", bytes_per_s * 8.0 / 1e9)
}

/// Format an item count with thousands separators (`12_345_678` →
/// `12,345,678`).
pub fn count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let bytes = s.as_bytes();
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*b as char);
    }
    out
}

/// Format a duration in engineering units.
pub fn duration_s(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.3} s")
    } else if seconds >= 1e-3 {
        format!("{:.3} ms", seconds * 1e3)
    } else if seconds >= 1e-6 {
        format!("{:.3} µs", seconds * 1e6)
    } else {
        format!("{:.1} ns", seconds * 1e9)
    }
}

/// Render a percentage with a sign-aware fixed width.
pub fn pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

/// A minimal fixed-width text table builder used by every `repro`
/// subcommand and bench report so tables render consistently.
#[derive(Debug, Default)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<width$} |", c, width = w));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_separators() {
        assert_eq!(count(0), "0");
        assert_eq!(count(999), "999");
        assert_eq!(count(1000), "1,000");
        assert_eq!(count(1234567), "1,234,567");
    }

    #[test]
    fn rates() {
        assert_eq!(gbytes_per_s(12.48e9), "12.48 GB/s");
        assert_eq!(gbits_per_s(1.2875e9), "10.30 Gbit/s");
    }

    #[test]
    fn durations() {
        assert_eq!(duration_s(2.5), "2.500 s");
        assert_eq!(duration_s(203e-6), "203.000 µs");
        assert_eq!(duration_s(3.1e-9), "3.1 ns");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = TextTable::new(vec!["Pipelines", "Throughput"]);
        t.row(vec!["1", "0.05"]).row(vec!["16", "9.35"]);
        let s = t.render();
        assert!(s.contains("| Pipelines | Throughput |"));
        assert!(s.lines().count() == 4);
        // All lines same width.
        let lens: Vec<usize> = s.lines().map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }
}
