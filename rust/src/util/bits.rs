//! Bit-manipulation helpers shared across the HLL core, the FPGA
//! simulator's leading-zero-detector stage, and the runtime.

/// Number of leading zeros of `w` when interpreted as a `width`-bit word
/// (`width` ≤ 64). This is the FPGA "Leading Zero Detector" stage; the
/// paper implements it with the HLS `CountLeadingZero` primitive, CPUs
/// with `LZCNT`.
#[inline]
pub fn leading_zeros_width(w: u64, width: u32) -> u32 {
    debug_assert!(width >= 1 && width <= 64);
    debug_assert!(width == 64 || w < (1u64 << width));
    if w == 0 {
        width
    } else {
        w.leading_zeros() - (64 - width)
    }
}

/// The HLL rank ρ(w): leading zeros within a `width`-bit word plus one.
/// For `w == 0` the rank is `width + 1` (the maximum observable rank,
/// eq. (2) of the paper: ρ ≤ H − p + 1).
#[inline]
pub fn rho(w: u64, width: u32) -> u8 {
    (leading_zeros_width(w, width) + 1) as u8
}

/// Ceil of log2 for positive integers — register width in bits needed to
/// hold values in `[0, n]`... specifically the paper's eq. (3) uses
/// ⌈log2(H − p + 1)⌉ as the per-bucket register size.
#[inline]
pub fn ceil_log2(n: u64) -> u32 {
    debug_assert!(n >= 1);
    if n <= 1 {
        0
    } else {
        64 - (n - 1).leading_zeros()
    }
}

/// Rotate-left on 64-bit words (Murmur3 building block; maps to the
/// FPGA's DSP-slice rotate in the paper's pipeline).
#[inline(always)]
pub fn rotl64(x: u64, r: u32) -> u64 {
    x.rotate_left(r)
}

/// Rotate-left on 32-bit words.
#[inline(always)]
pub fn rotl32(x: u32, r: u32) -> u32 {
    x.rotate_left(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leading_zeros_full_width() {
        assert_eq!(leading_zeros_width(0, 64), 64);
        assert_eq!(leading_zeros_width(1, 64), 63);
        assert_eq!(leading_zeros_width(u64::MAX, 64), 0);
    }

    #[test]
    fn leading_zeros_narrow_width() {
        // 48-bit words (the paper's w for p=16, H=64).
        assert_eq!(leading_zeros_width(0, 48), 48);
        assert_eq!(leading_zeros_width(1, 48), 47);
        assert_eq!(leading_zeros_width(1 << 47, 48), 0);
        // 4-bit words (the paper's Table I example).
        assert_eq!(leading_zeros_width(0b0101, 4), 1);
        assert_eq!(leading_zeros_width(0b0001, 4), 3);
        assert_eq!(leading_zeros_width(0b1000, 4), 0);
    }

    #[test]
    fn rho_matches_paper_definition() {
        // ρ(w) = #leading zeros + 1; ρ(0) = width + 1 = max rank.
        assert_eq!(rho(0, 48), 49);
        assert_eq!(rho(1 << 47, 48), 1);
        assert_eq!(rho(1, 48), 48);
        assert_eq!(rho(0, 16), 17);
    }

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        // Paper Table II: ⌈log2(H−p+1)⌉ — (p=14,H=32) → ⌈log2 19⌉ = 5,
        // (p=14,H=64) → ⌈log2 51⌉ = 6, (p=16,H=32) → ⌈log2 17⌉ = 5,
        // (p=16,H=64) → ⌈log2 49⌉ = 6.
        assert_eq!(ceil_log2(19), 5);
        assert_eq!(ceil_log2(51), 6);
        assert_eq!(ceil_log2(17), 5);
        assert_eq!(ceil_log2(49), 6);
    }
}
