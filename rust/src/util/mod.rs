//! Foundational utilities built from scratch for the offline environment:
//! deterministic PRNGs, bit manipulation, formatting, and a tiny logger.

pub mod bits;
pub mod fmt;
pub mod logger;
pub mod prng;

pub use prng::{SplitMix64, Xoshiro256StarStar, Zipf};
