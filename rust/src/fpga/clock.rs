//! Clock-domain model. The paper's design has two domains (Section VII):
//! the network/CMAC domain at 322 MHz (which also drives the HLL
//! pipelines, period 3.1 ns) and the PCIe/XDMA domain at 250 MHz.

/// A fixed-frequency clock domain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClockDomain {
    freq_hz: f64,
}

impl ClockDomain {
    /// The 100G Ethernet / CMAC clock driving the HLL pipelines.
    pub const NETWORK: ClockDomain = ClockDomain { freq_hz: 322e6 };
    /// The XDMA / PCIe subsystem clock.
    pub const PCIE: ClockDomain = ClockDomain { freq_hz: 250e6 };

    pub fn new(freq_hz: f64) -> Self {
        assert!(freq_hz > 0.0);
        Self { freq_hz }
    }

    #[inline]
    pub fn freq_hz(&self) -> f64 {
        self.freq_hz
    }

    /// Clock period in seconds (3.1 ns for the network domain).
    #[inline]
    pub fn period_s(&self) -> f64 {
        1.0 / self.freq_hz
    }

    #[inline]
    pub fn cycles_to_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 * self.period_s()
    }

    #[inline]
    pub fn seconds_to_cycles(&self, seconds: f64) -> u64 {
        (seconds * self.freq_hz).round() as u64
    }

    /// Bytes/second moved by a datapath `width_bytes` wide at this clock
    /// (one beat per cycle, II=1).
    #[inline]
    pub fn throughput_bytes_per_s(&self, width_bytes: usize) -> f64 {
        self.freq_hz * width_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn network_domain_matches_paper() {
        let c = ClockDomain::NETWORK;
        // Period 3.1 ns (Section VI).
        assert!((c.period_s() - 3.1e-9).abs() < 0.05e-9);
        // One 32-bit word per cycle = 10.3 Gbit/s (Section VI).
        let gbit = c.throughput_bytes_per_s(4) * 8.0 / 1e9;
        assert!((gbit - 10.304).abs() < 0.01, "{gbit}");
    }

    #[test]
    fn drain_time_matches_paper() {
        // Section VII: reading all 2^16 buckets takes 203 µs.
        let c = ClockDomain::NETWORK;
        let t = c.cycles_to_seconds(1 << 16);
        assert!((t - 203e-6).abs() < 1e-6, "{t}");
    }

    #[test]
    fn cycle_second_roundtrip() {
        let c = ClockDomain::PCIE;
        assert_eq!(c.seconds_to_cycles(c.cycles_to_seconds(12345)), 12345);
    }
}
