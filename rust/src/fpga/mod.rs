//! Cycle-approximate simulator of the paper's FPGA dataflow architecture
//! (Section V): single pipeline (Fig 2), parallel multi-pipeline engine
//! (Fig 3), the hazard-merging BRAM bucket memory, clock domains, and the
//! Table-III resource model.
//!
//! Substitution note (DESIGN.md §7): the paper measures on a VCU118; this
//! simulator reproduces the design's timing law (II=1 @ 322 MHz, drain =
//! 2^p cycles) and functional semantics exactly, which is what every
//! throughput figure in the evaluation derives from.

pub mod bram;
pub mod clock;
pub mod parallel;
pub mod pipeline;
pub mod resources;

pub use bram::BucketMemory;
pub use clock::ClockDomain;
pub use parallel::{
    theoretical_throughput_bytes_per_s, timing_only_cycles, ParallelHll, ParallelResult,
};
pub use pipeline::{HllPipeline, PipelineResult, StageLatencies};
pub use resources::{Device, ResourceModel, Resources, UtilizationPct};
