//! FPGA resource model — Table III of the paper.
//!
//! Resource usage is a *design property*, not a runtime measurement: it
//! scales linearly in the number of pipelines with a fixed base cost
//! (shared control, AXI plumbing, computation phase). The per-pipeline
//! increments below are derived from the paper's own Table III (p=16,
//! 64-bit hash on a XCVU9P / VCU118); the model reproduces every table
//! entry and extrapolates to arbitrary k, reporting device utilization
//! and the scaling limit (DSP-bound, as the paper observes).

use crate::hll::{HashKind, HllConfig};

/// Resource vector (absolute counts).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Resources {
    pub bram: u32,
    pub dsp: u32,
    pub lut: u32,
    pub ff: u32,
}

impl Resources {
    pub fn utilization(&self, device: &Device) -> UtilizationPct {
        UtilizationPct {
            bram: 100.0 * self.bram as f64 / device.bram as f64,
            dsp: 100.0 * self.dsp as f64 / device.dsp as f64,
            lut: 100.0 * self.lut as f64 / device.lut as f64,
            ff: 100.0 * self.ff as f64 / device.ff as f64,
        }
    }

    pub fn fits(&self, device: &Device) -> bool {
        self.bram <= device.bram
            && self.dsp <= device.dsp
            && self.lut <= device.lut
            && self.ff <= device.ff
    }
}

/// Utilization percentages (as Table III reports them).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UtilizationPct {
    pub bram: f64,
    pub dsp: f64,
    pub lut: f64,
    pub ff: f64,
}

/// FPGA device capacities.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Device {
    pub name: &'static str,
    /// BRAM36 tiles.
    pub bram: u32,
    pub dsp: u32,
    pub lut: u32,
    pub ff: u32,
}

impl Device {
    /// Xilinx Virtex UltraScale+ XCVU9P (VCU118 board) — the paper's
    /// platform. Counts from the UltraScale+ product table.
    pub const XCVU9P: Device = Device {
        name: "XCVU9P",
        bram: 2160,
        dsp: 6840,
        lut: 1_182_240,
        ff: 2_364_480,
    };
}

/// Linear per-pipeline resource model.
#[derive(Debug, Clone, Copy)]
pub struct ResourceModel {
    base: Resources,
    per_pipeline: Resources,
}

impl ResourceModel {
    /// Model for the paper's hardware configuration (p=16, 64-bit hash),
    /// calibrated so that every entry of Table III is reproduced:
    ///
    /// * BRAM:  12 per pipeline (48 KiB of packed counters + margins);
    /// * DSP:   16 shared + 68 per pipeline (Murmur3 multiply chain);
    /// * LUT:   ~3.6 K shared + ~0.96 K per pipeline;
    /// * FF:    ~4.1 K shared + ~1.42 K per pipeline.
    pub fn paper_h64_p16() -> Self {
        Self {
            base: Resources { bram: 0, dsp: 16, lut: 3560, ff: 4080 },
            per_pipeline: Resources { bram: 12, dsp: 68, lut: 960, ff: 1420 },
        }
    }

    /// A 32-bit-hash pipeline needs roughly half the DSP chain and a
    /// 5-bit (vs 6-bit) register file.
    pub fn paper_h32_p16() -> Self {
        Self {
            base: Resources { bram: 0, dsp: 12, lut: 3100, ff: 3600 },
            per_pipeline: Resources { bram: 10, dsp: 34, lut: 760, ff: 1050 },
        }
    }

    pub fn for_config(cfg: &HllConfig) -> Self {
        // BRAM scales with the counter footprint: rescale the p=16 figure
        // by the packed footprint ratio (12 BRAM36 ≈ 48 KiB at p=16/H64).
        let base_model = match cfg.hash() {
            HashKind::H64 => Self::paper_h64_p16(),
            HashKind::H32 => Self::paper_h32_p16(),
        };
        let p16 = HllConfig::new(16, cfg.hash()).expect("p=16 valid");
        let ratio = cfg.footprint_bits() as f64 / p16.footprint_bits() as f64;
        let bram = ((base_model.per_pipeline.bram as f64 * ratio).ceil() as u32).max(1);
        Self {
            base: base_model.base,
            per_pipeline: Resources { bram, ..base_model.per_pipeline },
        }
    }

    pub fn usage(&self, k: usize) -> Resources {
        let k = k as u32;
        Resources {
            bram: self.base.bram + self.per_pipeline.bram * k,
            dsp: self.base.dsp + self.per_pipeline.dsp * k,
            lut: self.base.lut + self.per_pipeline.lut * k,
            ff: self.base.ff + self.per_pipeline.ff * k,
        }
    }

    /// Maximum number of pipelines the device can host — the paper notes
    /// DSP is the binding resource on the XCVU9P.
    pub fn max_pipelines(&self, device: &Device) -> usize {
        let mut k = 0usize;
        while self.usage(k + 1).fits(device) {
            k += 1;
        }
        k
    }

    /// Which resource binds the scaling limit.
    pub fn binding_resource(&self, device: &Device) -> &'static str {
        let kmax = self.max_pipelines(device);
        let next = self.usage(kmax + 1);
        if next.dsp > device.dsp {
            "DSP"
        } else if next.bram > device.bram {
            "BRAM"
        } else if next.lut > device.lut {
            "LUT"
        } else {
            "FF"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_bram_and_dsp_exact() {
        // Paper Table III (p=16, H=64): exact BRAM/DSP per pipeline count.
        let m = ResourceModel::paper_h64_p16();
        let expect = [
            (1usize, 12u32, 84u32),
            (2, 24, 152),
            (4, 48, 288),
            (8, 96, 560),
            (10, 120, 696),
            (16, 192, 1104),
        ];
        for (k, bram, dsp) in expect {
            let u = m.usage(k);
            assert_eq!(u.bram, bram, "BRAM at k={k}");
            assert_eq!(u.dsp, dsp, "DSP at k={k}");
        }
    }

    #[test]
    fn table3_lut_ff_within_tolerance() {
        // LUT/FF are synthesis-dependent; the linear fit must reproduce
        // the table within 10%.
        let m = ResourceModel::paper_h64_p16();
        let expect = [
            (1usize, 4_500u32, 5_500u32),
            (2, 5_500, 6_900),
            (4, 7_300, 9_500),
            (8, 11_200, 15_400),
            (10, 13_100, 18_300),
            (16, 18_900, 26_800),
        ];
        for (k, lut, ff) in expect {
            let u = m.usage(k);
            let lut_err = (u.lut as f64 - lut as f64).abs() / lut as f64;
            let ff_err = (u.ff as f64 - ff as f64).abs() / ff as f64;
            assert!(lut_err < 0.10, "LUT at k={k}: {} vs {lut}", u.lut);
            assert!(ff_err < 0.10, "FF at k={k}: {} vs {ff}", u.ff);
        }
    }

    #[test]
    fn table3_utilization_percentages() {
        // Spot-check the percentages the paper prints: 12 BRAM = 0.55%,
        // 84 DSP = 1.22%, 696 DSP = 10.18%.
        let m = ResourceModel::paper_h64_p16();
        let d = Device::XCVU9P;
        let u1 = m.usage(1).utilization(&d);
        assert!((u1.bram - 0.55).abs() < 0.01, "{}", u1.bram);
        assert!((u1.dsp - 1.22).abs() < 0.01, "{}", u1.dsp);
        let u10 = m.usage(10).utilization(&d);
        assert!((u10.dsp - 10.18).abs() < 0.01, "{}", u10.dsp);
        assert!((u10.bram - 5.55).abs() < 0.01, "{}", u10.bram);
    }

    #[test]
    fn dsp_binds_scaling_on_xcvu9p() {
        let m = ResourceModel::paper_h64_p16();
        let d = Device::XCVU9P;
        let kmax = m.max_pipelines(&d);
        // (6840 - 16) / 68 = 100.35 → 100 pipelines.
        assert_eq!(kmax, 100);
        assert_eq!(m.binding_resource(&d), "DSP");
    }

    #[test]
    fn h32_uses_fewer_resources() {
        let h64 = ResourceModel::paper_h64_p16().usage(10);
        let h32 = ResourceModel::paper_h32_p16().usage(10);
        assert!(h32.dsp < h64.dsp);
        assert!(h32.bram < h64.bram);
    }

    #[test]
    fn config_scaling_reduces_bram_for_small_p() {
        let cfg14 = HllConfig::new(14, HashKind::H64).unwrap();
        let m14 = ResourceModel::for_config(&cfg14);
        let m16 = ResourceModel::paper_h64_p16();
        assert!(m14.usage(1).bram < m16.usage(1).bram);
    }
}
