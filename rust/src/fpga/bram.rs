//! Dual-port BRAM bucket memory with the pipelined read-modify-write
//! update of Section V-A-4.
//!
//! The hardware update is itself a 3-stage pipeline: (a) read the counter
//! at the extracted index, (b) compare with the incoming rank, (c) write
//! back the max. An update to the *same* counter arriving while an
//! earlier one is still in flight would read a stale value; the paper's
//! design "merges" such colliding updates. This module models the
//! three-stage pipeline cycle by cycle, including the hazard-forwarding
//! network, and a test proves the result equals the serial max fold.

/// In-flight update (one per pipeline stage).
#[derive(Debug, Clone, Copy)]
struct Update {
    idx: usize,
    /// Rank being inserted.
    rank: u8,
    /// Value read from the BRAM in stage (a), possibly stale.
    read: u8,
}

/// Cycle-accurate bucket memory: a BRAM array plus the RMW pipeline.
#[derive(Debug, Clone)]
pub struct BucketMemory {
    mem: Vec<u8>,
    /// Stage (b) slot: read done, compare pending.
    stage_b: Option<Update>,
    /// Stage (c) slot: compare done, write pending.
    stage_c: Option<Update>,
    /// Whether hazard forwarding (update merging) is enabled — the
    /// paper's design has it; disabling it demonstrates the data-loss
    /// bug it prevents (see the ablation bench).
    forwarding: bool,
    cycles: u64,
}

impl BucketMemory {
    pub fn new(m: usize) -> Self {
        Self { mem: vec![0; m], stage_b: None, stage_c: None, forwarding: true, cycles: 0 }
    }

    /// Build with hazard forwarding disabled (ablation only — produces
    /// stale-read artifacts under index collisions).
    pub fn without_forwarding(m: usize) -> Self {
        Self { forwarding: false, ..Self::new(m) }
    }

    pub fn len(&self) -> usize {
        self.mem.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mem.is_empty()
    }

    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Advance one clock with an optional new (idx, rank) entering the
    /// pipeline. II = 1: an update can enter every cycle.
    pub fn clock(&mut self, input: Option<(usize, u8)>) {
        self.cycles += 1;

        // Stage (c): write back max(read, rank).
        if let Some(u) = self.stage_c.take() {
            let val = u.read.max(u.rank);
            if val > self.mem[u.idx] {
                self.mem[u.idx] = val;
            } else if !self.forwarding {
                // Without forwarding the write is unconditional — a stale
                // read can *lower* the stored value (the bug merging
                // prevents). Model that faithfully for the ablation.
                self.mem[u.idx] = val;
            }
        }

        // Stage (b) -> (c): compare. With forwarding, a same-index update
        // ahead in stage (c) has already written by now (write happens
        // above in the same cycle), but an update that was in stage (b)
        // last cycle wrote nothing yet — the forwarding network merges by
        // taking the max of the in-flight ranks.
        if let Some(mut u) = self.stage_b.take() {
            if self.forwarding {
                // Re-read (forward) the current memory value — models the
                // bypass mux from the write port.
                u.read = u.read.max(self.mem[u.idx]);
            }
            self.stage_c = Some(u);
        }

        // Stage (a): accept input, read memory.
        if let Some((idx, rank)) = input {
            assert!(idx < self.mem.len(), "bucket index out of range");
            let mut read = self.mem[idx];
            if self.forwarding {
                // Forward from both in-flight stages on an index match.
                if let Some(c) = &self.stage_c {
                    if c.idx == idx {
                        read = read.max(c.read.max(c.rank));
                    }
                }
            }
            self.stage_b = Some(Update { idx, rank, read });
        }
    }

    /// Drain the pipeline (2 idle cycles).
    pub fn flush(&mut self) {
        while self.stage_b.is_some() || self.stage_c.is_some() {
            self.clock(None);
        }
    }

    /// Stream a whole sequence of updates at II=1 and flush.
    pub fn run(&mut self, updates: impl IntoIterator<Item = (usize, u8)>) {
        for u in updates {
            self.clock(Some(u));
        }
        self.flush();
    }

    /// The register file (valid after `flush`).
    pub fn registers(&self) -> &[u8] {
        &self.mem
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::Runner;

    fn serial_max(m: usize, updates: &[(usize, u8)]) -> Vec<u8> {
        let mut regs = vec![0u8; m];
        for &(i, r) in updates {
            if r > regs[i] {
                regs[i] = r;
            }
        }
        regs
    }

    #[test]
    fn no_collisions_simple() {
        let mut b = BucketMemory::new(8);
        b.run([(0, 3), (1, 5), (2, 1)]);
        assert_eq!(&b.registers()[..3], &[3, 5, 1]);
    }

    #[test]
    fn back_to_back_same_index_merges() {
        // The canonical hazard: consecutive updates to one bucket. The
        // second read is stale without forwarding.
        let mut b = BucketMemory::new(4);
        b.run([(2, 5), (2, 3), (2, 4)]);
        assert_eq!(b.registers()[2], 5);

        let mut b = BucketMemory::new(4);
        b.run([(2, 3), (2, 5), (2, 4)]);
        assert_eq!(b.registers()[2], 5);
    }

    #[test]
    fn without_forwarding_loses_updates() {
        // Demonstrate the bug the merge network prevents: rank 5 enters,
        // then rank 3 to the same bucket reads stale 0 and overwrites.
        let mut b = BucketMemory::without_forwarding(4);
        b.run([(2, 5), (2, 3)]);
        assert!(b.registers()[2] < 5, "stale write should have clobbered");
    }

    #[test]
    fn ii_is_one() {
        // n updates + pipeline drain ≤ n + 2 cycles.
        let mut b = BucketMemory::new(16);
        let updates: Vec<(usize, u8)> = (0..1000).map(|i| (i % 16, (i % 7) as u8 + 1)).collect();
        b.run(updates);
        assert!(b.cycles() <= 1000 + 2, "II must be 1: {} cycles", b.cycles());
    }

    #[test]
    fn hazard_merge_equals_serial_max_property() {
        // The core equivalence the paper's design relies on, over random
        // collision-heavy streams.
        Runner::new("bram_hazard_merge").cases(100).run(|g| {
            let m = 1usize << g.usize_in(2..=6);
            let n = g.usize_in(0..=512);
            let updates: Vec<(usize, u8)> = (0..n)
                .map(|_| (g.usize_in(0..=m - 1), g.u32_in(1..=49) as u8))
                .collect();
            let mut b = BucketMemory::new(m);
            b.run(updates.iter().copied());
            assert_eq!(b.registers(), &serial_max(m, &updates)[..]);
        });
    }
}
