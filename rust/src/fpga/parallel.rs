//! The multi-pipelined parallel architecture of Fig. 3: k identical
//! aggregation pipelines fed by slicing the input word stream, partial
//! sketches folded by the "Merge buckets" module, then the single shared
//! computation phase.
//!
//! Input slicing "only implies wiring": words are processed where they
//! arrive with no active reassignment (Section V-B) — modelled as
//! dealing k-word groups across the pipelines each cycle.

use super::clock::ClockDomain;
use super::pipeline::{HllPipeline, StageLatencies};
use crate::hll::{estimate, EstimateBreakdown, HllConfig, HllSketch};

/// The k-pipeline parallel engine.
#[derive(Debug)]
pub struct ParallelHll {
    cfg: HllConfig,
    pipelines: Vec<HllPipeline>,
    clock: ClockDomain,
    words_in: u64,
}

impl ParallelHll {
    pub fn new(cfg: HllConfig, k: usize) -> Self {
        assert!(k >= 1, "need at least one pipeline");
        Self {
            cfg,
            pipelines: (0..k).map(|_| HllPipeline::new(cfg)).collect(),
            clock: ClockDomain::NETWORK,
            words_in: 0,
        }
    }

    pub fn k(&self) -> usize {
        self.pipelines.len()
    }

    pub fn config(&self) -> &HllConfig {
        &self.cfg
    }

    /// Aggregate input bandwidth in bytes/s: k × 32-bit words per cycle.
    pub fn input_bandwidth_bytes_per_s(&self) -> f64 {
        self.clock.throughput_bytes_per_s(4 * self.k())
    }

    /// Feed a word slice; the slicer deals words round-robin in k-word
    /// groups (one group per cycle).
    pub fn feed(&mut self, words: &[u32]) {
        let k = self.k();
        self.words_in += words.len() as u64;
        if k == 1 {
            self.pipelines[0].feed(words);
            return;
        }
        // Deal column i of each k-word group to pipeline i. Collecting
        // per-pipeline slices keeps the per-word cost low while exactly
        // matching the positional slicing of the hardware.
        let mut lanes: Vec<Vec<u32>> = vec![Vec::with_capacity(words.len() / k + 1); k];
        for (i, &w) in words.iter().enumerate() {
            lanes[i % k].push(w);
        }
        for (pipe, lane) in self.pipelines.iter_mut().zip(&lanes) {
            pipe.feed(lane);
        }
    }

    /// Close the stream: merge the partial sketches and run the shared
    /// computation phase. Returns the result plus full cycle accounting.
    pub fn finish(mut self) -> ParallelResult {
        let k = self.k();
        // Aggregation time = the slowest pipeline (they run in lock-step;
        // the slicer gives them equal shares ±1 word).
        let agg_cycles = self
            .pipelines
            .iter()
            .map(|p| p.agg_cycles())
            .max()
            .unwrap_or(0);

        // Merge fold: partial sketches are streamed in parallel and
        // folded bucket by bucket — m cycles pipelined, plus ⌈log2 k⌉
        // fill for the comparator tree.
        let mut merged = vec![0u8; self.cfg.m()];
        for pipe in &mut self.pipelines {
            for (dst, src) in merged.iter_mut().zip(pipe.registers_snapshot()) {
                if src > *dst {
                    *dst = src;
                }
            }
        }
        let merge_cycles = if k > 1 {
            self.cfg.m() as u64 + (usize::BITS - (k - 1).leading_zeros()) as u64
        } else {
            0
        };

        let breakdown = estimate(&self.cfg, &merged);
        // Shared computation phase, identical to the single-pipeline one.
        let drain_cycles = self.cfg.m() as u64 + 32;
        let sketch = HllSketch::from_registers(self.cfg, merged).expect("merged regs valid");

        ParallelResult {
            sketch,
            breakdown,
            k,
            words: self.words_in,
            agg_cycles,
            merge_cycles,
            drain_cycles,
            clock: self.clock,
        }
    }
}

/// Outcome of a completed parallel run.
#[derive(Debug, Clone)]
pub struct ParallelResult {
    pub sketch: HllSketch,
    pub breakdown: EstimateBreakdown,
    pub k: usize,
    pub words: u64,
    pub agg_cycles: u64,
    pub merge_cycles: u64,
    pub drain_cycles: u64,
    pub clock: ClockDomain,
}

impl ParallelResult {
    pub fn total_cycles(&self) -> u64 {
        self.agg_cycles + self.merge_cycles + self.drain_cycles
    }

    pub fn aggregation_seconds(&self) -> f64 {
        self.clock.cycles_to_seconds(self.agg_cycles)
    }

    pub fn total_seconds(&self) -> f64 {
        self.clock.cycles_to_seconds(self.total_cycles())
    }

    /// Sustained aggregation throughput (bytes/s) across all pipelines.
    pub fn throughput_bytes_per_s(&self) -> f64 {
        (self.words * 4) as f64 / self.aggregation_seconds()
    }
}

/// Pure timing model (no functional processing) for large sweeps:
/// aggregation throughput of k pipelines at II=1.
pub fn theoretical_throughput_bytes_per_s(k: usize) -> f64 {
    ClockDomain::NETWORK.throughput_bytes_per_s(4 * k)
}

/// Cycle count to aggregate `words` through k pipelines and finish
/// (merge fold + computation phase), without materializing data.
pub fn timing_only_cycles(cfg: &HllConfig, k: usize, words: u64) -> u64 {
    let fill = StageLatencies::for_config(cfg).fill_latency();
    let agg = words.div_ceil(k as u64) + fill;
    let merge = if k > 1 {
        cfg.m() as u64 + (usize::BITS - (k - 1).leading_zeros()) as u64
    } else {
        0
    };
    agg + merge + cfg.m() as u64 + 32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256StarStar;

    fn cfg() -> HllConfig {
        HllConfig::PAPER
    }

    #[test]
    fn parallel_equals_single_pipeline_functionally() {
        // Fig 3's correctness claim: slicing + merge == one pipeline.
        let mut rng = Xoshiro256StarStar::seed_from_u64(3);
        let words: Vec<u32> = (0..30_000).map(|_| rng.next_u32()).collect();
        let mut sw = HllSketch::new(cfg());
        sw.insert_batch(&words);
        for k in [1, 2, 4, 7, 10, 16] {
            let mut par = ParallelHll::new(cfg(), k);
            par.feed(&words);
            let r = par.finish();
            assert_eq!(r.sketch, sw, "k={k}");
        }
    }

    #[test]
    fn speedup_scales_linearly() {
        let words: Vec<u32> = (0..64_000u32).collect();
        let mut t1 = None;
        for k in [1usize, 2, 4, 8, 16] {
            let mut par = ParallelHll::new(cfg(), k);
            par.feed(&words);
            let r = par.finish();
            let agg = r.agg_cycles;
            match t1 {
                None => t1 = Some(agg),
                Some(base) => {
                    let speedup = base as f64 / agg as f64;
                    let rel = (speedup - k as f64).abs() / (k as f64);
                    assert!(rel < 0.01, "k={k}: speedup {speedup}");
                }
            }
        }
    }

    #[test]
    fn input_bandwidth_formula() {
        // k × 32 bit × 322 MHz; 10 pipelines = 103 Gbit/s (Section VI-A).
        let par = ParallelHll::new(cfg(), 10);
        let gbit = par.input_bandwidth_bytes_per_s() * 8.0 / 1e9;
        assert!((gbit - 103.0).abs() < 0.1, "{gbit}");
    }

    #[test]
    fn timing_only_matches_functional() {
        let words: Vec<u32> = (0..10_000u32).collect();
        for k in [1usize, 4, 10] {
            let mut par = ParallelHll::new(cfg(), k);
            par.feed(&words);
            let r = par.finish();
            let predicted = timing_only_cycles(&cfg(), k, words.len() as u64);
            // Functional slicer gives ±1 word per lane; allow ±k cycles.
            let actual = r.total_cycles();
            assert!(
                (predicted as i64 - actual as i64).unsigned_abs() <= k as u64 + 1,
                "k={k}: predicted {predicted} actual {actual}"
            );
        }
    }

    #[test]
    fn merge_fold_cost_accounted() {
        let mut par = ParallelHll::new(cfg(), 8);
        par.feed(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let r = par.finish();
        assert!(r.merge_cycles >= cfg().m() as u64);
        let single = ParallelHll::new(cfg(), 1);
        let r1 = single.finish();
        assert_eq!(r1.merge_cycles, 0);
    }
}
