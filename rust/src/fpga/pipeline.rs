//! The single-pipelined HLL dataflow engine of Fig. 2, cycle-approximate.
//!
//! Functional behaviour (the sketch contents) is computed exactly —
//! hash, index, rank, and the BRAM update through the hazard-merging
//! [`super::bram::BucketMemory`]. Timing follows the paper's design:
//! II = 1 at 322 MHz, a fixed pipeline fill latency, and a computation
//! (drain) phase of one cycle per bucket (2^16 × 3.1 ns = 203 µs for
//! p = 16).

use super::bram::BucketMemory;
use super::clock::ClockDomain;
use crate::hll::{estimate, EstimateBreakdown, HllConfig, HllSketch};

/// Stage depths (cycles), mirroring Fig. 2's modules. These determine
/// only the constant fill latency — at II=1 they do not affect
/// throughput, exactly as in the paper.
#[derive(Debug, Clone, Copy)]
pub struct StageLatencies {
    /// Murmur3 over DSP slices (multiply/rotate chain, pipelined).
    pub hash: u64,
    /// Index extractor (pure wiring + register).
    pub index_extract: u64,
    /// Leading-zero detector.
    pub lzd: u64,
    /// BRAM read-modify-write.
    pub bucket_update: u64,
}

impl StageLatencies {
    /// Depths for the paper's 64-bit-hash configuration. The Murmur3
    /// x64_128 tail+finalizer is 5 multiplies + 6 shifts/xors + 4 adds;
    /// scheduled on DSP48E2 slices at 322 MHz this pipelines to ~16
    /// stages (each multiply is 3-4 DSP pipeline registers).
    pub const H64: StageLatencies =
        StageLatencies { hash: 16, index_extract: 1, lzd: 1, bucket_update: 3 };
    /// The 32-bit hash has roughly half the multiply chain.
    pub const H32: StageLatencies =
        StageLatencies { hash: 8, index_extract: 1, lzd: 1, bucket_update: 3 };

    pub fn fill_latency(&self) -> u64 {
        self.hash + self.index_extract + self.lzd + self.bucket_update
    }

    pub fn for_config(cfg: &HllConfig) -> Self {
        match cfg.hash() {
            crate::hll::HashKind::H64 => Self::H64,
            crate::hll::HashKind::H32 => Self::H32,
        }
    }
}

/// One aggregation pipeline: functional sketch + cycle accounting.
#[derive(Debug, Clone)]
pub struct HllPipeline {
    cfg: HllConfig,
    stages: StageLatencies,
    clock: ClockDomain,
    bram: BucketMemory,
    words_in: u64,
    /// Cycles spent in the aggregation phase (including fill).
    agg_cycles: u64,
    started: bool,
}

impl HllPipeline {
    pub fn new(cfg: HllConfig) -> Self {
        Self {
            cfg,
            stages: StageLatencies::for_config(&cfg),
            clock: ClockDomain::NETWORK,
            bram: BucketMemory::new(cfg.m()),
            words_in: 0,
            agg_cycles: 0,
            started: false,
        }
    }

    pub fn config(&self) -> &HllConfig {
        &self.cfg
    }

    pub fn clock_domain(&self) -> ClockDomain {
        self.clock
    }

    /// Feed a slice of 32-bit stream words (one per cycle, II = 1).
    pub fn feed(&mut self, words: &[u32]) {
        // A probe sketch computes hash/index/rank exactly as the Rust
        // core does; the BRAM model then replays the update stream
        // through the hazard-merging RMW pipeline.
        let probe = HllSketch::new(self.cfg);
        if !self.started && !words.is_empty() {
            self.agg_cycles += self.stages.fill_latency();
            self.started = true;
        }
        for &w in words {
            let h = probe.hash_u32(w);
            let (idx, rank) = probe.index_and_rank(h);
            self.bram.clock(Some((idx, rank)));
            self.words_in += 1;
            self.agg_cycles += 1;
        }
    }

    /// End the aggregation phase: flush in-flight updates, stream the
    /// buckets through the harmonic-mean/correction back-end, and return
    /// the estimate plus total cycle counts.
    pub fn finish(mut self) -> PipelineResult {
        self.bram.flush();
        let regs = self.bram.registers().to_vec();
        let breakdown = estimate(&self.cfg, &regs);
        // Computation phase: one cycle per bucket to drain the BRAM,
        // plus a short floating-point epilogue for E = α·m²/S and the
        // correction mux (~32 cycles of HLS-synthesized FP latency).
        let drain_cycles = self.cfg.m() as u64 + 32;
        let sketch = HllSketch::from_registers(self.cfg, regs).expect("bram regs valid");
        PipelineResult {
            sketch,
            breakdown,
            words: self.words_in,
            agg_cycles: self.agg_cycles,
            drain_cycles,
            clock: self.clock,
        }
    }

    pub fn words_in(&self) -> u64 {
        self.words_in
    }

    pub fn agg_cycles(&self) -> u64 {
        self.agg_cycles
    }

    /// Peek the current (flushed) register state without consuming the
    /// pipeline — used by the parallel architecture's merge fold.
    pub fn registers_snapshot(&mut self) -> Vec<u8> {
        self.bram.flush();
        self.bram.registers().to_vec()
    }
}

/// Outcome of a completed single-pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineResult {
    pub sketch: HllSketch,
    pub breakdown: EstimateBreakdown,
    pub words: u64,
    pub agg_cycles: u64,
    pub drain_cycles: u64,
    pub clock: ClockDomain,
}

impl PipelineResult {
    pub fn total_cycles(&self) -> u64 {
        self.agg_cycles + self.drain_cycles
    }

    pub fn aggregation_seconds(&self) -> f64 {
        self.clock.cycles_to_seconds(self.agg_cycles)
    }

    pub fn drain_seconds(&self) -> f64 {
        self.clock.cycles_to_seconds(self.drain_cycles)
    }

    /// Sustained aggregation throughput in bytes/s (4 B words at II=1).
    pub fn throughput_bytes_per_s(&self) -> f64 {
        (self.words * 4) as f64 / self.aggregation_seconds()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::HashKind;
    use crate::util::Xoshiro256StarStar;

    fn cfg() -> HllConfig {
        HllConfig::PAPER
    }

    #[test]
    fn functional_equivalence_with_software_sketch() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(1);
        let words: Vec<u32> = (0..20_000).map(|_| rng.next_u32()).collect();
        let mut pipe = HllPipeline::new(cfg());
        pipe.feed(&words);
        let result = pipe.finish();

        let mut sw = HllSketch::new(cfg());
        sw.insert_batch(&words);
        assert_eq!(result.sketch, sw, "pipeline must equal software sketch");
        assert_eq!(result.breakdown.estimate, sw.estimate());
    }

    #[test]
    fn ii_one_cycle_accounting() {
        let mut pipe = HllPipeline::new(cfg());
        let words: Vec<u32> = (0..10_000).collect();
        pipe.feed(&words);
        let fill = StageLatencies::H64.fill_latency();
        assert_eq!(pipe.agg_cycles(), 10_000 + fill);
    }

    #[test]
    fn throughput_matches_paper_per_pipeline_rate() {
        // 322 MHz × 32 bit = 10.3 Gbit/s (Section VI), asymptotically.
        let mut pipe = HllPipeline::new(cfg());
        let words: Vec<u32> = (0..1_000_000u32).collect();
        pipe.feed(&words);
        let r = pipe.finish();
        let gbit = r.throughput_bytes_per_s() * 8.0 / 1e9;
        assert!((gbit - 10.3).abs() < 0.01, "{gbit} Gbit/s");
    }

    #[test]
    fn drain_time_is_203us_at_p16() {
        let pipe = HllPipeline::new(cfg());
        let r = pipe.finish();
        // 2^16 × 3.1 ns ≈ 203 µs; the FP epilogue adds ~0.1 µs.
        assert!((r.drain_seconds() - 203e-6).abs() < 2e-6, "{}", r.drain_seconds());
    }

    #[test]
    fn h32_variant_works() {
        let cfg32 = HllConfig::new(14, HashKind::H32).unwrap();
        let mut pipe = HllPipeline::new(cfg32);
        let words: Vec<u32> = (0..5000).collect();
        pipe.feed(&words);
        let r = pipe.finish();
        let mut sw = HllSketch::new(cfg32);
        sw.insert_batch(&words);
        assert_eq!(r.sketch, sw);
    }

    #[test]
    fn incremental_feed_equals_single_feed() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(9);
        let words: Vec<u32> = (0..5000).map(|_| rng.next_u32()).collect();
        let mut a = HllPipeline::new(cfg());
        a.feed(&words);
        let mut b = HllPipeline::new(cfg());
        for chunk in words.chunks(97) {
            b.feed(chunk);
        }
        assert_eq!(a.finish().sketch, b.finish().sketch);
        // (cycle counts differ only by nothing: fill charged once)
    }
}
