//! Full-registry snapshot/restore: every `(key, sketch)` pair streamed to
//! or from an on-disk file, so a restarted server resumes with identical
//! estimates and sketches can be shipped across nodes.
//!
//! # File format (v2, `HLLSNAP2`)
//!
//! All integers little-endian:
//!
//! | offset | size | field                                        |
//! |--------|------|----------------------------------------------|
//! | 0      | 8    | magic `b"HLLSNAP2"` ([`SNAPSHOT_MAGIC`])     |
//! | 8      | 1    | snapshot version ([`SNAPSHOT_VERSION`], 2)   |
//! | 9      | 8    | key count, u64                               |
//! | 17     | 8    | FNV-1a 64 checksum of the body               |
//! | 25     | 1    | global-record flag (0 = absent, 1 = present) |
//! | 26     | ...  | if flag: global record `len u32 · len bytes` |
//! | …      | ...  | body continues: key count × record           |
//!
//! Each per-key record is `key u64 · len u32 · len bytes` where the bytes
//! are one sketch in the seed-carrying wire format v2 (see
//! [`crate::hll::sketch`]); the global record is the registry's
//! all-keys union sketch in the same encoding (written whenever the
//! registry tracks a non-empty global union). The checksum covers the
//! whole body — flag, global record and key records — so any flipped
//! byte fails restore with [`SnapshotError::ChecksumMismatch`] before a
//! single sketch is decoded.
//!
//! Version 1 files (`HLLSNAP1`, no flag byte, records begin at offset
//! 25) remain fully readable: every read path dispatches on the magic.
//! The writer always emits v2.
//!
//! Writes go to a uniquely named `<path>.<pid>.<seq>.tmp` sibling and
//! are atomically renamed into place, so a crash mid-snapshot leaves
//! the previous snapshot intact, and concurrent snapshots to one path
//! never interleave — each writes its own temp file and the last
//! complete rename wins.
//!
//! # What a restore guarantees
//!
//! Every *live* key restores with a bit-identical register file, so all
//! per-key estimates survive a restart exactly. Because v2 persists the
//! global union sketch as its own record, `GlobalEstimate` survives
//! exactly too — including the words of keys evicted *before* the
//! snapshot, which a rebuilt-from-live-keys union (the v1 behavior,
//! still what restoring a v1 file yields) would drop.

use std::fs;
use std::io::{self, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::hll::{HllSketch, SketchError};
use crate::registry::SketchRegistry;

/// Leading magic of every snapshot file the writer emits (format v2).
pub const SNAPSHOT_MAGIC: [u8; 8] = *b"HLLSNAP2";
/// Magic of legacy v1 files, still accepted by every read path.
pub const SNAPSHOT_MAGIC_V1: [u8; 8] = *b"HLLSNAP1";
/// Version byte following the magic.
pub const SNAPSHOT_VERSION: u8 = 2;
/// Version byte of legacy v1 files.
pub const SNAPSHOT_VERSION_V1: u8 = 1;
/// Fixed header length: magic(8) + version(1) + count(8) + checksum(8).
pub const SNAPSHOT_HEADER_LEN: usize = 25;

/// Errors writing or reading a snapshot file.
#[derive(Debug)]
pub enum SnapshotError {
    Io(io::Error),
    BadMagic([u8; 8]),
    BadVersion(u8),
    /// Structural damage: truncation, trailing bytes, impossible lengths.
    Corrupt(String),
    ChecksumMismatch { expected: u64, actual: u64 },
    Sketch(SketchError),
}

impl std::fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotError::Io(e) => write!(f, "snapshot io error: {e}"),
            SnapshotError::BadMagic(m) => write!(f, "not a snapshot file (magic {m:02x?})"),
            SnapshotError::BadVersion(v) => {
                write!(f, "unsupported snapshot version {v} (expected {SNAPSHOT_VERSION})")
            }
            SnapshotError::Corrupt(what) => write!(f, "snapshot corrupt: {what}"),
            SnapshotError::ChecksumMismatch { expected, actual } => write!(
                f,
                "snapshot body checksum mismatch (header {expected:#018x}, computed {actual:#018x})"
            ),
            SnapshotError::Sketch(e) => write!(f, "snapshot sketch record invalid: {e}"),
        }
    }
}

impl std::error::Error for SnapshotError {}

impl From<io::Error> for SnapshotError {
    fn from(e: io::Error) -> Self {
        SnapshotError::Io(e)
    }
}

impl From<SketchError> for SnapshotError {
    fn from(e: SketchError) -> Self {
        SnapshotError::Sketch(e)
    }
}

/// What a completed snapshot wrote.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SnapshotSummary {
    /// Keys persisted.
    pub keys: u64,
    /// Total file size in bytes.
    pub bytes: u64,
}

/// FNV-1a 64-bit offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold more bytes into a running FNV-1a 64 state — the checksum is a
/// byte-wise fold, so the writer can stream records to disk while
/// checksumming without ever holding the whole body in memory.
fn fnv1a64_update(mut h: u64, data: &[u8]) -> u64 {
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a 64-bit — the snapshot body checksum (dependency-free, and
/// plenty for detecting corruption; this is an integrity check, not an
/// authenticity one).
pub fn fnv1a64(data: &[u8]) -> u64 {
    fnv1a64_update(FNV_OFFSET, data)
}

/// Monotone suffix so concurrent snapshots (two `SNAPSHOT` RPCs, or two
/// servers sharing a directory) never share a temp file.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

fn tmp_sibling(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(format!(
        ".{}.{}.tmp",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    PathBuf::from(os)
}

/// Serialize every live key of `registry` to `path` (atomic
/// replace-on-rename). Concurrent ingest during the walk is safe; keys
/// touched mid-snapshot land in this snapshot or the next. Concurrent
/// snapshots to the same path are safe too: each writes a unique temp
/// file and the last complete rename wins.
pub fn write_snapshot(
    registry: &SketchRegistry<u64>,
    path: &Path,
) -> Result<SnapshotSummary, SnapshotError> {
    // Stream records straight to the temp file with a running checksum,
    // one shard's serialization in memory at a time
    // ([`SketchRegistry::for_each_sketch_bytes`]); key count and
    // checksum are patched into the header once the walk is done. The
    // whole dense image never exists in memory.
    let tmp = tmp_sibling(path);
    let write = (|| {
        let mut w = io::BufWriter::new(fs::File::create(&tmp)?);
        let mut header = [0u8; SNAPSHOT_HEADER_LEN];
        header[0..8].copy_from_slice(&SNAPSHOT_MAGIC);
        header[8] = SNAPSHOT_VERSION;
        // Bytes 9..17 (count) and 17..25 (checksum) stay zero until
        // patched below.
        w.write_all(&header)?;
        let mut keys = 0u64;
        let mut hash = FNV_OFFSET;
        let mut total = SNAPSHOT_HEADER_LEN as u64;
        let global = encode_global_section(registry);
        hash = fnv1a64_update(hash, &global);
        w.write_all(&global)?;
        total += global.len() as u64;
        let mut io_err: Option<io::Error> = None;
        registry.for_each_sketch_bytes(|key, bytes| {
            if io_err.is_some() {
                return;
            }
            let rec_key = key.to_le_bytes();
            let rec_len = (bytes.len() as u32).to_le_bytes();
            hash = fnv1a64_update(hash, &rec_key);
            hash = fnv1a64_update(hash, &rec_len);
            hash = fnv1a64_update(hash, &bytes);
            let res = w
                .write_all(&rec_key)
                .and_then(|()| w.write_all(&rec_len))
                .and_then(|()| w.write_all(&bytes));
            match res {
                Ok(()) => {
                    keys += 1;
                    total += 12 + bytes.len() as u64;
                }
                Err(e) => io_err = Some(e),
            }
        });
        if let Some(e) = io_err {
            return Err(e);
        }
        w.seek(SeekFrom::Start(9))?;
        w.write_all(&keys.to_le_bytes())?;
        w.write_all(&hash.to_le_bytes())?;
        let f = w.into_inner().map_err(|e| e.into_error())?;
        f.sync_all()?;
        fs::rename(&tmp, path)?;
        Ok::<(u64, u64), io::Error>((keys, total))
    })();
    match write {
        Ok((keys, bytes)) => Ok(SnapshotSummary { keys, bytes }),
        Err(e) => {
            let _ = fs::remove_file(&tmp);
            Err(e.into())
        }
    }
}

/// Validate a snapshot header's magic and version, returning
/// `(format version, key count, body checksum)`. Both the current v2
/// magic and the legacy v1 magic are accepted.
fn parse_snapshot_header(
    header: &[u8; SNAPSHOT_HEADER_LEN],
) -> Result<(u8, u64, u64), SnapshotError> {
    let version = if header[0..8] == SNAPSHOT_MAGIC {
        if header[8] != SNAPSHOT_VERSION {
            return Err(SnapshotError::BadVersion(header[8]));
        }
        SNAPSHOT_VERSION
    } else if header[0..8] == SNAPSHOT_MAGIC_V1 {
        if header[8] != SNAPSHOT_VERSION_V1 {
            return Err(SnapshotError::BadVersion(header[8]));
        }
        SNAPSHOT_VERSION_V1
    } else {
        let mut magic = [0u8; 8];
        magic.copy_from_slice(&header[0..8]);
        return Err(SnapshotError::BadMagic(magic));
    };
    let count = u64::from_le_bytes(header[9..17].try_into().unwrap());
    let checksum = u64::from_le_bytes(header[17..25].try_into().unwrap());
    Ok((version, count, checksum))
}

/// Everything a snapshot image holds, decoded.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotContents {
    /// Format version the image was encoded with (1 or 2).
    pub version: u8,
    /// The global union record (v2 only, and only when the source
    /// registry tracked a non-empty union).
    pub global: Option<HllSketch>,
    /// Every `(key, sketch)` record.
    pub entries: Vec<(u64, HllSketch)>,
}

/// Decode and fully validate a snapshot image held in memory (a read
/// file, or a replication `FULL_SYNC` body). Magic, version, count,
/// checksum and every sketch record are checked; any damage is a typed
/// error, never a panic.
pub fn decode_snapshot_bytes(data: &[u8]) -> Result<SnapshotContents, SnapshotError> {
    if data.len() < SNAPSHOT_HEADER_LEN {
        return Err(SnapshotError::Corrupt(format!(
            "image is {} bytes, header needs {SNAPSHOT_HEADER_LEN}",
            data.len()
        )));
    }
    let (version, count, expected) =
        parse_snapshot_header(data[..SNAPSHOT_HEADER_LEN].try_into().unwrap())?;
    let body = &data[SNAPSHOT_HEADER_LEN..];
    let actual = fnv1a64(body);
    if actual != expected {
        return Err(SnapshotError::ChecksumMismatch { expected, actual });
    }

    let mut pos = 0usize;
    let mut global = None;
    if version >= SNAPSHOT_VERSION {
        if body.is_empty() {
            return Err(SnapshotError::Corrupt("global-record flag missing".into()));
        }
        let flag = body[0];
        pos = 1;
        match flag {
            0 => {}
            1 => {
                if body.len() - pos < 4 {
                    return Err(SnapshotError::Corrupt(
                        "global record length truncated".into(),
                    ));
                }
                let len =
                    u32::from_le_bytes(body[pos..pos + 4].try_into().unwrap()) as usize;
                pos += 4;
                if body.len() - pos < len {
                    return Err(SnapshotError::Corrupt(format!(
                        "global record declares {len} sketch bytes, {} remain",
                        body.len() - pos
                    )));
                }
                global = Some(HllSketch::from_bytes(&body[pos..pos + len])?);
                pos += len;
            }
            other => {
                return Err(SnapshotError::Corrupt(format!(
                    "bad global-record flag {other}"
                )))
            }
        }
    }
    let mut out = Vec::with_capacity(count.min(1 << 20) as usize);
    for i in 0..count {
        if body.len() - pos < 12 {
            return Err(SnapshotError::Corrupt(format!(
                "record {i} header truncated at body offset {pos}"
            )));
        }
        let key = u64::from_le_bytes(body[pos..pos + 8].try_into().unwrap());
        let len = u32::from_le_bytes(body[pos + 8..pos + 12].try_into().unwrap()) as usize;
        pos += 12;
        if body.len() - pos < len {
            return Err(SnapshotError::Corrupt(format!(
                "record {i} declares {len} sketch bytes, {} remain",
                body.len() - pos
            )));
        }
        let sketch = HllSketch::from_bytes(&body[pos..pos + len])?;
        pos += len;
        out.push((key, sketch));
    }
    if pos != body.len() {
        return Err(SnapshotError::Corrupt(format!(
            "{} trailing body bytes after {count} records",
            body.len() - pos
        )));
    }
    Ok(SnapshotContents { version, global, entries: out })
}

/// Read and fully validate a snapshot file, returning decoded
/// `(key, sketch)` pairs (the global record, if any, is dropped — use
/// [`read_snapshot_contents`] to keep it).
///
/// Holds the whole file plus every decoded sketch in memory —
/// convenient for tests and small registries; [`restore_registry`]
/// streams record-by-record instead and is what the server's restart
/// path should use at scale.
pub fn read_snapshot(path: &Path) -> Result<Vec<(u64, HllSketch)>, SnapshotError> {
    Ok(decode_snapshot_bytes(&fs::read(path)?)?.entries)
}

/// As [`read_snapshot`], returning the full [`SnapshotContents`]
/// including the v2 global-union record.
pub fn read_snapshot_contents(path: &Path) -> Result<SnapshotContents, SnapshotError> {
    decode_snapshot_bytes(&fs::read(path)?)
}

/// Encode the v2 global-record section (flag byte, plus `len · bytes`
/// when present) — the one shared definition both the streaming file
/// writer and the in-memory image builder emit. The union including
/// evicted keys' words is persisted whenever it is non-empty; an
/// all-zero union carries nothing and is elided, keeping
/// empty-registry snapshots at a few dozen bytes instead of a full
/// register file.
fn encode_global_section(registry: &SketchRegistry<u64>) -> Vec<u8> {
    match registry
        .global_sketch()
        .filter(|g| g.zero_registers() < g.config().m())
    {
        Some(g) => {
            let bytes = g.to_bytes();
            let mut out = Vec::with_capacity(5 + bytes.len());
            out.push(1u8);
            out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
            out.extend_from_slice(&bytes);
            out
        }
        None => vec![0u8],
    }
}

/// Build a complete v2 snapshot image in memory — the body of a
/// replication `FULL_SYNC` frame. Deliberately non-streaming (the frame
/// has to be one in-memory payload anyway); the file writer
/// [`write_snapshot`] remains the streaming path for at-scale persistence.
pub fn snapshot_to_vec(registry: &SketchRegistry<u64>) -> Vec<u8> {
    let mut body = encode_global_section(registry);
    let mut keys = 0u64;
    registry.for_each_sketch_bytes(|key, bytes| {
        body.extend_from_slice(&key.to_le_bytes());
        body.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
        body.extend_from_slice(&bytes);
        keys += 1;
    });
    let mut out = Vec::with_capacity(SNAPSHOT_HEADER_LEN + body.len());
    out.extend_from_slice(&SNAPSHOT_MAGIC);
    out.push(SNAPSHOT_VERSION);
    out.extend_from_slice(&keys.to_le_bytes());
    out.extend_from_slice(&fnv1a64(&body).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Restore an in-memory snapshot image into `registry`: the global
/// record (if present) raises the global union, then every key record
/// max-merges in. Returns the number of key records applied. The image
/// is fully validated first; the first config/seed mismatch aborts with
/// its typed error (earlier records stay applied — max-merge makes a
/// re-run after fixing the registry safe).
pub fn restore_from_bytes(
    registry: &SketchRegistry<u64>,
    data: &[u8],
) -> Result<usize, SnapshotError> {
    let contents = decode_snapshot_bytes(data)?;
    if let Some(global) = &contents.global {
        registry.merge_global(global)?;
    }
    let mut applied = 0;
    for (key, sketch) in contents.entries {
        registry.merge_sketch(key, sketch)?;
        applied += 1;
    }
    Ok(applied)
}

/// *Replace* `registry`'s contents with a snapshot image: the
/// follower's `FULL_SYNC` apply path. A resync image is the complete,
/// newer truth about the primary — merge-only application
/// ([`restore_from_bytes`]) would keep keys the primary evicted, and
/// could max-merge a dead incarnation of an evicted-then-re-created key
/// into the new one, whenever the tombstone batches rotated out of log
/// retention before the resync.
///
/// The image is decoded and config-checked in full *before* the
/// registry is touched, so a corrupt or config/seed-mismatched image
/// leaves existing state serving untouched (the halt-on-last-good
/// guarantee of [`crate::replica::FollowerServer`]). Readers racing the
/// apply may observe a partially restored registry for its duration.
/// Returns the number of keys applied.
pub fn replace_from_bytes(
    registry: &SketchRegistry<u64>,
    data: &[u8],
) -> Result<usize, SnapshotError> {
    let contents = decode_snapshot_bytes(data)?;
    let want = registry.config().hll;
    for sketch in contents.global.iter().chain(contents.entries.iter().map(|(_, s)| s)) {
        if *sketch.config() != want {
            return Err(SketchError::ConfigMismatch(*sketch.config(), want).into());
        }
    }
    registry.clear();
    if let Some(global) = &contents.global {
        registry.merge_global(global)?;
    }
    let mut applied = 0;
    for (key, sketch) in contents.entries {
        registry.merge_sketch(key, sketch)?;
        applied += 1;
    }
    Ok(applied)
}

/// Restore a snapshot file into `registry` (max-merge over whatever is
/// live — see [`SketchRegistry::merge_sketch`]). Returns the number of
/// keys applied.
///
/// Streaming and two-pass: the first pass verifies the body checksum in
/// fixed-size chunks (no corrupt file applies a single record), the
/// second decodes and merges one record at a time — peak memory is one
/// sketch, mirroring the streaming writer, instead of the whole file
/// plus every decoded sketch. A config/seed mismatch aborts at the
/// offending record with earlier records already applied (merges are
/// idempotent max-folds, so re-running restore after fixing the target
/// registry is safe).
pub fn restore_registry(
    registry: &SketchRegistry<u64>,
    path: &Path,
) -> Result<usize, SnapshotError> {
    use std::io::Read;

    let short_file = |what: &str| SnapshotError::Corrupt(what.to_string());

    // Pass 1: header + streamed checksum over the body.
    let mut f = fs::File::open(path)?;
    let mut header = [0u8; SNAPSHOT_HEADER_LEN];
    f.read_exact(&mut header).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            short_file("file shorter than the snapshot header")
        } else {
            SnapshotError::Io(e)
        }
    })?;
    let (version, count, expected) = parse_snapshot_header(&header)?;
    let mut hash = FNV_OFFSET;
    let mut body_len = 0u64;
    let mut chunk = [0u8; 64 * 1024];
    loop {
        let n = f.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        hash = fnv1a64_update(hash, &chunk[..n]);
        body_len += n as u64;
    }
    if hash != expected {
        return Err(SnapshotError::ChecksumMismatch { expected, actual: hash });
    }

    // Pass 2: decode + merge record by record.
    let mut r = io::BufReader::new(fs::File::open(path)?);
    r.read_exact(&mut header)
        .map_err(|_| short_file("file shrank between checksum and restore passes"))?;
    let mut consumed = 0u64;
    let mut applied = 0usize;
    if version >= SNAPSHOT_VERSION {
        let mut flag = [0u8; 1];
        r.read_exact(&mut flag)
            .map_err(|_| short_file("global-record flag missing"))?;
        consumed += 1;
        match flag[0] {
            0 => {}
            1 => {
                let mut len_bytes = [0u8; 4];
                r.read_exact(&mut len_bytes)
                    .map_err(|_| short_file("global record length truncated"))?;
                let len = u32::from_le_bytes(len_bytes) as usize;
                consumed += 4 + len as u64;
                if consumed > body_len {
                    return Err(SnapshotError::Corrupt(format!(
                        "global record declares {len} sketch bytes, overrunning the body"
                    )));
                }
                let mut global_bytes = vec![0u8; len];
                r.read_exact(&mut global_bytes)
                    .map_err(|_| short_file("global record truncated"))?;
                let global = HllSketch::from_bytes(&global_bytes)?;
                registry.merge_global(&global)?;
            }
            other => {
                return Err(SnapshotError::Corrupt(format!(
                    "bad global-record flag {other}"
                )))
            }
        }
    }
    for i in 0..count {
        let mut rec = [0u8; 12];
        r.read_exact(&mut rec)
            .map_err(|_| SnapshotError::Corrupt(format!("record {i} header truncated")))?;
        let key = u64::from_le_bytes(rec[0..8].try_into().unwrap());
        let len = u32::from_le_bytes(rec[8..12].try_into().unwrap()) as usize;
        consumed += 12 + len as u64;
        if consumed > body_len {
            return Err(SnapshotError::Corrupt(format!(
                "record {i} declares {len} sketch bytes, overrunning the body"
            )));
        }
        let mut sketch_bytes = vec![0u8; len];
        r.read_exact(&mut sketch_bytes)
            .map_err(|_| SnapshotError::Corrupt(format!("record {i} truncated")))?;
        let sketch = HllSketch::from_bytes(&sketch_bytes)?;
        registry.merge_sketch(key, sketch)?;
        applied += 1;
    }
    if consumed != body_len {
        return Err(SnapshotError::Corrupt(format!(
            "{} trailing body bytes after {count} records",
            body_len - consumed
        )));
    }
    Ok(applied)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::RegistryConfig;
    use crate::util::Xoshiro256StarStar;
    use std::path::PathBuf;

    fn temp_path(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("hll_snapshot_{}_{name}.snap", std::process::id()));
        p
    }

    fn populated_registry() -> SketchRegistry<u64> {
        let reg = SketchRegistry::new(RegistryConfig {
            shards: 8,
            ..RegistryConfig::default()
        })
        .unwrap();
        let mut rng = Xoshiro256StarStar::seed_from_u64(21);
        for key in 0u64..30 {
            let n = 5 + (key as usize * 97) % 2_500;
            let words: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            reg.ingest(key, &words);
        }
        reg
    }

    #[test]
    fn snapshot_roundtrip_restores_identical_estimates() {
        let reg = populated_registry();
        let path = temp_path("roundtrip");
        let summary = write_snapshot(&reg, &path).unwrap();
        assert_eq!(summary.keys, 30);
        assert_eq!(summary.bytes, fs::metadata(&path).unwrap().len());

        let restored = SketchRegistry::new(RegistryConfig {
            shards: 8,
            ..RegistryConfig::default()
        })
        .unwrap();
        assert_eq!(restore_registry(&restored, &path).unwrap(), 30);
        assert_eq!(restored.len(), reg.len());
        for (key, est) in reg.estimates() {
            assert_eq!(restored.estimate(&key), Some(est), "key {key}");
        }
        assert_eq!(restored.merge_all(), reg.merge_all());
        assert_eq!(restored.global_estimate(), reg.global_estimate());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn empty_registry_snapshots_and_restores() {
        let reg: SketchRegistry<u64> =
            SketchRegistry::new(RegistryConfig::default()).unwrap();
        let path = temp_path("empty");
        let summary = write_snapshot(&reg, &path).unwrap();
        assert_eq!(summary.keys, 0);
        // v2 header plus the lone global-record flag byte (the empty
        // union is elided rather than serialized as 64 KiB of zeros).
        assert_eq!(summary.bytes as usize, SNAPSHOT_HEADER_LEN + 1);
        let contents = read_snapshot_contents(&path).unwrap();
        assert_eq!(contents.version, SNAPSHOT_VERSION);
        assert!(contents.global.is_none());
        assert!(contents.entries.is_empty());
        let _ = fs::remove_file(&path);
    }

    /// Build a legacy v1 snapshot image (no global record) from a live
    /// registry — what a pre-v2 server would have written.
    fn v1_snapshot_bytes(reg: &SketchRegistry<u64>) -> Vec<u8> {
        let mut body = Vec::new();
        let mut keys = 0u64;
        reg.for_each_sketch_bytes(|key, bytes| {
            body.extend_from_slice(&key.to_le_bytes());
            body.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
            body.extend_from_slice(&bytes);
            keys += 1;
        });
        let mut out = Vec::with_capacity(SNAPSHOT_HEADER_LEN + body.len());
        out.extend_from_slice(&SNAPSHOT_MAGIC_V1);
        out.push(SNAPSHOT_VERSION_V1);
        out.extend_from_slice(&keys.to_le_bytes());
        out.extend_from_slice(&fnv1a64(&body).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    #[test]
    fn v1_snapshot_still_restores_under_the_v2_reader() {
        let reg = populated_registry();
        let path = temp_path("v1compat");
        fs::write(&path, v1_snapshot_bytes(&reg)).unwrap();

        // Both the in-memory decoder and the streaming restorer accept it.
        let contents = read_snapshot_contents(&path).unwrap();
        assert_eq!(contents.version, SNAPSHOT_VERSION_V1);
        assert!(contents.global.is_none());
        assert_eq!(contents.entries.len(), 30);

        let restored = SketchRegistry::new(RegistryConfig {
            shards: 8,
            ..RegistryConfig::default()
        })
        .unwrap();
        assert_eq!(restore_registry(&restored, &path).unwrap(), 30);
        for (key, est) in reg.estimates() {
            assert_eq!(restored.estimate(&key), Some(est), "key {key}");
        }
        // v1 carries no union record: the restored global is rebuilt
        // from live keys (the documented v1 behavior).
        assert_eq!(restored.merge_all(), reg.merge_all());
        // A v1 magic with a v2 version byte (and vice versa) is rejected.
        let mut crossed = v1_snapshot_bytes(&reg);
        crossed[8] = SNAPSHOT_VERSION;
        fs::write(&path, &crossed).unwrap();
        assert!(matches!(
            read_snapshot(&path),
            Err(SnapshotError::BadVersion(_))
        ));
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn v2_global_record_preserves_pre_snapshot_evictions() {
        let reg = populated_registry();
        let live_global = reg.global_estimate().unwrap();
        // Evict a third of the keys *before* snapshotting: their words
        // stay in the union sketch but leave the live key set.
        for key in 0u64..10 {
            reg.evict(&key);
        }
        assert_eq!(reg.global_estimate(), Some(live_global));
        assert!(reg.merge_all().estimate() < live_global);

        let path = temp_path("v2global");
        write_snapshot(&reg, &path).unwrap();
        let contents = read_snapshot_contents(&path).unwrap();
        assert_eq!(contents.version, SNAPSHOT_VERSION);
        assert_eq!(contents.entries.len(), 20);
        assert_eq!(contents.global.as_ref().unwrap().estimate(), live_global);

        // Restore: GlobalEstimate survives the restart exactly — the
        // caveat the v1 format documented is gone.
        let restored = SketchRegistry::new(RegistryConfig {
            shards: 8,
            ..RegistryConfig::default()
        })
        .unwrap();
        assert_eq!(restore_registry(&restored, &path).unwrap(), 20);
        assert_eq!(restored.global_estimate(), Some(live_global));
        assert_eq!(restored.merge_all(), reg.merge_all());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn in_memory_image_roundtrips_like_the_file_path() {
        let reg = populated_registry();
        let image = snapshot_to_vec(&reg);
        // The in-memory image and the file writer produce byte-identical
        // snapshots of the same registry state.
        let path = temp_path("image");
        write_snapshot(&reg, &path).unwrap();
        assert_eq!(image, fs::read(&path).unwrap());

        let restored = SketchRegistry::new(RegistryConfig {
            shards: 8,
            ..RegistryConfig::default()
        })
        .unwrap();
        assert_eq!(restore_from_bytes(&restored, &image).unwrap(), 30);
        assert_eq!(restored.merge_all(), reg.merge_all());
        assert_eq!(restored.global_estimate(), reg.global_estimate());

        // Damage is typed, never a panic.
        let mut bad = image.clone();
        let last = bad.len() - 1;
        bad[last] ^= 1;
        assert!(matches!(
            restore_from_bytes(&restored, &bad),
            Err(SnapshotError::ChecksumMismatch { .. })
        ));
        assert!(matches!(
            decode_snapshot_bytes(&image[..10]),
            Err(SnapshotError::Corrupt(_))
        ));
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn flipped_body_byte_fails_checksum() {
        let reg = populated_registry();
        let path = temp_path("flip");
        write_snapshot(&reg, &path).unwrap();
        let mut data = fs::read(&path).unwrap();
        let last = data.len() - 1;
        data[last] ^= 0x01;
        fs::write(&path, &data).unwrap();
        assert!(matches!(
            read_snapshot(&path),
            Err(SnapshotError::ChecksumMismatch { .. })
        ));
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn truncation_magic_and_version_are_typed_errors() {
        let reg = populated_registry();
        let path = temp_path("damage");
        write_snapshot(&reg, &path).unwrap();
        let original = fs::read(&path).unwrap();

        // Truncated header.
        fs::write(&path, &original[..10]).unwrap();
        assert!(matches!(read_snapshot(&path), Err(SnapshotError::Corrupt(_))));

        // Truncated body (checksum fails first — that's the point: any
        // truncation is caught before record parsing).
        fs::write(&path, &original[..original.len() - 40]).unwrap();
        assert!(matches!(
            read_snapshot(&path),
            Err(SnapshotError::ChecksumMismatch { .. })
        ));

        // Bad magic.
        let mut bad = original.clone();
        bad[0] = b'X';
        fs::write(&path, &bad).unwrap();
        assert!(matches!(read_snapshot(&path), Err(SnapshotError::BadMagic(_))));

        // Bad version.
        let mut bad = original.clone();
        bad[8] = 9;
        fs::write(&path, &bad).unwrap();
        assert!(matches!(read_snapshot(&path), Err(SnapshotError::BadVersion(9))));

        // Missing file.
        let _ = fs::remove_file(&path);
        assert!(matches!(read_snapshot(&path), Err(SnapshotError::Io(_))));
    }

    #[test]
    fn seed_mismatch_restore_is_rejected() {
        use crate::hll::HllConfig;
        // Snapshot from a seed-7 registry cannot restore into a seed-0 one.
        let seeded: SketchRegistry<u64> = SketchRegistry::new(RegistryConfig {
            hll: HllConfig::PAPER.with_seed(7),
            shards: 4,
            ..RegistryConfig::default()
        })
        .unwrap();
        seeded.ingest(1, &[1, 2, 3]);
        let path = temp_path("seed");
        write_snapshot(&seeded, &path).unwrap();

        let plain: SketchRegistry<u64> = SketchRegistry::new(RegistryConfig {
            shards: 4,
            ..RegistryConfig::default()
        })
        .unwrap();
        assert!(matches!(
            restore_registry(&plain, &path),
            Err(SnapshotError::Sketch(SketchError::ConfigMismatch(..)))
        ));
        assert!(plain.is_empty());

        // But it restores fine into a matching seeded registry.
        let seeded2: SketchRegistry<u64> = SketchRegistry::new(RegistryConfig {
            hll: HllConfig::PAPER.with_seed(7),
            shards: 4,
            ..RegistryConfig::default()
        })
        .unwrap();
        assert_eq!(restore_registry(&seeded2, &path).unwrap(), 1);
        assert_eq!(seeded2.estimate(&1), seeded.estimate(&1));
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn fnv_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
