//! `SO_REUSEPORT` listener groups: one listening socket per event loop
//! sharing a single port, so the kernel shards incoming connections
//! across loops by 4-tuple hash and accepts stop funneling through
//! loop 0's listener + cross-thread routing channel.
//!
//! `std::net::TcpListener` cannot express this — `SO_REUSEPORT` must be
//! set between `socket(2)` and `bind(2)`, and std exposes no hook there
//! (and the offline crate set has no `socket2`/`libc`). So this module
//! performs the socket/setsockopt/bind/listen sequence through raw
//! `extern "C"` declarations, then hands the fd to
//! [`TcpListener::from_raw_fd`] so everything downstream (accept,
//! readiness registration, drop-closes) is plain std.
//!
//! Linux-only: `SO_REUSEPORT`'s per-socket-queue semantics are what the
//! accept-sharding design relies on, and the serving stack targets the
//! Linux containers CI and production run on. On other platforms
//! [`bind_group`] reports `Unsupported` and the server falls back to
//! the single-listener + round-robin-routing model, which remains fully
//! correct (just accept-funneled).

use std::io;
use std::net::{SocketAddr, TcpListener, ToSocketAddrs};

/// Bind `count` listeners on `addr`, all sharing the port via
/// `SO_REUSEPORT`. With `addr` on port 0 the first bind picks the
/// concrete port and the rest join it. All-or-nothing: any failure
/// closes the partial group and returns the error, so the caller can
/// fall back to a single listener.
pub fn bind_group(addr: impl ToSocketAddrs, count: usize) -> io::Result<Vec<TcpListener>> {
    let requested = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "no address to bind"))?;
    if count == 0 {
        return Err(io::Error::new(io::ErrorKind::InvalidInput, "empty listener group"));
    }
    imp::bind_group(requested, count)
}

#[cfg(target_os = "linux")]
mod imp {
    use super::*;
    use std::os::raw::{c_int, c_void};
    use std::os::unix::io::FromRawFd;

    const AF_INET: c_int = 2;
    const AF_INET6: c_int = 10;
    const SOCK_STREAM: c_int = 1;
    const SOCK_NONBLOCK: c_int = 0o4000;
    const SOCK_CLOEXEC: c_int = 0o2000000;
    const SOL_SOCKET: c_int = 1;
    const SO_REUSEADDR: c_int = 2;
    const SO_REUSEPORT: c_int = 15;
    /// Matches the default net.core.somaxconn ceiling; the kernel clamps.
    const BACKLOG: c_int = 1024;

    extern "C" {
        fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
        fn setsockopt(
            fd: c_int,
            level: c_int,
            name: c_int,
            value: *const c_void,
            len: u32,
        ) -> c_int;
        fn bind(fd: c_int, addr: *const c_void, len: u32) -> c_int;
        fn listen(fd: c_int, backlog: c_int) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    /// `struct sockaddr_in`. Port and address are stored as byte arrays
    /// already in network order, sidestepping endianness bookkeeping.
    #[repr(C)]
    struct SockAddrIn {
        family: u16,
        port: [u8; 2],
        addr: [u8; 4],
        zero: [u8; 8],
    }

    /// `struct sockaddr_in6`.
    #[repr(C)]
    struct SockAddrIn6 {
        family: u16,
        port: [u8; 2],
        flowinfo: u32,
        addr: [u8; 16],
        scope_id: u32,
    }

    fn bind_one(addr: SocketAddr) -> io::Result<TcpListener> {
        let domain = match addr {
            SocketAddr::V4(_) => AF_INET,
            SocketAddr::V6(_) => AF_INET6,
        };
        let fd = unsafe { socket(domain, SOCK_STREAM | SOCK_NONBLOCK | SOCK_CLOEXEC, 0) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        // From here on, any failure must close `fd` before returning.
        let result = (|| {
            let one: c_int = 1;
            for opt in [SO_REUSEADDR, SO_REUSEPORT] {
                let rc = unsafe {
                    setsockopt(
                        fd,
                        SOL_SOCKET,
                        opt,
                        &one as *const c_int as *const c_void,
                        std::mem::size_of::<c_int>() as u32,
                    )
                };
                if rc != 0 {
                    return Err(io::Error::last_os_error());
                }
            }
            let rc = match addr {
                SocketAddr::V4(v4) => {
                    let sa = SockAddrIn {
                        family: AF_INET as u16,
                        port: v4.port().to_be_bytes(),
                        addr: v4.ip().octets(),
                        zero: [0; 8],
                    };
                    unsafe {
                        bind(
                            fd,
                            &sa as *const SockAddrIn as *const c_void,
                            std::mem::size_of::<SockAddrIn>() as u32,
                        )
                    }
                }
                SocketAddr::V6(v6) => {
                    let sa = SockAddrIn6 {
                        family: AF_INET6 as u16,
                        port: v6.port().to_be_bytes(),
                        flowinfo: v6.flowinfo(),
                        addr: v6.ip().octets(),
                        scope_id: v6.scope_id(),
                    };
                    unsafe {
                        bind(
                            fd,
                            &sa as *const SockAddrIn6 as *const c_void,
                            std::mem::size_of::<SockAddrIn6>() as u32,
                        )
                    }
                }
            };
            if rc != 0 {
                return Err(io::Error::last_os_error());
            }
            if unsafe { listen(fd, BACKLOG) } != 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        })();
        match result {
            Ok(()) => Ok(unsafe { TcpListener::from_raw_fd(fd) }),
            Err(e) => {
                unsafe {
                    close(fd);
                }
                Err(e)
            }
        }
    }

    pub(super) fn bind_group(requested: SocketAddr, count: usize) -> io::Result<Vec<TcpListener>> {
        let mut group = Vec::with_capacity(count);
        // The first bind resolves port 0 to a concrete port; siblings
        // must join that exact port or they'd each get their own.
        let first = bind_one(requested)?;
        let concrete = first.local_addr()?;
        group.push(first);
        for _ in 1..count {
            group.push(bind_one(concrete)?);
        }
        Ok(group)
    }
}

#[cfg(not(target_os = "linux"))]
mod imp {
    use super::*;

    pub(super) fn bind_group(_requested: SocketAddr, _count: usize) -> io::Result<Vec<TcpListener>> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "SO_REUSEPORT listener groups are Linux-only here",
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpStream;

    #[cfg(target_os = "linux")]
    #[test]
    fn group_shares_one_port_and_serves_connects() {
        let group = bind_group("127.0.0.1:0", 3).unwrap();
        let addr = group[0].local_addr().unwrap();
        for l in &group {
            assert_eq!(l.local_addr().unwrap().port(), addr.port(), "one shared port");
            l.set_nonblocking(true).unwrap();
        }
        // Every connect lands in exactly one member's accept queue.
        let n_clients = 24;
        let clients: Vec<TcpStream> =
            (0..n_clients).map(|_| TcpStream::connect(addr).unwrap()).collect();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        let mut accepted = Vec::new();
        while accepted.len() < n_clients && std::time::Instant::now() < deadline {
            let mut progressed = false;
            for l in &group {
                match l.accept() {
                    Ok((s, _)) => {
                        accepted.push(s);
                        progressed = true;
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {}
                    Err(e) => panic!("accept: {e}"),
                }
            }
            if !progressed {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        assert_eq!(accepted.len(), n_clients, "every connect accepted somewhere");
        // The sockets are real: bytes flow end to end.
        (&clients[0]).write_all(b"ping").unwrap();
        let mut buf = [0u8; 4];
        let mut found = false;
        for s in &accepted {
            s.set_nonblocking(true).unwrap();
            let mut r = s;
            if let Ok(4) = r.read(&mut buf) {
                assert_eq!(&buf, b"ping");
                found = true;
            }
        }
        assert!(found, "payload surfaced on an accepted socket");
    }

    #[test]
    fn empty_group_is_rejected() {
        assert!(bind_group("127.0.0.1:0", 0).is_err());
    }
}
